"""Benchmark harness — one function per paper table/figure + framework tables.

Prints ``name,value,derived`` CSV rows (timing rows use µs per call).
Paper tables/figures covered:

* Table 1/2  — kernel energy characterization (model inputs, checked sums)
* Fig. 6     — Single Task vs Julienning vs Whole Application (thermal)
* Fig. 7     — design space: N_bursts vs Q_max (both sensor variants)
* Fig. 8     — design space: E_total overhead vs Q_max
* §4.3       — optimizer scaling (the O(n²) column sweep vs the paper's O(n³·|P|))

Framework tables (beyond paper):

* julienne planners (pipeline / offload / remat) over the model zoo
* roofline summary per (arch × shape × mesh) from experiments/dryrun/*.json
* Pallas kernel microbenches (CPU interpret mode — correctness-path timing)
* partition_sweep: scan vs CSR/Pallas sweep backends + export footprints
  (also written to BENCH_partition_sweep.json)
* plan_table: offline table build vs O(1) request-path lookup vs the
  per-request re-plan it replaces (also written to BENCH_plan_table.json)

CLI: ``--section NAME`` runs one section (default: all);
``--backend {scan,pallas,auto}`` and ``--smoke`` scope the partition_sweep
and plan_table sections so CI can smoke-run them; ``--json-out`` overrides
the JSON path.
"""

import argparse
import glob
import json
import os
import sys
import time

# The sharded-DSE section wants an emulated multi-device host. jax locks the
# device count at first initialization (same constraint as launch/dryrun.py),
# so when that section was explicitly requested and the operator didn't pick
# their own topology, set the flag before anything imports jax.
if "XLA_FLAGS" not in os.environ and any(
    a == "plan_table_sharded" or a.endswith("=plan_table_sharded")
    for a in sys.argv[1:]
):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import PartitionSpec, solve  # noqa: E402
from repro.core import (  # noqa: E402
    PAPER_FRAM_MODEL, q_min, single_task_partition, whole_app_partition)
from repro.core.apps.headcount import THERMAL, VISUAL, build_graph  # noqa: E402

CM = PAPER_FRAM_MODEL


def _np_partition(g, cm, q_max):
    """One numpy-backend partition through the façade (the old
    ``optimal_partition`` call shape)."""
    return solve(PartitionSpec(graph=g, cost=cm, q_max=q_max,
                               backend="numpy")).partition()


def _np_sweep(g, cm, qs):
    """Numpy-backend Q-grid sweep through the façade (the old ``sweep``)."""
    return solve(PartitionSpec(graph=g, cost=cm, q_grid=tuple(qs),
                               backend="numpy")).partitions()


def _row(name, value, derived=""):
    print(f"{name},{value},{derived}")


def table12_energy_characterization():
    g = build_graph(THERMAL)
    _row("table2.n_tasks", g.n_tasks, "paper=5458")
    _row("table2.e_app_J", f"{g.total_task_cost():.6f}", "paper=2.294")
    _row("table2.cnn1_sum_mJ", f"{4125 * 0.396:.1f}", "paper=1633.5")
    _row("table2.cnn2_sum_mJ", f"{936 * 0.396:.1f}", "paper=370.7")
    _row("table2.cnn3_sum_mJ", f"{391 * 0.403:.1f}", "paper=157.6")
    _row("table1.thermal_sense_mJ", 131.9, "measured in paper")
    _row("table1.visual_sense_mJ", 4.4, "measured in paper")


def fig6_partitioning_comparison():
    g = build_graph(THERMAL)
    t0 = time.time()
    jl = _np_partition(g, CM, 132e-3)
    t_opt = (time.time() - t0) * 1e6
    st = single_task_partition(g, CM)
    wa = whole_app_partition(g, CM)
    _row("fig6.julienne.n_bursts", jl.n_bursts, "paper=18")
    _row("fig6.julienne.overhead_pct",
         f"{100 * jl.e_overhead / jl.e_total:.3f}", "paper=0.12")
    _row("fig6.julienne.overhead_mJ", f"{jl.e_overhead * 1e3:.2f}", "paper=2.79")
    _row("fig6.single_task.n_bursts", st.n_bursts, "paper=5458")
    _row("fig6.single_task.MB_transferred",
         f"{st.transfer_bytes / 1e6:.1f}", "paper>437")
    _row("fig6.single_task.overhead_gt_app",
         int(st.e_overhead > st.e_app), "paper: overhead larger than E_app")
    _row("fig6.whole_app.storage_J", f"{wa.max_burst:.4f}", "needs 2.294 J")
    _row("fig6.storage_reduction_pct",
         f"{100 * (1 - q_min(g, CM) / wa.max_burst):.2f}", "paper>94")
    _row("fig6.optimizer_us_per_call", f"{t_opt:.0f}", "5458-task partition")


def fig7_fig8_design_space():
    for spec in (THERMAL, VISUAL):
        g = build_graph(spec)
        qmn = q_min(g, CM)
        qs = np.geomspace(qmn, g.total_task_cost() * 1.05, 12)
        parts = _np_sweep(g, CM, qs)
        for q, p in zip(qs, parts):
            if p is None:
                continue
            _row(f"fig7.{spec.name}.nbursts@Q={q * 1e3:.1f}mJ", p.n_bursts,
                 f"E_total={p.e_total * 1e3:.2f}mJ")
        feas = [p.n_bursts for p in parts if p is not None]
        _row(f"fig7.{spec.name}.feasible_range", f"1-{max(feas)}",
             "paper: thermal 1-18, visual 1-456")
        # Fig 8 caption: overhead < 3% down to storage bounds of 4.3% E_app
        # (thermal's Q_min is already 5.8% of E_app, so report its smallest
        # feasible point; visual reaches 0.2%).
        small = next(p for p in parts if p is not None)
        _row(f"fig8.{spec.name}.overhead_pct@Qmin",
             f"{100 * small.e_overhead / small.e_total:.3f}",
             f"paper<3% ; Qmin={qs[0] * 1e3:.1f}mJ="
             f"{100 * qs[0] / g.total_task_cost():.1f}%Eapp")


def optimizer_scaling():
    from repro.core import GraphBuilder

    for n in (512, 2048, 8192):
        b = GraphBuilder()
        b.packet("x", 1024, external=True)
        for i in range(n):
            w = b.packet(f"p{i}", 64)
            b.task(f"t{i}", reads=("x",), writes=(w,), cost=1e-4)
        g = b.build()
        t0 = time.time()
        _np_partition(g, CM, 0.05)
        _row(f"scaling.partition_n={n}_us", f"{(time.time() - t0) * 1e6:.0f}",
             "column-sweep O(n^2); paper O(n^3 |P|)")


def partition_jax_engine():
    """Jitted batched engine vs the numpy `sweep` path (same outputs: optimal
    E_total + bounds per Q). Headcount Q-grid sweeps at two reductions, the
    optimizer-scaling ladder, and the whole zoo in one vmapped batch."""
    from repro.core import lower_zoo, q_min as qmin_np, tpu_host_offload_model

    def best_of(f, n=3):
        ts = []
        for _ in range(n):
            t0 = time.time()
            f()
            ts.append(time.time() - t0)
        return min(ts)

    # Output parity note: sweep() eagerly builds full Partition objects
    # (per-burst details) per feasible Q; the engine returns the DSE answers
    # (e_total + bounds per Q) as arrays. The speedup row compares those
    # paths as a consumer would call them; the *_jax_full_parts_ms row adds
    # the cost of materializing every Partition from the jax result too.
    for scale in (192, 128, 64):
        g = build_graph(THERMAL.reduced(scale))
        qmn = qmin_np(g, CM)
        qs = list(np.geomspace(qmn, g.total_task_cost() * 1.05, 4096))
        spec = PartitionSpec(graph=g, cost=CM, q_grid=tuple(qs))
        solve(spec)  # compile outside the timed region
        t_jax = best_of(lambda: solve(spec).sweep)
        t_np = best_of(lambda: _np_sweep(g, CM, qs))
        tag = f"partition_jax.headcount_n{g.n_tasks}"
        _row(f"{tag}.q4096_numpy_ms", f"{t_np * 1e3:.1f}",
             "numpy backend: dp + eager Partition objects")
        _row(f"{tag}.q4096_jax_ms", f"{t_jax * 1e3:.1f}",
             "jitted: e_total + bounds arrays")
        _row(f"{tag}.q4096_speedup", f"{t_np / t_jax:.1f}",
             "acceptance: >=5x (n=33 row); see parity note")
        if scale == 192:
            t_jp = best_of(
                lambda: solve(spec).partitions(), n=2
            )
            _row(f"{tag}.q4096_jax_full_parts_ms", f"{t_jp * 1e3:.1f}",
                 "jax engine + eager Partition objects (parity w/ numpy)")

    # whole model zoo, one vmapped kernel: 10 graphs x 512 Q points
    cm = tpu_host_offload_model()
    zoo = lower_zoo(batch=8, seq=4096)
    names = sorted(zoo)
    qmns = {n: qmin_np(zoo[n], cm) for n in names}
    qs = list(np.geomspace(min(qmns.values()), max(qmns.values()) * 64, 512))
    spec = PartitionSpec(graphs=tuple(zoo[n] for n in names), cost=cm,
                         q_grid=tuple(qs))
    solve(spec)  # compile
    t = best_of(lambda: solve(spec).sweeps, n=2)
    _row("partition_jax.zoo.batched_ms", f"{t * 1e3:.1f}",
         f"{len(names)} graphs x 512 Q, one vmap")
    for n, res in zip(names, solve(spec).sweeps):
        feas = np.flatnonzero(res.feasible)
        lo = feas[0] if len(feas) else -1
        b = res.bounds(int(feas[-1])) if len(feas) else []
        _row(f"partition_jax.zoo.{n}", f"{zoo[n].n_tasks}",
             f"qmin={qmns[n] * 1e3:.2f}ms bursts@qmin="
             f"{len(res.bounds(int(lo))) if lo >= 0 else 0} "
             f"bursts@64x={len(b)}")


def partition_sweep(backend="auto", smoke=False, json_out=None):
    """Scan vs CSR/Pallas sweep backends (same outputs, different layouts).

    Rows: export footprint on the full 5458-task head-count graph (dense
    computed analytically — materializing it is the ~1 GB blow-up the CSR
    layout exists to avoid), solver timings on a reduced graph where both
    backends run, the objective matrix (minimax + exact-K per backend, each
    bit-compared against the numpy oracle — any mismatch exits nonzero),
    and (unless ``smoke``) the full-graph CSR solve. Results are also
    dumped to BENCH_partition_sweep.json for trend tracking.
    """
    from repro.core import dense_export_nbytes, q_min as qmin_np

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    def best_of(f, n=3):
        ts = []
        for _ in range(n):
            t0 = time.time()
            f()
            ts.append(time.time() - t0)
        return min(ts)

    # Export footprint: dense (N, R) rectangles vs CSR slot arrays.
    g_full = build_graph(THERMAL)
    csr = g_full.to_csr_arrays()
    r = max(len(t.reads) for t in g_full.tasks)
    w = max(len(t.writes) for t in g_full.tasks)
    dense_b = dense_export_nbytes(g_full.n_tasks, r, w)
    row("partition_sweep.dense_export_MB", f"{dense_b / 1e6:.0f}",
        f"(N,R)=({g_full.n_tasks},{r}) — never materialized")
    row("partition_sweep.csr_export_kB", f"{csr.nbytes / 1e3:.0f}",
        f"{csr.nnz_reads} read slots")
    row("partition_sweep.export_ratio", f"{dense_b / csr.nbytes:.0f}",
        "acceptance: >=50x")

    # Reduced graph where the dense backend is feasible: time both.
    g = build_graph(THERMAL.reduced(64))
    qmn = qmin_np(g, CM)
    qs = list(np.geomspace(qmn, g.total_task_cost() * 1.05, 64))
    backends = ("scan", "pallas") if backend == "auto" else (backend,)
    times = {}
    for be in backends:
        spec = PartitionSpec(graph=g, cost=CM, q_grid=tuple(qs), backend=be)
        solve(spec)  # compile outside the timed region
        times[be] = best_of(lambda spec=spec: solve(spec).sweep)
        row(f"partition_sweep.n{g.n_tasks}.q64_{be}_ms",
            f"{times[be] * 1e3:.1f}", "same outputs (bit-exact columns)")
    if len(times) == 2:
        row("partition_sweep.n90.scan_over_pallas",
            f"{times['scan'] / times['pallas']:.2f}",
            "dense scan vs CSR kernel at equal N")

    # Objective matrix: the kernel's minimax and exact-K modes, timed per
    # backend and bit-compared against the numpy oracle. The *_bit_identical
    # rows are the acceptance gate — CI runs this section as a named step
    # and any mismatch exits nonzero instead of printing a row nobody reads.
    mismatches = []
    ref_qmin = float(qmin_np(g, CM))
    k = min(18, g.n_tasks)
    ref_part = solve(PartitionSpec(graph=g, cost=CM, objective="exact_k",
                                   n_bursts=k, backend="numpy")).partition()
    for be in backends:
        mm_spec = PartitionSpec(graph=g, cost=CM, objective="minimax",
                                backend=be)
        ek_spec = PartitionSpec(graph=g, cost=CM, objective="exact_k",
                                n_bursts=k, backend=be)
        solve(mm_spec), solve(ek_spec)  # compile outside the timed region
        t_mm = best_of(lambda: solve(mm_spec).q_min())
        t_ek = best_of(lambda: solve(ek_spec).partition())
        row(f"partition_sweep.objectives.minimax_{be}_us",
            f"{t_mm * 1e6:.0f}", f"Q_min over n={g.n_tasks}")
        row(f"partition_sweep.objectives.exact_k_{be}_us",
            f"{t_ek * 1e6:.0f}", f"optimal {k}-burst partition")
        mm_ok = solve(mm_spec).q_min() == ref_qmin
        got = solve(ek_spec).partition()
        ek_ok = (list(got.bounds) == list(ref_part.bounds)
                 and got.e_total == ref_part.e_total)
        row(f"partition_sweep.objectives.minimax_{be}_bit_identical",
            int(mm_ok), "vs numpy q_min; acceptance: 1")
        row(f"partition_sweep.objectives.exact_k_{be}_bit_identical",
            int(ek_ok), "vs numpy optimal_partition_k; acceptance: 1")
        if not mm_ok:
            mismatches.append(f"minimax[{be}] != numpy q_min")
        if not ek_ok:
            mismatches.append(f"exact_k[{be}] != numpy optimal partition")

    # The full graph only exists through the CSR backend.
    if not smoke:
        be = "pallas" if backend == "auto" else backend
        if be != "pallas":
            row("partition_sweep.full.skipped", 1,
                "scan backend cannot materialize the full graph")
        else:
            spec_full = PartitionSpec(graph=g_full, cost=CM,
                                      q_grid=(132e-3, None), backend="pallas")
            solve(spec_full)
            t = best_of(lambda: solve(spec_full).sweep, n=2)
            res = solve(spec_full).sweep
            row("partition_sweep.full.q2_pallas_s", f"{t:.2f}",
                f"{g_full.n_tasks} tasks, one fused kernel")
            row("partition_sweep.full.bursts@132mJ",
                len(res.bounds(0)), "paper=18")

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_partition_sweep.json"
    )
    _merge_bench_json(path, records, backend=backend, smoke=bool(smoke))
    if mismatches:
        raise SystemExit("partition_sweep objective matrix: "
                         + "; ".join(mismatches))


def _merge_bench_json(path, new_rows, **meta):
    """Read-modify-write a BENCH json: sections share one trend file, so a
    plan_table run must not clobber the plan_table_sharded rows (or vice
    versa) — rows merge by name, metadata keys overwrite."""
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            data = {}
    rows = data.get("rows", {})
    rows.update(new_rows)
    data.update(meta)
    data["rows"] = rows
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def plan_table_bench(smoke=False, json_out=None):
    """Plan-table serving subsystem: offline build cost vs online lookup.

    Rows: one-shot table build (the whole bucket × Q grid in one batched
    engine call), table footprint, O(1) lookup latency, and the per-request
    re-plan it replaces (lower the request's graph + solve one Q — what
    serve.py would otherwise do per request). Results also land in
    BENCH_plan_table.json for trend tracking.
    """
    from repro.core.layer_profile import lower_config
    from repro.core.plan_table import _default_cost
    from repro.launch.planner import build_table_for_arch, resolve_config

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    arch = "qwen3-4b"
    buckets = [(2, 24), (2, 48)] if smoke else [(2, 24), (2, 48), (4, 48), (4, 96)]
    n_q = 8 if smoke else 32
    t0 = time.time()
    table = build_table_for_arch(arch, buckets, n_q)
    build_s = time.time() - t0
    row("plan_table.build_ms", f"{build_s * 1e3:.1f}",
        f"{len(buckets)} buckets x {table.n_q} Q, one batched solve")
    row("plan_table.size_kB", f"{table.nbytes() / 1e3:.1f}",
        f"{int(table.feasible.sum())} feasible plans")

    cfg = resolve_config(arch, smoke=True)
    cm = _default_cost("time")
    mid_q = float(np.median(table.q_grid[np.isfinite(table.q_grid)]))

    n_lookups = 2000
    t0 = time.time()
    for _ in range(n_lookups):
        table.lookup(2, 20, mid_q)
    lookup_us = (time.time() - t0) / n_lookups * 1e6
    row("plan_table.lookup_us", f"{lookup_us:.1f}",
        "bucketize + Q select + plan slice (request path)")

    # the per-request alternative: lower the shape and solve one Q
    def _replan():
        g = lower_config(cfg, 2, 24, kind="time")  # per-request lowering
        return solve(PartitionSpec(graph=g, cost=cm, q_max=mid_q)).partition()

    _replan()
    n_replans = 5
    t0 = time.time()
    for _ in range(n_replans):
        _replan()
    replan_us = (time.time() - t0) / n_replans * 1e6
    row("plan_table.replan_us", f"{replan_us:.0f}",
        "lower_config + one-Q solve per request (the path lookups replace)")
    row("plan_table.lookup_speedup", f"{replan_us / max(lookup_us, 1e-9):.0f}",
        "re-plan / lookup")

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_plan_table.json"
    )
    _merge_bench_json(path, records, smoke=bool(smoke))


def plan_table_sharded(smoke=False, json_out=None):
    """Sharded DSE: multi-device plan-table builds + incremental extension.

    The ROADMAP-scale sweep: 10⁵ Q points × 100 graph variants (100 (batch,
    seq) buckets of the *full* qwen3-4b config — a production bucket fleet)
    solved once single-host and once Q-sharded across an 8-device mesh
    (emulated via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
    which this script sets itself when the section is requested). Rows pin
    the acceptance bit — the sharded table is byte-identical to the
    single-host one — plus build timings, and the incremental-extension
    path: growing the fleet by one batch row re-solves only the new cells
    (SOLVE_COUNT-verified) instead of rebuilding the world. ``--smoke``
    shrinks the grid for CI. Rows merge into BENCH_plan_table.json.
    """
    import jax

    from repro.api import QGridSharding
    from repro.configs import get_config
    from repro.core import partition_jax as pj
    from repro.core.plan_table import (
        _default_cost, build_plan_table, extend_plan_table)
    from repro.launch.mesh import shard_devices
    from repro.launch.planner import derive_q_grid, lower_buckets

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    arch = "qwen3-4b"
    cfg = get_config(arch)
    if smoke:
        batches, seqs, n_q, shards = [2, 4], [64, 128, 256], 511, 4
    else:
        batches = [1, 2, 4, 8, 16]
        seqs = [128 * k for k in range(1, 21)]  # 128..2560
        n_q, shards = 99_999, 8
    buckets = [(b, s) for b in batches for s in seqs]
    cm = _default_cost("time")
    graphs = lower_buckets(cfg, buckets, "time")
    qs = derive_q_grid(graphs, cm, n_q)  # +1 unbounded entry
    n_dev = len(jax.local_devices())
    row("plan_table_sharded.grid", f"{len(buckets)}x{len(qs)}",
        f"buckets x Q points, {arch} full config ({graphs[0].n_tasks} tasks)")
    row("plan_table_sharded.devices", n_dev,
        f"{shards} shards; pmap needs devices >= shards, else seq fallback")

    t0 = time.time()
    single = build_plan_table(cfg, buckets, qs, cost=cm, graphs=graphs)
    t_single = time.time() - t0
    row("plan_table_sharded.single_host_build_s", f"{t_single:.2f}",
        "one batched engine call + vectorized assembly")
    t0 = time.time()
    sharded = build_plan_table(
        cfg, buckets, qs, cost=cm, graphs=graphs,
        sharding=QGridSharding(shards, shard_devices(shards)))
    t_shard = time.time() - t0
    row("plan_table_sharded.sharded_build_s", f"{t_shard:.2f}",
        f"{shards}-way Q-shard "
        f"({'pmap mesh' if n_dev >= shards else 'sequential fallback'})")
    row("plan_table_sharded.byte_identical",
        int(sharded.content_digest() == single.content_digest()),
        "acceptance: 1 (sharded == single-host bytes)")
    row("plan_table_sharded.table_MB", f"{single.nbytes() / 1e6:.1f}",
        f"{int(single.feasible.sum())} feasible plans")

    # Incremental extension: grow the fleet by one batch row without
    # re-solving the existing cells.
    n_keep = len(buckets) - len(seqs)
    base = build_plan_table(cfg, buckets[:n_keep], qs, cost=cm,
                            graphs=graphs[:n_keep])
    solves0 = dict(pj.SOLVE_COUNT)
    t0 = time.time()
    ext = extend_plan_table(base, cfg, add_buckets=buckets[n_keep:], cost=cm)
    t_ext = time.time() - t0
    delta = {k: pj.SOLVE_COUNT[k] - solves0[k] for k in solves0}
    row("plan_table_sharded.extend_s", f"{t_ext:.2f}",
        f"+{len(buckets) - n_keep} buckets x {len(qs)} Q appended")
    row("plan_table_sharded.extend_engine_calls", sum(delta.values()),
        "solves for the new cells only (old cells byte-moved)")
    row("plan_table_sharded.extend_matches_fresh",
        int(ext.content_digest() == single.content_digest()),
        "acceptance: 1 (incremental == fresh bytes)")
    row("plan_table_sharded.extend_speedup", f"{t_single / max(t_ext, 1e-9):.1f}",
        "full rebuild / incremental extension")
    solves0 = dict(pj.SOLVE_COUNT)
    untouched = extend_plan_table(ext, cfg, add_buckets=buckets, cost=cm)
    n_calls = sum(pj.SOLVE_COUNT[k] - solves0[k] for k in solves0)
    if untouched is not ext:  # must be the base object, not a rebuild
        n_calls = -1
    row("plan_table_sharded.untouched_extend_solves", n_calls,
        "acceptance: 0 (re-extend of an untouched base never re-solves)")

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_plan_table.json"
    )
    _merge_bench_json(path, records, sharded_smoke=bool(smoke))


def api_facade(smoke=False, json_out=None):
    """Façade dispatch overhead: ``solve(PartitionSpec)`` vs calling the
    engine implementation directly.

    The façade validates the spec, resolves the backend through the
    registry's capability flags, and wraps the result — all host-side
    bookkeeping. The acceptance row pins that this costs <1% on the smoke
    config (the old direct ``sweep_jax_batched`` call shape), so routing
    every consumer through the one API is free at solve granularity. Rows
    merge into BENCH_partition_sweep.json.
    """
    from repro.core import lower_config, q_min as qmin_np
    from repro.core.partition_jax import _sweep_jax_batched
    from repro.core.plan_table import _default_cost
    from repro.launch.planner import resolve_config

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    def median_of(f, n=25):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            f()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    cfg = resolve_config("qwen3-4b", smoke=True)
    cm = _default_cost("time")
    graphs = [lower_config(cfg, b, s, kind="time")
              for (b, s) in ((2, 24), (2, 48))]
    qmn = min(qmin_np(g, cm) for g in graphs)
    n_q = 1024 if smoke else 8192
    qs = list(np.geomspace(qmn, qmn * 64, n_q)) + [None]
    spec = PartitionSpec(graphs=tuple(graphs), cost=cm, q_grid=tuple(qs),
                         backend="scan")

    _sweep_jax_batched(graphs, cm, qs, backend="scan")  # compile once
    solve(spec)
    t_direct = median_of(
        lambda: _sweep_jax_batched(graphs, cm, qs, backend="scan")
    )
    t_facade = median_of(lambda: solve(spec))

    # The two medians above sit inside the same multi-ms XLA-dispatch noise
    # band, so the *added* cost is also measured in isolation: run the full
    # façade shell (spec validation, registry resolution, capability checks,
    # Solution wrap) against a stubbed-out solver and charge its whole
    # median against the direct solve time. This is the number the <1%
    # acceptance bound actually constrains.
    import repro.core.partition_jax as _pj

    canned = _sweep_jax_batched(graphs, cm, qs, backend="scan")
    real_impl = _pj._sweep_jax_batched
    _pj._sweep_jax_batched = lambda *a, **k: canned
    try:
        t_shell = median_of(lambda: solve(spec), n=200)
    finally:
        _pj._sweep_jax_batched = real_impl
    overhead = 100.0 * t_shell / t_direct

    row("api_facade.direct_ms", f"{t_direct * 1e3:.2f}",
        "engine implementation called directly (old sweep_jax_batched path)")
    row("api_facade.solve_ms", f"{t_facade * 1e3:.2f}",
        "solve(PartitionSpec) end to end (same noise band as direct)")
    row("api_facade.dispatch_us", f"{t_shell * 1e6:.1f}",
        "façade shell alone: validate + registry dispatch + wrap")
    row("api_facade.overhead_pct", f"{overhead:.3f}",
        "dispatch / direct solve; acceptance: <1% on the smoke config")
    row("api_facade.grid", f"{len(graphs)}x{len(qs)}",
        "smoke buckets x Q points, scan backend")

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_partition_sweep.json"
    )
    _merge_bench_json(path, records, facade_smoke=bool(smoke))
    # This section *is* the acceptance gate (CI runs it as a named step):
    # fail loudly instead of merely printing a row nobody asserts on.
    if overhead >= 1.0:
        raise SystemExit(
            f"api_facade: dispatch overhead {overhead:.3f}% breaks the <1% "
            f"acceptance bound ({t_shell * 1e6:.1f} µs shell vs "
            f"{t_direct * 1e3:.2f} ms solve)"
        )


def serving_traffic(smoke=False, json_out=None):
    """Continuous-traffic serving: the plan table under sustained load.

    Drives :class:`repro.launch.traffic.TrafficHarness` over the real
    planned executor with a deterministic burst of same-shape requests plus
    an admission-controlled run (capacity ≈ 1.5 requests, income ≈ 0.9
    request-energies per unit virtual time → at least one deferral). Rows:
    sustained requests/sec, wall p50/p95/p99 latency, plan-cache hit rate,
    admission/deferral/reject counts, and the zero-retrace acceptance bit.
    Results land in BENCH_serving.json. This section is also the acceptance
    gate: any post-warmup retrace or a failed admission split exits nonzero.
    """
    from repro.launch.planner import build_table_for_arch
    from repro.launch.serve import PlannedExecutor
    from repro.launch.traffic import (
        HarvestModel, TrafficHarness, deterministic_arrivals, request_energy)

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    arch = "qwen3-4b"
    batch, prompt_len, gen = 2, 8, 6
    n_requests = 8 if smoke else 32
    max_seq = prompt_len + gen
    table = build_table_for_arch(arch, [(batch, max_seq)], n_q=8)
    ex = PlannedExecutor(arch, table)
    plan = ex.planner.plan_for(batch, max_seq, None)
    _, e_req = request_energy(plan, gen, None, ex.planner.e_startup)
    reqs = deterministic_arrivals(n_requests, 0.0, (batch, prompt_len, gen))

    # throughput run: unlimited harvest, compile outside the measured window
    harness = TrafficHarness(ex)
    harness.warmup(reqs)
    report = harness.run(reqs)
    pct = report.latency_percentiles_ms()
    row("serving_traffic.requests", report.completed,
        f"{arch} {batch}x{prompt_len}x{gen}, deterministic burst")
    row("serving_traffic.requests_per_s", f"{report.requests_per_s:.1f}",
        "sustained, warm caches")
    row("serving_traffic.latency_p50_ms", f"{pct['p50']:.1f}",
        "wall-clock arrival→complete")
    row("serving_traffic.latency_p95_ms", f"{pct['p95']:.1f}", "")
    row("serving_traffic.latency_p99_ms", f"{pct['p99']:.1f}", "")
    row("serving_traffic.hit_rate", f"{report.hit_rate:.3f}",
        "plan-cache lookups answered from the table; acceptance: 1.0")
    row("serving_traffic.retraces", report.retraces,
        "jit retraces after warmup; acceptance: 0")

    # admission run: pool holds ~1.5 requests, income ~0.9 req/unit-time
    harness2 = TrafficHarness(
        ex, harvest=HarvestModel(capacity=1.5 * e_req, rate=0.9 * e_req))
    report2 = harness2.run(deterministic_arrivals(
        max(3, n_requests // 4), 0.0, (batch, prompt_len, gen)))
    row("serving_traffic.admitted", report2.admitted,
        "capacity=1.5 req, rate=0.9 req/t")
    row("serving_traffic.deferred", report2.deferred,
        "acceptance: >=1 (pool too small for the burst)")
    row("serving_traffic.rejected", report2.rejected, "")
    row("serving_traffic.energy_spent", f"{report2.energy_spent:.4f}",
        f"one request draws {e_req:.4f} (table units)")

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_serving.json")
    _merge_bench_json(path, records, smoke=bool(smoke))

    failures = []
    if report.retraces:
        failures.append(f"{report.retraces} retraces after warmup "
                        f"({report.trace_delta})")
    if report.completed != n_requests or report.hit_rate != 1.0:
        failures.append(
            f"throughput run: {report.completed}/{n_requests} completed, "
            f"hit rate {report.hit_rate}")
    if report2.deferred < 1 or report2.completed != report2.arrived:
        failures.append(
            f"admission run: {report2.deferred} deferred, "
            f"{report2.completed}/{report2.arrived} completed")
    if failures:
        raise SystemExit("serving_traffic: " + "; ".join(failures))


def telemetry_overhead(smoke=False, json_out=None):
    """Telemetry cost on the instrumented serving hot path (plan lookup +
    admission + burst step), tracing enabled vs disabled.

    Drives the full TrafficHarness request path over tiny numpy chain
    graphs (the fast-tier synthetic-executor shape — no jax, no XLA), so
    the only delta between the timed runs is ``repro.obs`` itself: span
    capture, per-request instants, harvest counters, and the energy
    ledger. Two acceptance rows, both gated here (CI runs this section as
    a named step):

    * enabled: the added wall cost per request must stay under 1% of the
      measured serving pace in BENCH_serving.json (requests_per_s);
    * disabled: tracing compiles down to one ``TRACER.enabled`` attribute
      check per instrumentation site — the residual is measured directly
      and must round to 0% of the same pace.

    Rows merge into BENCH_serving.json.
    """
    from repro.core import (
        BurstRuntime, CostModel, GraphBuilder, LinearTransfer, Partition)
    from repro.core.burst import burst_detail
    from repro.launch.planner import ServePlanner, request_cycles
    from repro.launch.traffic import (
        Continuation, HarvestModel, Request, TrafficHarness,
        deterministic_arrivals)
    from repro.obs.metrics import reset_all
    from repro.obs.trace import TRACER

    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    e_total, e_startup = 0.25, 0.1

    class _Plan:
        def __init__(self, batch, seq_bucket):
            self.batch, self.seq_bucket, self.e_total = batch, seq_bucket, e_total

        def summary(self):
            return f"{self.batch}x{self.seq_bucket}"

    class _Table:  # duck-typed PlanTable: exact batch, covering seq bucket
        arch = "synthetic"
        e_startup = 0.1  # == the CostModel e_startup below

        def lookup(self, batch, seq, energy_budget=None):
            return _Plan(batch, max(seq, 16))

    class _Exec:  # the fast-tier synthetic executor shape (numpy chains)
        def __init__(self):
            self.planner = ServePlanner(_Table())
            self._rid = 0

        def open(self, batch, prompt_len, gen, *, seed=0, cycle_budget=None,
                 prompts=None, plan=None, nvm=None, crash_hook=None):
            if plan is None:
                plan = self.planner.plan_for(batch, prompt_len + gen,
                                             cycle_budget)
            b = GraphBuilder()
            b.packet("prompts", 8, external=True)
            for k in range(gen - 1):
                b.packet(f"state{k}", 8)
            b.packet("sequence", 8, keep=True)

            def mk(k):
                def fn(inp):
                    src = inp["prompts"] if k == 0 else inp[f"state{k - 1}"]
                    name = "sequence" if k == gen - 1 else f"state{k}"
                    return {name: np.asarray(src) + 1}
                return fn

            for k in range(gen):
                b.task(f"step{k}",
                       reads=("prompts",) if k == 0 else (f"state{k - 1}",),
                       writes=("sequence",) if k == gen - 1 else (f"state{k}",),
                       cost=plan.e_total, fn=mk(k))
            graph = b.build()
            cycles = request_cycles(gen, plan.e_total, cycle_budget,
                                    e_startup=e_startup)
            cost = CostModel(e_startup=e_startup,
                             read=LinearTransfer(0.0, 0.0),
                             write=LinearTransfer(0.0, 0.0), name="synthetic")
            part = Partition(
                cycles,
                [burst_detail(graph, cost, i, j) for (i, j) in cycles], None)
            rt = BurstRuntime(graph, part, nvm=nvm, cost=cost,
                              crash_hook=crash_hook)
            if rt.nvm.read_index() == 0:
                rt.seed_inputs({"prompts": np.full((batch,), seed, np.int64)})
            rid, self._rid = self._rid, self._rid + 1
            return Continuation(
                request=Request(rid=rid, batch=batch, prompt_len=prompt_len,
                                gen=gen, seed=seed),
                plan=plan, cycles=list(cycles), runtime=rt,
                e_startup=e_startup)

    gen, q = 6, 0.4                      # 6 one-step cycles per request
    n_requests = 16 if smoke else 48
    e_req = gen * (e_startup + e_total)  # E_s is paid per cycle at this Q
    reqs = deterministic_arrivals(n_requests, 0.0, (1, 4, gen))
    n_cycles = n_requests * gen

    def one_run():
        harness = TrafficHarness(
            _Exec(), harvest=HarvestModel(capacity=n_requests * e_req),
            cycle_budget=q)
        report = harness.run(reqs)
        if report.completed != n_requests:
            raise SystemExit(
                f"telemetry_overhead: {report.completed}/{n_requests} "
                f"completed — measurement run is broken")
        return report

    def timed(enabled):
        if enabled:
            TRACER.configure(enabled=True, clear=True)
        try:
            t0 = time.perf_counter()
            one_run()
            return time.perf_counter() - t0
        finally:
            if enabled:
                TRACER.reset()
            reset_all()

    timed(False)  # warm allocators / imports outside the measured window
    timed(True)
    reps = 5 if smoke else 7
    t_dis, t_en = [], []
    for _ in range(reps):  # interleave so drift hits both modes equally
        t_dis.append(timed(False))
        t_en.append(timed(True))
    t_dis, t_en = min(t_dis), min(t_en)  # min-of-N: robust to scheduler noise
    added_us_req = max(0.0, t_en - t_dis) / n_requests * 1e6

    # the disabled-mode residual: one attribute check per instrumentation
    # site (span guard / instant guard / counter guard), measured directly
    n_checks = 1_000_000
    t0 = time.perf_counter()
    for _ in range(n_checks):
        if TRACER.enabled:
            pass
    guard_ns = (time.perf_counter() - t0) / n_checks * 1e9
    # sites per request: ~3 arrival/admission events + ~4 per cycle
    # (cycle span, harvest sample, burst span, commit instant)
    sites_per_req = 3 + 4 * gen
    disabled_us_req = guard_ns * sites_per_req / 1e3

    # the pace the <1% bound is charged against: the measured real-model
    # serving throughput from the serving_traffic section of this file
    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_serving.json")
    try:
        with open(path) as f:
            rps = float(json.load(f)["rows"]
                        ["serving_traffic.requests_per_s"]["value"])
    except (OSError, KeyError, ValueError, json.JSONDecodeError):
        raise SystemExit(
            f"telemetry_overhead: no serving_traffic.requests_per_s row in "
            f"{path} — run the serving_traffic section first")
    budget_us_req = 1e6 / rps
    overhead_pct = 100.0 * added_us_req / budget_us_req
    disabled_pct = 100.0 * disabled_us_req / budget_us_req

    row("telemetry_overhead.run_disabled_ms", f"{t_dis * 1e3:.2f}",
        f"{n_requests} requests / {n_cycles} cycles, tracing off (min of "
        f"{reps})")
    row("telemetry_overhead.run_enabled_ms", f"{t_en * 1e3:.2f}",
        "same run: spans + instants + counters + energy ledger captured")
    row("telemetry_overhead.added_us_per_request", f"{added_us_req:.1f}",
        "enabled minus disabled wall, per request")
    row("telemetry_overhead.guard_ns", f"{guard_ns:.1f}",
        "one TRACER.enabled check — all a disabled site costs")
    row("telemetry_overhead.enabled_pct", f"{overhead_pct:.3f}",
        f"added cost vs measured serving pace ({budget_us_req / 1e3:.1f} "
        f"ms/request); acceptance: <1%")
    row("telemetry_overhead.disabled_pct", f"{disabled_pct:.4f}",
        f"{sites_per_req} guard checks/request vs the same pace; "
        f"acceptance: <0.05% (~0)")

    _merge_bench_json(path, records, telemetry_smoke=bool(smoke))

    failures = []
    if overhead_pct >= 1.0:
        failures.append(
            f"enabled tracing adds {added_us_req:.1f} µs/request = "
            f"{overhead_pct:.3f}% of the serving pace (bound: <1%)")
    if disabled_pct >= 0.05:
        failures.append(
            f"disabled residual {disabled_pct:.4f}% is not ~0 — a hot-path "
            f"site is doing work beyond the TRACER.enabled guard")
    if failures:
        raise SystemExit("telemetry_overhead: " + "; ".join(failures))


def calibration_bench(smoke=False, json_out=None):
    """Calibration-loop cost and contract gates (core/calibration.py).

    * ledger → MeasuredCostTable ingest pace (Welford accumulation) and
      fingerprint time;
    * the sigma=0 contract, as a hard gate: a table whose samples match
      the analytical model must materialize the analytical CostModel
      *object* and sweep bit-identically through the engine;
    * confidence pricing overhead: E_total at confidence 0.95 over the
      mean-priced E_total on the qwen3-4b smoke graph — must be >= 1
      (pricing is pessimistic, never optimistic).

    Rows merge into BENCH_serving.json.
    """
    import random

    from repro.api import PartitionSpec, solve
    from repro.core import lower_config
    from repro.core.calibration import MeasuredCostTable
    from repro.core.layer_profile import analytical_cost_model
    from repro.obs.ledger import EnergyLedger
    from repro.configs import SMOKE_CONFIGS

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_serving.json")
    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    cm = analytical_cost_model("time")
    rng = random.Random(0)
    n_rows = 600 if smoke else 3000

    led = EnergyLedger()
    for i in range(n_rows // 3):
        led.charge(i % 7, i // 7, restore=float(cm.e_startup),
                   compute=rng.uniform(1e-5, 1e-4), commit=1e-6)
    t0 = time.time()
    clean = MeasuredCostTable.from_ledger(led, base=cm, kind="time")
    t_ingest = time.time() - t0
    row("calibration.ingest_rows", str(clean.n_samples), "ledger entries")
    row("calibration.ingest_ms", f"{t_ingest * 1e3:.2f}",
        f"{clean.n_samples / max(t_ingest, 1e-9):.0f} rows/s Welford")
    t0 = time.time()
    fp = clean.fingerprint()
    row("calibration.fingerprint_us", f"{(time.time() - t0) * 1e6:.0f}",
        f"sha256 {fp[:12]}…")

    # sigma=0 gate: identical-object materialization + bitwise sweep
    g = lower_config(SMOKE_CONFIGS["qwen3-4b"], batch=2, seq=16, kind="time")
    qs = (5e-5, None)
    base_sweep = solve(PartitionSpec(graph=g, cost=cm, q_grid=qs,
                                     backend="scan")).sweep
    meas_sweep = solve(PartitionSpec(graph=g, cost=clean, q_grid=qs,
                                     backend="scan")).sweep
    identical = clean.cost_model() is cm and all(
        getattr(base_sweep, f).tobytes() == getattr(meas_sweep, f).tobytes()
        for f in ("dp", "parent", "e_total", "feasible", "starts"))
    row("calibration.sigma0_bit_identical", str(int(identical)),
        "clean table materializes the analytical model; acceptance: ==1")

    # confidence overhead on a noisy profile
    noisy = MeasuredCostTable(cm, "time")
    for _ in range(200):
        noisy.add("restore", rng.gauss(float(cm.e_startup) * 2, float(cm.e_startup) * 0.5))
        noisy.add("commit", abs(rng.gauss(1e-6, 3e-7)))
    t0 = time.time()
    e_mean = float(solve(PartitionSpec(
        graph=g, cost=noisy, q_grid=(None,), backend="scan")).sweep.e_total[0])
    e_conf = float(solve(PartitionSpec(
        graph=g, cost=noisy, q_grid=(None,), confidence=0.95,
        backend="scan")).sweep.e_total[0])
    t_solve = time.time() - t0
    ratio = e_conf / e_mean
    row("calibration.confidence_overhead_ratio", f"{ratio:.4f}",
        "E_total@0.95 / E_total@mean on qwen3-4b smoke; acceptance: >=1")
    row("calibration.confident_solve_ms", f"{t_solve / 2 * 1e3:.1f}",
        "mean of the two priced solves above")

    _merge_bench_json(path, records, calibration_smoke=bool(smoke))

    failures = []
    if not identical:
        failures.append(
            "sigma=0 table does not reproduce the analytical sweep "
            "bit-for-bit — the measured path is recomputing, not slotting in")
    if ratio < 1.0:
        failures.append(
            f"confidence pricing lowered E_total ({ratio:.4f} < 1) — "
            f"mean + z*sigma must never be optimistic")
    if failures:
        raise SystemExit("calibration: " + "; ".join(failures))


def placement_bench(smoke=False, json_out=None):
    """Swarm placement grid solver (core/placement.py + placement_jax.py).

    * solve pace: the whole bandwidth × memory × Q grid in ONE batched
      engine call (cold = includes jit compile, warm = steady state);
    * transfer overhead at the best cell of a memory-constrained swarm
      (the NS-Optimizer-style figure: hop TX+RX over swarm E_total);
    * ``placement.oracle_bit_identical`` as a hard gate: the scan backend
      must reproduce the numpy reference on every DP array — values *and*
      argmin parents — and every feasible plan must conserve energy
      node-by-node. Nonzero exit on any mismatch.

    Rows land in BENCH_placement.json.
    """
    import numpy as np

    from repro.api import Engine, PartitionSpec, solve
    from repro.core.layer_profile import default_cost_model
    from repro.core.placement import (
        LinkModel, NodeSpec, PlacementSpec, solve_placement_numpy,
    )
    from repro.core.placement_jax import solve_placement_scan

    path = json_out or os.path.join(
        os.path.dirname(__file__), "BENCH_placement.json")
    records = {}

    def row(name, value, derived=""):
        _row(name, value, derived)
        records[name] = {"value": value, "derived": derived}

    cm = default_cost_model("time")
    # an NS-Optimizer-shaped relay chain: enough layers that per-node NVM
    # caps actually bite (the zoo smoke graphs are 2-6 fused tasks — too
    # coarse to cut; scale is the point of this section)
    from repro.core.graph import GraphBuilder

    n_tasks = 24 if smoke else 64
    b = GraphBuilder()
    prev = None
    for i in range(n_tasks):
        pkt = f"act{i}"
        b.packet(pkt, 50_000 + 10_000 * (i % 7), keep=(i == n_tasks - 1))
        b.task(f"layer{i}", reads=(prev,) if prev else (), writes=(pkt,),
               cost=0.01 + 0.002 * (i % 5))
        prev = pkt
    g = b.build()
    qmin = solve(PartitionSpec(graph=g, cost=cm, objective="minimax")).q_min()
    n_links = 8 if smoke else 25
    bandwidths = [900.0 + 100.0 * i for i in range(n_links)]
    # cap node NVM below the whole-graph footprint so the swarm must split
    from repro.core.placement import placement_inputs

    probe = placement_inputs(
        g, cm, PlacementSpec(nodes=3, link=LinkModel(900.0)))
    full_mem = float(probe.mem[1, g.n_tasks])
    spec = PlacementSpec(
        nodes=tuple(
            NodeSpec(q_max=qmin * 1.25, memory_bytes=full_mem * 0.6)
            for _ in range(3)
        ),
        links=tuple(LinkModel(bw) for bw in bandwidths),
        q_scales=(0.9, 1.0, 1.2),
    )
    L, M, Z = spec.grid_shape

    eng = Engine()
    pspec = PartitionSpec(graph=g, cost=cm, placement=spec)
    t0 = time.time()
    sol = eng.solve(pspec)
    t_cold = time.time() - t0
    t0 = time.time()
    sol = eng.solve(pspec)
    t_warm = time.time() - t0
    sweep = sol.placement_sweep()
    cells = L * M * Z
    row("placement.grid_cells", str(cells),
        f"{L} links x {M} mem x {Z} Q, 3 nodes, {g.n_tasks} tasks")
    row("placement.solve_cold_ms", f"{t_cold * 1e3:.1f}",
        "one batched engine call incl. jit compile")
    row("placement.solve_warm_ms", f"{t_warm * 1e3:.1f}",
        f"{cells / max(t_warm, 1e-9):.0f} cells/s steady state")

    feasible = [p for p in sweep.plans() if p is not None]
    row("placement.feasible_cells", str(len(feasible)), f"of {cells}")
    best = min(feasible, key=lambda p: p.e_total)
    row("placement.transfer_overhead_pct",
        f"{100 * best.transfer_overhead:.2f}",
        f"best cell: {best.n_nodes_used} nodes @ "
        f"{best.link.bandwidth_mbps:g} mbps, "
        f"{best.transfer_bytes:.0f} B over {len(best.hop_boundaries)} hops")

    # the hard gate: scan == numpy bitwise, ledgers conserve
    ref = solve_placement_numpy(g, cm, spec)
    got = solve_placement_scan(g, cm, spec)
    identical = all(
        np.array_equal(getattr(ref, f), getattr(got, f))
        for f in ("e_total", "k_used", "outer_dp", "outer_parent",
                  "inner_S", "inner_A")
    )
    conserved = True
    for p in feasible:
        try:
            p.validate()
            p.check_conservation()
        except Exception:
            conserved = False
            break
    row("placement.oracle_bit_identical", str(int(identical)),
        "scan DP arrays == numpy reference bitwise; acceptance: ==1")
    row("placement.ledger_conserved", str(int(conserved)),
        f"{len(feasible)} feasible plans conserve node-by-node; "
        f"acceptance: ==1")

    _merge_bench_json(path, records, placement_smoke=bool(smoke))

    failures = []
    if not identical:
        failures.append(
            "scan backend diverged from the numpy placement oracle — "
            "bit-identity (values and parents) is the backend contract")
    if not conserved:
        failures.append(
            "a feasible placement plan failed per-node ledger conservation")
    if not feasible:
        failures.append("no feasible cell on the benchmark grid")
    if failures:
        raise SystemExit("placement: " + "; ".join(failures))


def julienne_planners():
    from repro.configs import REGISTRY
    from repro.core.offload import min_activation_budget, plan_offload
    from repro.core.pipeline import plan_pipeline
    from repro.core.remat_policy import plan_remat

    for arch in ("deepseek-coder-33b", "zamba2-7b", "whisper-large-v3",
                 "phi3.5-moe-42b-a6.6b"):
        cfg = REGISTRY[arch]
        pp = plan_pipeline(cfg, 16, 4096, 8)
        _row(f"pipeline.{arch}.balance", f"{pp.balance:.3f}",
             f"bottleneck={pp.bottleneck_seconds * 1e3:.1f}ms")
        qmn = min_activation_budget(cfg, 4, 4096)
        _row(f"offload.{arch}.qmin_GB", f"{qmn / 1e9:.3f}",
             "smallest feasible activation budget (§4.4), B=4")
        op = plan_offload(cfg, 4, 4096, qmn * 2)
        _row(f"offload.{arch}.pcie_overhead_pct",
             f"{100 * op.overhead_fraction:.1f}",
             f"{op.n_segments} segments @ 2×Qmin")
        rp = plan_remat(cfg, 4, 4096, qmn * 16)
        _row(f"remat.{arch}.recompute_pct",
             f"{100 * rp.recompute_fraction:.1f}",
             f"{rp.n_segments} segments @ 16×Qmin")


def roofline_summary():
    recs = []
    for f in glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                    "experiments", "dryrun", "*.json")):
        recs.append(json.load(open(f)))
    ok = [r for r in recs if r.get("status") == "ok"]
    if not ok:
        _row("roofline.cells", 0, "run launch/dryrun first")
        return
    _row("roofline.cells_ok", len(ok),
         f"skipped={sum(r.get('status') == 'skipped' for r in recs)}")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        dom = r["dominant"].replace("t_", "")
        _row(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
             f"{max(t.values()) * 1e3:.2f}ms", f"dominant={dom}")


def kernel_microbench():
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rmsnorm.ops import rmsnorm

    q = jnp.ones((1, 256, 4, 64), jnp.bfloat16)
    k = jnp.ones((1, 256, 2, 64), jnp.bfloat16)
    flash_attention(q, k, k, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        flash_attention(q, k, k, interpret=True).block_until_ready()
    _row("kernel.flash_attention_us", f"{(time.time() - t0) / 3 * 1e6:.0f}",
         "interpret mode (correctness path, not TPU perf)")
    x = jnp.ones((1024, 512), jnp.bfloat16)
    w = jnp.ones((512,), jnp.float32)
    rmsnorm(x, w, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        rmsnorm(x, w, interpret=True).block_until_ready()
    _row("kernel.rmsnorm_us", f"{(time.time() - t0) / 3 * 1e6:.0f}",
         "interpret mode")


SECTIONS = {
    "tables": table12_energy_characterization,
    "fig6": fig6_partitioning_comparison,
    "design_space": fig7_fig8_design_space,
    "scaling": optimizer_scaling,
    "partition_jax": partition_jax_engine,
    "partition_sweep": partition_sweep,
    "plan_table": plan_table_bench,
    "plan_table_sharded": plan_table_sharded,
    "api_facade": api_facade,
    "serving_traffic": serving_traffic,
    "telemetry_overhead": telemetry_overhead,
    "calibration": calibration_bench,
    "placement": placement_bench,
    "planners": julienne_planners,
    "roofline": roofline_summary,
    "kernels": kernel_microbench,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--section", choices=sorted(SECTIONS), default=None,
                    help="run one section instead of all")
    ap.add_argument("--backend", choices=("scan", "pallas", "auto"),
                    default="auto",
                    help="partition_sweep: which solver backend(s) to time")
    ap.add_argument("--smoke", action="store_true",
                    help="partition_sweep: skip the full 5458-task solve")
    ap.add_argument("--json-out", default=None,
                    help="partition_sweep: override the JSON dump path")
    args = ap.parse_args(argv)

    print("name,value,derived")
    sections = [args.section] if args.section else list(SECTIONS)
    for name in sections:
        fn = SECTIONS[name]
        if name == "partition_sweep":
            fn(backend=args.backend, smoke=args.smoke, json_out=args.json_out)
        elif name in ("plan_table", "plan_table_sharded", "api_facade",
                      "serving_traffic", "telemetry_overhead", "calibration",
                      "placement"):
            fn(smoke=args.smoke, json_out=args.json_out)
        else:
            fn()


if __name__ == "__main__":
    main()
