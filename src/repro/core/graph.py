"""Ladybirds-style task-graph specification model (paper §3).

An application is a *sequence* of tasks. Each task reads and writes a set of
named :class:`Packet`\\ s with statically known sizes. Packets obey SSA: each
packet is written by exactly one task (or is an *external* input, conceptually
written by a virtual task 0). Packets marked ``keep=True`` are application
outputs, conceptually read by a virtual task ``n_t + 1`` — they must survive
the final burst.

The analysis products mirror the paper's §4.2 definitions:

* ``writer[p]``  — index of the task writing ``p`` (0 for external packets).
* ``l_inf[p]``   — last task index that reads or writes ``p``
  (``n_t + 1`` for ``keep`` packets).
* ``last_touch_before(k, p)`` — the paper's ``l_k(p)``: the highest index
  ``< k`` of a task touching ``p``; 0 when no earlier task touches it. For an
  external packet this is 0, so it is loaded by the first burst that uses it.

Indices are 1-based throughout (task 1 .. n_t), matching the paper's notation;
index 0 is the virtual "before the application" state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Packet", "Task", "TaskGraph", "GraphBuilder", "GraphArrays",
           "GraphCSRArrays", "stack_graph_arrays", "stack_csr_arrays",
           "dense_export_nbytes"]


@dataclasses.dataclass(frozen=True)
class Packet:
    """A fixed-size unit of data exchanged between tasks.

    ``c0_weight`` scales the fixed (per-DMA-initiation) component of the
    transfer cost model. Sub-packets of a contiguous array that are always
    transferred as one coalesced DMA batch use ``c0_weight = 1/len(array)``
    to amortize the initiation cost (see DESIGN.md: coalescing note).
    """

    name: str
    nbytes: int
    c0_weight: float = 1.0
    keep: bool = False          # application output: must survive the last burst
    external: bool = False      # present in NVM before the application starts
    meta: Any = None            # optional payload (shape/dtype for the runtime)

    def __post_init__(self) -> None:
        if self.nbytes < 0:
            raise ValueError(f"packet {self.name!r}: negative size")


@dataclasses.dataclass(frozen=True)
class Task:
    """One atomic kernel invocation (paper: a *task*)."""

    name: str
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    cost: float                               # E_task (units: whatever the cost model uses)
    fn: Optional[Callable[..., Mapping[str, Any]]] = None  # runtime body (optional)

    def __post_init__(self) -> None:
        if self.cost < 0:
            raise ValueError(f"task {self.name!r}: negative cost")
        if len(set(self.writes)) != len(self.writes):
            raise ValueError(f"task {self.name!r}: duplicate writes")
        if len(set(self.reads)) != len(self.reads):
            raise ValueError(f"task {self.name!r}: duplicate reads")
        if set(self.reads) & set(self.writes):
            raise ValueError(
                f"task {self.name!r}: packet both read and written — model "
                "'inout' as a read of the old version plus a write of a new one (SSA)"
            )


@dataclasses.dataclass(frozen=True)
class GraphArrays:
    """Dense, padded, cost-model-independent export of a :class:`TaskGraph`.

    The burst recurrence (§4.2, see :mod:`.burst`) only ever inspects, per
    task ``j`` (1-based) and per packet it touches: the packet's transfer
    size, its DMA-initiation weight, its last touch strictly before ``j``
    (``l_j``), its writer, and its overall last use (``l_∞``). Those are
    exported here as rectangular arrays — one row per task, one column per
    read/write *slot* — so that graphs of different sizes pad to a common
    shape and batch together under ``jax.vmap`` (see
    :mod:`repro.core.partition_jax`).

    Shapes: ``e_task`` is ``(N,)``; read arrays are ``(N, R)``; write arrays
    are ``(N, W)`` where ``N ≥ n_tasks`` and R/W are ≥ the per-task maximum
    read/write counts. Padded slots have ``*_valid == 0`` and contribute
    exactly zero to every cost term (their bytes/weights are zeroed too).
    Cost-model scalars (E_s, c0/c1 per direction) are *not* baked in — the
    same export serves the FRAM, PCIe-offload, remat, and HBM-bytes models.

    Index conventions match the paper: tasks are 1-based, ``read_lt == 0``
    means "never touched before" (external / first use), ``read_writer == 0``
    means external, and ``l_∞ == n_tasks + 1`` marks kept outputs.
    """

    n_tasks: int
    e_task: np.ndarray       # (N,)   f64  task execution cost, 0-padded
    read_bytes: np.ndarray   # (N, R) f64  |p| per read slot
    read_c0w: np.ndarray     # (N, R) f64  c0_weight per read slot
    read_lt: np.ndarray      # (N, R) i32  l_j(p): last touch strictly before j
    read_writer: np.ndarray  # (N, R) i32  writer(p) (0 = external)
    read_linf: np.ndarray    # (N, R) i32  l_∞(p) of the read packet
    read_valid: np.ndarray   # (N, R) f64  1.0 for real slots, 0.0 padding
    write_bytes: np.ndarray  # (N, W) f64
    write_c0w: np.ndarray    # (N, W) f64
    write_linf: np.ndarray   # (N, W) i32  l_∞(p) of the written packet
    write_valid: np.ndarray  # (N, W) f64

    @property
    def n_pad(self) -> int:
        return int(self.e_task.shape[-1])

    @property
    def r_pad(self) -> int:
        return int(self.read_bytes.shape[-1])

    @property
    def w_pad(self) -> int:
        return int(self.write_bytes.shape[-1])

    def padded(self, n_pad: int, r_pad: int, w_pad: int) -> "GraphArrays":
        """Re-pad to a (larger) common shape, for cross-graph batching."""
        if n_pad < self.n_pad or r_pad < self.r_pad or w_pad < self.w_pad:
            raise ValueError(
                f"cannot shrink padding {(self.n_pad, self.r_pad, self.w_pad)} "
                f"to {(n_pad, r_pad, w_pad)}"
            )

        def pad(a: np.ndarray, *target: int) -> np.ndarray:
            widths = [(0, t - s) for t, s in zip(target, a.shape)]
            return np.pad(a, widths)

        return GraphArrays(
            n_tasks=self.n_tasks,
            e_task=pad(self.e_task, n_pad),
            read_bytes=pad(self.read_bytes, n_pad, r_pad),
            read_c0w=pad(self.read_c0w, n_pad, r_pad),
            read_lt=pad(self.read_lt, n_pad, r_pad),
            read_writer=pad(self.read_writer, n_pad, r_pad),
            read_linf=pad(self.read_linf, n_pad, r_pad),
            read_valid=pad(self.read_valid, n_pad, r_pad),
            write_bytes=pad(self.write_bytes, n_pad, w_pad),
            write_c0w=pad(self.write_c0w, n_pad, w_pad),
            write_linf=pad(self.write_linf, n_pad, w_pad),
            write_valid=pad(self.write_valid, n_pad, w_pad),
        )


def dense_export_nbytes(n_tasks: int, r_slots: int, w_slots: int) -> int:
    """Bytes :meth:`TaskGraph.to_arrays` would materialize, without building it.

    Used by the engine's ``backend="auto"`` policy and the benchmarks: on the
    full head-count graph the ``(N, R)`` rectangle alone is ~238 MB of float64
    (R ≈ 5452 because the sort task reads every score packet), which is why
    skewed-degree graphs route to the CSR export instead.
    """
    n, r, w = int(n_tasks), int(r_slots), int(w_slots)
    f64 = 8 * (n + 3 * n * r + 3 * n * w)  # e_task; read/write bytes,c0w,valid
    i32 = 4 * (3 * n * r + n * w)          # read lt,writer,linf; write linf
    return f64 + i32


@dataclasses.dataclass(frozen=True)
class GraphCSRArrays:
    """Compressed (CSR-style) slot export of a :class:`TaskGraph`.

    Same per-slot quantities as :class:`GraphArrays`, but the ``(N, R)`` /
    ``(N, W)`` rectangles are flattened task-major into flat slot arrays with
    row pointers: task ``j`` (1-based) owns read slots
    ``read_ptr[j-1]:read_ptr[j]`` and write slots
    ``write_ptr[j-1]:write_ptr[j]``, in declaration order. Export size is
    O(n_tasks + nnz) instead of O(n_tasks × max_degree) — the full 5458-task
    head-count graph (whose sort task reads 5452 score packets and would
    force a ~1 GB dense export) compresses to ~400 kB.

    This is the feed for the Pallas sweep kernel
    (:mod:`repro.kernels.partition_sweep`): the issue's ``slot_task_ptr`` /
    ``slot_cost`` / ``slot_lt`` / ``slot_writer`` / ``slot_linf`` operands are
    ``read_ptr`` plus the per-slot arrays below, with byte counts turned into
    costs at solve time (the export stays cost-model-independent, exactly
    like :class:`GraphArrays`).

    Padding is CSR-natural: extra ``e_task`` rows carry pointer ``nnz`` (no
    slots), and padded slot entries are never addressed by any pointer range,
    so padded graphs solve identically — that is what
    :func:`stack_csr_arrays` relies on.
    """

    n_tasks: int
    e_task: np.ndarray        # (N,)      f64  task execution cost, 0-padded
    read_ptr: np.ndarray      # (N+1,)    i32  row pointers into the read slots
    read_bytes: np.ndarray    # (nnz_r,)  f64  |p| per read slot
    read_c0w: np.ndarray      # (nnz_r,)  f64  c0_weight per read slot
    read_lt: np.ndarray       # (nnz_r,)  i32  l_j(p): last touch strictly before j
    read_writer: np.ndarray   # (nnz_r,)  i32  writer(p) (0 = external)
    read_linf: np.ndarray     # (nnz_r,)  i32  l_∞(p) of the read packet
    write_ptr: np.ndarray     # (N+1,)    i32  row pointers into the write slots
    write_bytes: np.ndarray   # (nnz_w,)  f64
    write_c0w: np.ndarray     # (nnz_w,)  f64
    write_linf: np.ndarray    # (nnz_w,)  i32

    @property
    def n_pad(self) -> int:
        return int(self.e_task.shape[-1])

    @property
    def nnz_reads(self) -> int:
        return int(self.read_bytes.shape[-1])

    @property
    def nnz_writes(self) -> int:
        return int(self.write_bytes.shape[-1])

    @property
    def nbytes(self) -> int:
        """Total bytes of the export (benchmarked against the dense path)."""
        return int(
            sum(
                getattr(self, f.name).nbytes
                for f in dataclasses.fields(self)
                if f.name != "n_tasks"
            )
        )

    def padded(self, n_pad: int, r_pad: int, w_pad: int) -> "GraphCSRArrays":
        """Re-pad to a (larger) common (N, nnz_r, nnz_w), for batching."""
        if n_pad < self.n_pad or r_pad < self.nnz_reads or w_pad < self.nnz_writes:
            raise ValueError(
                f"cannot shrink padding {(self.n_pad, self.nnz_reads, self.nnz_writes)} "
                f"to {(n_pad, r_pad, w_pad)}"
            )

        def pad_ptr(ptr: np.ndarray) -> np.ndarray:
            return np.pad(ptr, (0, n_pad - self.n_pad), mode="edge")

        def pad1(a: np.ndarray, target: int) -> np.ndarray:
            return np.pad(a, (0, target - a.shape[0]))

        return GraphCSRArrays(
            n_tasks=self.n_tasks,
            e_task=pad1(self.e_task, n_pad),
            read_ptr=pad_ptr(self.read_ptr),
            read_bytes=pad1(self.read_bytes, r_pad),
            read_c0w=pad1(self.read_c0w, r_pad),
            read_lt=pad1(self.read_lt, r_pad),
            read_writer=pad1(self.read_writer, r_pad),
            read_linf=pad1(self.read_linf, r_pad),
            write_ptr=pad_ptr(self.write_ptr),
            write_bytes=pad1(self.write_bytes, w_pad),
            write_c0w=pad1(self.write_c0w, w_pad),
            write_linf=pad1(self.write_linf, w_pad),
        )


def stack_csr_arrays(arrays: Sequence[GraphCSRArrays]) -> GraphCSRArrays:
    """Stack CSR exports of different graphs into one batch (leading axis B).

    All arrays re-pad to the largest (N, nnz_r, nnz_w) in the batch;
    ``n_tasks`` becomes a ``(B,)`` int array. Mirrors
    :func:`stack_graph_arrays` for the compressed layout — this is what
    :func:`repro.core.partition_jax.sweep_jax_batched` feeds the Pallas
    backend (one compiled kernel serves every graph in the batch).
    """
    if not arrays:
        raise ValueError("empty batch")
    n = max(a.n_pad for a in arrays)
    r = max(max(a.nnz_reads for a in arrays), 1)
    w = max(max(a.nnz_writes for a in arrays), 1)
    padded = [a.padded(n, r, w) for a in arrays]
    fields = {
        f.name: np.stack([getattr(a, f.name) for a in padded])
        for f in dataclasses.fields(GraphCSRArrays)
        if f.name != "n_tasks"
    }
    return GraphCSRArrays(
        n_tasks=np.array([a.n_tasks for a in arrays], dtype=np.int32),  # type: ignore[arg-type]
        **fields,
    )


def stack_graph_arrays(arrays: Sequence[GraphArrays]) -> GraphArrays:
    """Stack exports of different graphs into one batch (leading axis B).

    All arrays are re-padded to the largest (N, R, W) in the batch;
    ``n_tasks`` becomes an ``(B,)`` int array. The result is what
    :func:`repro.core.partition_jax.sweep_jax_batched` vmaps over.
    """
    if not arrays:
        raise ValueError("empty batch")
    n = max(a.n_pad for a in arrays)
    r = max(a.r_pad for a in arrays)
    w = max(a.w_pad for a in arrays)
    padded = [a.padded(n, r, w) for a in arrays]
    fields = {
        f.name: np.stack([getattr(a, f.name) for a in padded])
        for f in dataclasses.fields(GraphArrays)
        if f.name != "n_tasks"
    }
    return GraphArrays(
        n_tasks=np.array([a.n_tasks for a in arrays], dtype=np.int32),  # type: ignore[arg-type]
        **fields,
    )


class TaskGraph:
    """A validated sequential application with explicit data dependencies."""

    def __init__(self, tasks: Sequence[Task], packets: Iterable[Packet]):
        self.tasks: List[Task] = list(tasks)
        self.packets: Dict[str, Packet] = {}
        for p in packets:
            if p.name in self.packets:
                raise ValueError(f"duplicate packet {p.name!r}")
            self.packets[p.name] = p
        self._validate()
        self._analyze()

    # -- construction helpers -------------------------------------------------

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def task(self, index: int) -> Task:
        """1-based task accessor (paper notation)."""
        return self.tasks[index - 1]

    def _validate(self) -> None:
        writer: Dict[str, int] = {}
        for p in self.packets.values():
            if p.external:
                writer[p.name] = 0
        for idx, t in enumerate(self.tasks, start=1):
            for name in t.reads:
                if name not in self.packets:
                    raise ValueError(f"task {t.name!r} reads unknown packet {name!r}")
                if name not in writer:
                    raise ValueError(
                        f"task {t.name!r} (index {idx}) reads packet {name!r} "
                        "before it is written"
                    )
            for name in t.writes:
                if name not in self.packets:
                    raise ValueError(f"task {t.name!r} writes unknown packet {name!r}")
                if name in writer:
                    raise ValueError(
                        f"packet {name!r} written twice (SSA violation): "
                        f"by task {writer[name]} and task {idx}"
                    )
                writer[name] = idx
        for p in self.packets.values():
            if p.name not in writer:
                raise ValueError(f"packet {p.name!r} is never written and not external")
        self._writer = writer

    def _analyze(self) -> None:
        n = self.n_tasks
        # l_inf: last task touching each packet; keep-packets get n+1.
        l_inf: Dict[str, int] = {name: self._writer[name] for name in self.packets}
        for idx, t in enumerate(self.tasks, start=1):
            for name in t.reads:
                l_inf[name] = max(l_inf[name], idx)
        for p in self.packets.values():
            if p.keep:
                l_inf[p.name] = n + 1
        self.l_inf = l_inf

        # Per (task, read packet): the paper's l_k(p) — last touch strictly
        # before k. 0 when untouched before (external / first use).
        last_touch: Dict[str, int] = {
            name: (0 if self.packets[name].external else None)  # type: ignore[dict-item]
            for name in self.packets
        }
        self.read_last_touch: List[Tuple[int, ...]] = []  # aligned with tasks (0-based list)
        for idx, t in enumerate(self.tasks, start=1):
            row = []
            for name in t.reads:
                lt = last_touch[name]
                assert lt is not None  # _validate guarantees written-before-read
                row.append(lt)
            self.read_last_touch.append(tuple(row))
            for name in t.reads:
                last_touch[name] = idx
            for name in t.writes:
                last_touch[name] = idx

    # -- derived quantities ----------------------------------------------------

    def writer(self, packet: str) -> int:
        """Index of the task writing ``packet`` (0 = external)."""
        return self._writer[packet]

    def total_task_cost(self) -> float:
        """E_app: the cost of executing all tasks with no partitioning overhead."""
        return float(sum(t.cost for t in self.tasks))

    def total_packet_bytes(self) -> int:
        """Static size of all application data (used by the naive baseline)."""
        return int(sum(p.nbytes for p in self.packets.values()))

    def live_packets(self, boundary: int) -> List[str]:
        """Packets that are live across the boundary after task ``boundary``.

        A packet is live at boundary ``b`` (between tasks b and b+1) iff it was
        written at or before ``b`` and is used after ``b``.
        """
        out = []
        for name, p in self.packets.items():
            w = self._writer[name]
            if w <= boundary and self.l_inf[name] > boundary:
                out.append(name)
        return out

    def subgraph(self, lo: int, hi: int) -> "TaskGraph":
        """Tasks lo..hi (1-based inclusive) as a standalone graph.

        Packets produced before ``lo`` and read inside become external; packets
        produced inside and used after ``hi`` become ``keep``.
        """
        names = set()
        for k in range(lo, hi + 1):
            t = self.task(k)
            names.update(t.reads)
            names.update(t.writes)
        pkts = []
        for name in names:
            p = self.packets[name]
            w = self._writer[name]
            pkts.append(
                dataclasses.replace(
                    p,
                    external=(w < lo),
                    keep=(self.l_inf[name] > hi and w >= lo),
                )
            )
        return TaskGraph(self.tasks[lo - 1 : hi], pkts)

    def to_arrays(
        self,
        n_pad: Optional[int] = None,
        r_pad: Optional[int] = None,
        w_pad: Optional[int] = None,
    ) -> GraphArrays:
        """Export the §4.2 analysis products as dense padded arrays.

        ``n_pad`` / ``r_pad`` / ``w_pad`` override the natural task / read-slot
        / write-slot counts (must be ≥ them) so that different graphs share a
        shape and batch under ``vmap``. See :class:`GraphArrays` for the
        exact per-field semantics.
        """
        if n_pad is None and r_pad is None and w_pad is None:
            cached = getattr(self, "_arrays_cache", None)
            if cached is not None:
                return cached
        n = self.n_tasks
        nat_r = max((len(t.reads) for t in self.tasks), default=0)
        nat_w = max((len(t.writes) for t in self.tasks), default=0)
        N = n if n_pad is None else int(n_pad)
        R = max(nat_r if r_pad is None else int(r_pad), 1)
        W = max(nat_w if w_pad is None else int(w_pad), 1)
        if N < n or R < nat_r or W < nat_w:
            raise ValueError(
                f"padding ({N},{R},{W}) smaller than natural ({n},{nat_r},{nat_w})"
            )

        e_task = np.zeros(N, dtype=np.float64)
        rb = np.zeros((N, R), dtype=np.float64)
        rc0 = np.zeros((N, R), dtype=np.float64)
        rlt = np.zeros((N, R), dtype=np.int32)
        rwr = np.zeros((N, R), dtype=np.int32)
        rli = np.zeros((N, R), dtype=np.int32)
        rv = np.zeros((N, R), dtype=np.float64)
        wb = np.zeros((N, W), dtype=np.float64)
        wc0 = np.zeros((N, W), dtype=np.float64)
        wli = np.zeros((N, W), dtype=np.int32)
        wv = np.zeros((N, W), dtype=np.float64)

        for idx, t in enumerate(self.tasks):
            e_task[idx] = t.cost
            for r, (name, lt) in enumerate(zip(t.reads, self.read_last_touch[idx])):
                p = self.packets[name]
                rb[idx, r] = p.nbytes
                rc0[idx, r] = p.c0_weight
                rlt[idx, r] = lt
                rwr[idx, r] = self._writer[name]
                rli[idx, r] = self.l_inf[name]
                rv[idx, r] = 1.0
            for w, name in enumerate(t.writes):
                p = self.packets[name]
                wb[idx, w] = p.nbytes
                wc0[idx, w] = p.c0_weight
                wli[idx, w] = self.l_inf[name]
                wv[idx, w] = 1.0

        out = GraphArrays(
            n_tasks=n,
            e_task=e_task,
            read_bytes=rb, read_c0w=rc0, read_lt=rlt,
            read_writer=rwr, read_linf=rli, read_valid=rv,
            write_bytes=wb, write_c0w=wc0, write_linf=wli, write_valid=wv,
        )
        if n_pad is None and r_pad is None and w_pad is None:
            self._arrays_cache = out  # graphs are immutable once built
        return out

    def to_csr_arrays(
        self,
        n_pad: Optional[int] = None,
        r_pad: Optional[int] = None,
        w_pad: Optional[int] = None,
    ) -> GraphCSRArrays:
        """Export the §4.2 analysis products in the compressed slot layout.

        Semantics match :meth:`to_arrays` slot-for-slot (same per-task
        ordering, so the two exports are mutually reconstructible); only the
        container changes from padded rectangles to flat arrays + row
        pointers. ``n_pad``/``r_pad``/``w_pad`` grow the task count and the
        read/write slot pools for cross-graph batching (must be ≥ natural).
        """
        if n_pad is None and r_pad is None and w_pad is None:
            cached = getattr(self, "_csr_cache", None)
            if cached is not None:
                return cached
        n = self.n_tasks
        r_ptr = [0]
        rb: List[float] = []
        rc0: List[float] = []
        rlt: List[int] = []
        rwr: List[int] = []
        rli: List[int] = []
        w_ptr = [0]
        wb: List[float] = []
        wc0: List[float] = []
        wli: List[int] = []
        for idx, t in enumerate(self.tasks):
            for name, lt in zip(t.reads, self.read_last_touch[idx]):
                p = self.packets[name]
                rb.append(p.nbytes)
                rc0.append(p.c0_weight)
                rlt.append(lt)
                rwr.append(self._writer[name])
                rli.append(self.l_inf[name])
            r_ptr.append(len(rb))
            for name in t.writes:
                p = self.packets[name]
                wb.append(p.nbytes)
                wc0.append(p.c0_weight)
                wli.append(self.l_inf[name])
            w_ptr.append(len(wb))

        out = GraphCSRArrays(
            n_tasks=n,
            e_task=np.array([t.cost for t in self.tasks], dtype=np.float64),
            read_ptr=np.array(r_ptr, dtype=np.int32),
            read_bytes=np.array(rb, dtype=np.float64),
            read_c0w=np.array(rc0, dtype=np.float64),
            read_lt=np.array(rlt, dtype=np.int32),
            read_writer=np.array(rwr, dtype=np.int32),
            read_linf=np.array(rli, dtype=np.int32),
            write_ptr=np.array(w_ptr, dtype=np.int32),
            write_bytes=np.array(wb, dtype=np.float64),
            write_c0w=np.array(wc0, dtype=np.float64),
            write_linf=np.array(wli, dtype=np.int32),
        )
        if n_pad is not None or r_pad is not None or w_pad is not None:
            out = out.padded(
                n if n_pad is None else int(n_pad),
                max(len(rb) if r_pad is None else int(r_pad), 1),
                max(len(wb) if w_pad is None else int(w_pad), 1),
            )
        else:
            self._csr_cache = out  # graphs are immutable once built
        return out

    def __repr__(self) -> str:  # pragma: no cover
        return f"TaskGraph(n_tasks={self.n_tasks}, n_packets={len(self.packets)})"


class GraphBuilder:
    """Incremental builder mirroring a Ladybirds metakernel.

    >>> b = GraphBuilder()
    >>> b.packet("img", 9600)
    >>> b.task("sense", reads=(), writes=("img",), cost=0.1319)
    >>> g = b.build()
    """

    def __init__(self) -> None:
        self._packets: List[Packet] = []
        self._tasks: List[Task] = []

    def packet(self, name: str, nbytes: int, **kw: Any) -> str:
        self._packets.append(Packet(name, nbytes, **kw))
        return name

    def packet_array(self, name: str, count: int, nbytes_each: int, **kw: Any) -> List[str]:
        """A contiguous array of ``count`` sub-packets with amortized DMA init."""
        w = 1.0 / count
        return [
            self.packet(f"{name}[{i}]", nbytes_each, c0_weight=w, **kw)
            for i in range(count)
        ]

    def task(
        self,
        name: str,
        reads: Sequence[str] = (),
        writes: Sequence[str] = (),
        cost: float = 0.0,
        fn: Optional[Callable[..., Mapping[str, Any]]] = None,
    ) -> None:
        self._tasks.append(Task(name, tuple(reads), tuple(writes), float(cost), fn))

    def build(self) -> TaskGraph:
        return TaskGraph(self._tasks, self._packets)
