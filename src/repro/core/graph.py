"""Ladybirds-style task-graph specification model (paper §3).

An application is a *sequence* of tasks. Each task reads and writes a set of
named :class:`Packet`\\ s with statically known sizes. Packets obey SSA: each
packet is written by exactly one task (or is an *external* input, conceptually
written by a virtual task 0). Packets marked ``keep=True`` are application
outputs, conceptually read by a virtual task ``n_t + 1`` — they must survive
the final burst.

The analysis products mirror the paper's §4.2 definitions:

* ``writer[p]``  — index of the task writing ``p`` (0 for external packets).
* ``l_inf[p]``   — last task index that reads or writes ``p``
  (``n_t + 1`` for ``keep`` packets).
* ``last_touch_before(k, p)`` — the paper's ``l_k(p)``: the highest index
  ``< k`` of a task touching ``p``; 0 when no earlier task touches it. For an
  external packet this is 0, so it is loaded by the first burst that uses it.

Indices are 1-based throughout (task 1 .. n_t), matching the paper's notation;
index 0 is the virtual "before the application" state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Packet", "Task", "TaskGraph", "GraphBuilder"]


@dataclasses.dataclass(frozen=True)
class Packet:
    """A fixed-size unit of data exchanged between tasks.

    ``c0_weight`` scales the fixed (per-DMA-initiation) component of the
    transfer cost model. Sub-packets of a contiguous array that are always
    transferred as one coalesced DMA batch use ``c0_weight = 1/len(array)``
    to amortize the initiation cost (see DESIGN.md: coalescing note).
    """

    name: str
    nbytes: int
    c0_weight: float = 1.0
    keep: bool = False          # application output: must survive the last burst
    external: bool = False      # present in NVM before the application starts
    meta: Any = None            # optional payload (shape/dtype for the runtime)

    def __post_init__(self) -> None:
        if self.nbytes < 0:
            raise ValueError(f"packet {self.name!r}: negative size")


@dataclasses.dataclass(frozen=True)
class Task:
    """One atomic kernel invocation (paper: a *task*)."""

    name: str
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    cost: float                               # E_task (units: whatever the cost model uses)
    fn: Optional[Callable[..., Mapping[str, Any]]] = None  # runtime body (optional)

    def __post_init__(self) -> None:
        if self.cost < 0:
            raise ValueError(f"task {self.name!r}: negative cost")
        if len(set(self.writes)) != len(self.writes):
            raise ValueError(f"task {self.name!r}: duplicate writes")
        if len(set(self.reads)) != len(self.reads):
            raise ValueError(f"task {self.name!r}: duplicate reads")
        if set(self.reads) & set(self.writes):
            raise ValueError(
                f"task {self.name!r}: packet both read and written — model "
                "'inout' as a read of the old version plus a write of a new one (SSA)"
            )


class TaskGraph:
    """A validated sequential application with explicit data dependencies."""

    def __init__(self, tasks: Sequence[Task], packets: Iterable[Packet]):
        self.tasks: List[Task] = list(tasks)
        self.packets: Dict[str, Packet] = {}
        for p in packets:
            if p.name in self.packets:
                raise ValueError(f"duplicate packet {p.name!r}")
            self.packets[p.name] = p
        self._validate()
        self._analyze()

    # -- construction helpers -------------------------------------------------

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def task(self, index: int) -> Task:
        """1-based task accessor (paper notation)."""
        return self.tasks[index - 1]

    def _validate(self) -> None:
        writer: Dict[str, int] = {}
        for p in self.packets.values():
            if p.external:
                writer[p.name] = 0
        for idx, t in enumerate(self.tasks, start=1):
            for name in t.reads:
                if name not in self.packets:
                    raise ValueError(f"task {t.name!r} reads unknown packet {name!r}")
                if name not in writer:
                    raise ValueError(
                        f"task {t.name!r} (index {idx}) reads packet {name!r} "
                        "before it is written"
                    )
            for name in t.writes:
                if name not in self.packets:
                    raise ValueError(f"task {t.name!r} writes unknown packet {name!r}")
                if name in writer:
                    raise ValueError(
                        f"packet {name!r} written twice (SSA violation): "
                        f"by task {writer[name]} and task {idx}"
                    )
                writer[name] = idx
        for p in self.packets.values():
            if p.name not in writer:
                raise ValueError(f"packet {p.name!r} is never written and not external")
        self._writer = writer

    def _analyze(self) -> None:
        n = self.n_tasks
        # l_inf: last task touching each packet; keep-packets get n+1.
        l_inf: Dict[str, int] = {name: self._writer[name] for name in self.packets}
        for idx, t in enumerate(self.tasks, start=1):
            for name in t.reads:
                l_inf[name] = max(l_inf[name], idx)
        for p in self.packets.values():
            if p.keep:
                l_inf[p.name] = n + 1
        self.l_inf = l_inf

        # Per (task, read packet): the paper's l_k(p) — last touch strictly
        # before k. 0 when untouched before (external / first use).
        last_touch: Dict[str, int] = {
            name: (0 if self.packets[name].external else None)  # type: ignore[dict-item]
            for name in self.packets
        }
        self.read_last_touch: List[Tuple[int, ...]] = []  # aligned with tasks (0-based list)
        for idx, t in enumerate(self.tasks, start=1):
            row = []
            for name in t.reads:
                lt = last_touch[name]
                assert lt is not None  # _validate guarantees written-before-read
                row.append(lt)
            self.read_last_touch.append(tuple(row))
            for name in t.reads:
                last_touch[name] = idx
            for name in t.writes:
                last_touch[name] = idx

    # -- derived quantities ----------------------------------------------------

    def writer(self, packet: str) -> int:
        """Index of the task writing ``packet`` (0 = external)."""
        return self._writer[packet]

    def total_task_cost(self) -> float:
        """E_app: the cost of executing all tasks with no partitioning overhead."""
        return float(sum(t.cost for t in self.tasks))

    def total_packet_bytes(self) -> int:
        """Static size of all application data (used by the naive baseline)."""
        return int(sum(p.nbytes for p in self.packets.values()))

    def live_packets(self, boundary: int) -> List[str]:
        """Packets that are live across the boundary after task ``boundary``.

        A packet is live at boundary ``b`` (between tasks b and b+1) iff it was
        written at or before ``b`` and is used after ``b``.
        """
        out = []
        for name, p in self.packets.items():
            w = self._writer[name]
            if w <= boundary and self.l_inf[name] > boundary:
                out.append(name)
        return out

    def subgraph(self, lo: int, hi: int) -> "TaskGraph":
        """Tasks lo..hi (1-based inclusive) as a standalone graph.

        Packets produced before ``lo`` and read inside become external; packets
        produced inside and used after ``hi`` become ``keep``.
        """
        names = set()
        for k in range(lo, hi + 1):
            t = self.task(k)
            names.update(t.reads)
            names.update(t.writes)
        pkts = []
        for name in names:
            p = self.packets[name]
            w = self._writer[name]
            pkts.append(
                dataclasses.replace(
                    p,
                    external=(w < lo),
                    keep=(self.l_inf[name] > hi and w >= lo),
                )
            )
        return TaskGraph(self.tasks[lo - 1 : hi], pkts)

    def __repr__(self) -> str:  # pragma: no cover
        return f"TaskGraph(n_tasks={self.n_tasks}, n_packets={len(self.packets)})"


class GraphBuilder:
    """Incremental builder mirroring a Ladybirds metakernel.

    >>> b = GraphBuilder()
    >>> b.packet("img", 9600)
    >>> b.task("sense", reads=(), writes=("img",), cost=0.1319)
    >>> g = b.build()
    """

    def __init__(self) -> None:
        self._packets: List[Packet] = []
        self._tasks: List[Task] = []

    def packet(self, name: str, nbytes: int, **kw: Any) -> str:
        self._packets.append(Packet(name, nbytes, **kw))
        return name

    def packet_array(self, name: str, count: int, nbytes_each: int, **kw: Any) -> List[str]:
        """A contiguous array of ``count`` sub-packets with amortized DMA init."""
        w = 1.0 / count
        return [
            self.packet(f"{name}[{i}]", nbytes_each, c0_weight=w, **kw)
            for i in range(count)
        ]

    def task(
        self,
        name: str,
        reads: Sequence[str] = (),
        writes: Sequence[str] = (),
        cost: float = 0.0,
        fn: Optional[Callable[..., Mapping[str, Any]]] = None,
    ) -> None:
        self._tasks.append(Task(name, tuple(reads), tuple(writes), float(cost), fn))

    def build(self) -> TaskGraph:
        return TaskGraph(self._tasks, self._packets)
