"""One Julienning façade: declarative :class:`PartitionSpec` → :class:`Engine`.

The paper's contribution is a *specification model*: an application is
declared once (atomic kernels + explicit data dependencies) and a single
optimization flow produces energy-bounded cycles. This module is that model
for the solver layer. Instead of ~10 entry points with divergent signatures
(``optimal_partition``, ``sweep_jax_batched``, ``sweep_jax_sharded``, …),
callers build one immutable :class:`PartitionSpec` —

* **what** to partition: a :class:`~repro.core.graph.TaskGraph` (or a
  dense/CSR export of one), a batch of graphs, or a model-zoo config plus
  (batch, seq) shapes to lower;
* **what to optimize**: ``objective="sum"`` (the paper's E_total DP over a
  Q_max grid), ``"minimax"`` (§4.4 storage minimization — Q_min), or
  ``"exact_k"`` (the fixed-burst-count pipeline DP);
* **how** to solve it: ``backend="numpy" | "scan" | "pallas" | "auto"`` and
  an optional :class:`QGridSharding` spreading the Q grid over a device mesh

— and :meth:`Engine.solve` resolves it through a backend *registry*. Backends
self-register via :func:`register_backend` with capability flags
(``supports_sharding``, ``supports_csr``, ``supports_dense``, the supported
objective set), which replace the old hand-rolled ``_select_backend``
if-chain: ``backend="auto"`` picks the highest-priority registered backend
whose capabilities match the export kind (and dense-export size) of each
graph, and mismatches raise *typed* errors — :class:`ExportMismatch` for a
layout the backend cannot consume, :class:`UnsupportedObjective` for an
objective it does not implement — identically from every backend.

Results come back as a :class:`Solution` whose accessors reproduce each
legacy entry point **bit-identically** (pinned per legacy function by
tests/test_api.py): the same private implementations run underneath, the
façade only routes. The legacy entry points themselves survive as thin
:class:`DeprecationWarning` shims.

Most callers go through :mod:`repro.api`, which re-exports everything here
plus the module-level :func:`solve` convenience.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..obs.trace import PID_SOLVER, TRACER
from .cost import CostModel
from .graph import (
    GraphArrays,
    GraphCSRArrays,
    TaskGraph,
    dense_export_nbytes,
)
from .partition import Infeasible, Partition
from .placement import PlacementSpec

__all__ = [
    "EngineError",
    "SpecError",
    "UnsupportedObjective",
    "ExportMismatch",
    "BackendInfo",
    "register_backend",
    "backend_names",
    "backend_info",
    "resolve_jit_backend",
    "export_kind",
    "QGridSharding",
    "PartitionSpec",
    "Solution",
    "Engine",
    "default_engine",
    "OBJECTIVES",
]

AnyExport = Union[TaskGraph, GraphArrays, GraphCSRArrays]

OBJECTIVES = ("sum", "minimax", "exact_k")


# ---------------------------------------------------------------------------
# Typed errors
# ---------------------------------------------------------------------------


class EngineError(ValueError):
    """Base class for façade errors (spec validation, dispatch, capability)."""


class SpecError(EngineError):
    """Malformed or self-contradictory :class:`PartitionSpec`."""


class UnsupportedObjective(EngineError):
    """The selected backend does not implement the requested objective.

    Every built-in backend now implements all of :data:`OBJECTIVES` (the
    §4.4 combines are Pallas kernel modes), so in the default registry this
    only fires for externally registered backends with restricted
    ``objectives`` capability flags — the error-path suite pins the message
    against exactly such a fake backend.
    """


class ExportMismatch(EngineError, TypeError):
    """A graph export the selected backend cannot consume.

    Subclasses :class:`TypeError` for compatibility with the pre-façade
    behavior of ``_as_arrays`` / ``_as_csr``, which raised bare TypeErrors;
    the registry's capability check now produces this one typed error for
    every backend instead of backend-specific failures.
    """


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """Registry entry: a backend class plus its capability flags.

    ``objectives`` is the set of :data:`OBJECTIVES` the backend implements;
    ``supports_dense`` / ``supports_csr`` declare which *export* layouts it
    consumes (every backend accepts a :class:`TaskGraph` and converts it
    itself); ``supports_sharding`` gates :class:`QGridSharding`;
    ``supports_placement`` gates the multi-node placement axis
    (``placement=PlacementSpec(...)``); ``auto_eligible`` marks jit backends
    that ``backend="auto"`` may pick (the numpy reference path is
    explicit-only).
    """

    name: str
    factory: Any
    objectives: frozenset
    supports_sharding: bool = False
    supports_csr: bool = False
    supports_dense: bool = True
    supports_placement: bool = False
    auto_eligible: bool = True


_REGISTRY: Dict[str, BackendInfo] = {}


def register_backend(
    name: str,
    *,
    objectives: Sequence[str] = ("sum",),
    supports_sharding: bool = False,
    supports_csr: bool = False,
    supports_dense: bool = True,
    supports_placement: bool = False,
    auto_eligible: bool = True,
    registry: Optional[Dict[str, BackendInfo]] = None,
):
    """Class decorator: self-register a backend under ``name``.

    ``registry`` defaults to the process-global one; tests pass their own
    dict to exercise registration without touching global dispatch.
    """
    bad = set(objectives) - set(OBJECTIVES)
    if bad:
        raise SpecError(f"unknown objectives {sorted(bad)}; known: {OBJECTIVES}")

    def deco(cls):
        (_REGISTRY if registry is None else registry)[name] = BackendInfo(
            name=name,
            factory=cls,
            objectives=frozenset(objectives),
            supports_sharding=supports_sharding,
            supports_csr=supports_csr,
            supports_dense=supports_dense,
            supports_placement=supports_placement,
            auto_eligible=auto_eligible,
        )
        return cls

    return deco


def backend_names(registry: Optional[Dict[str, BackendInfo]] = None) -> List[str]:
    return sorted(_REGISTRY if registry is None else registry)


def backend_info(
    name: str, registry: Optional[Dict[str, BackendInfo]] = None
) -> BackendInfo:
    reg = _REGISTRY if registry is None else registry
    try:
        return reg[name]
    except KeyError:
        raise SpecError(
            f"unknown backend {name!r}; registered: {sorted(reg)}"
        ) from None


def export_kind(graph: AnyExport) -> str:
    """Classify a solver input: ``"graph"`` / ``"dense"`` / ``"csr"``."""
    if isinstance(graph, TaskGraph):
        return "graph"
    if isinstance(graph, GraphArrays):
        return "dense"
    if isinstance(graph, GraphCSRArrays):
        return "csr"
    raise ExportMismatch(
        f"cannot solve a {type(graph).__name__}: expected a TaskGraph or a "
        f"GraphArrays / GraphCSRArrays export"
    )


def _check_export(
    info: BackendInfo,
    graph: AnyExport,
    registry: Optional[Dict[str, BackendInfo]] = None,
) -> None:
    """The registry capability check guarding every dispatch.

    A :class:`TaskGraph` is accepted by every backend (each converts it to
    its own layout, or — the numpy reference DP — walks it directly); the
    pre-exported array layouts must match the backend's capability flags.
    """
    reg = _REGISTRY if registry is None else registry
    kind = export_kind(graph)
    if kind == "dense" and not info.supports_dense:
        raise ExportMismatch(
            f"backend {info.name!r} does not consume dense GraphArrays "
            f"exports; pass the TaskGraph or pick a backend with "
            f"supports_dense (registered: "
            f"{[b.name for b in reg.values() if b.supports_dense]})"
        )
    if kind == "csr" and not info.supports_csr:
        raise ExportMismatch(
            f"backend {info.name!r} does not consume GraphCSRArrays exports; "
            f"pass the TaskGraph or pick a backend with supports_csr "
            f"(registered: "
            f"{[b.name for b in reg.values() if b.supports_csr]})"
        )


def resolve_jit_backend(
    graph: AnyExport,
    backend: str = "auto",
    objective: str = "sum",
    registry: Optional[Dict[str, BackendInfo]] = None,
) -> str:
    """Resolve ``backend="auto"`` for one graph via the registry flags.

    This replaces the hand-rolled if-chain that used to live in
    ``partition_jax._select_backend`` (which now delegates here): among the
    ``auto_eligible`` backends implementing ``objective``, a CSR export picks
    a ``supports_csr`` backend, a dense export a ``supports_dense`` one, and
    a raw :class:`TaskGraph` routes by dense-export size — above
    ``partition_jax._AUTO_DENSE_BYTES`` (read at call time so tests can
    monkeypatch it) the compressed-layout backend wins. Explicit names pass
    through after a registry existence check.
    """
    reg = _REGISTRY if registry is None else registry
    jit = [b for b in reg.values() if b.auto_eligible]
    if backend != "auto":
        if backend not in reg:
            raise SpecError(
                f"unknown backend {backend!r}; registered: {sorted(reg)}"
            )
        if backend not in [b.name for b in jit]:
            # registered, just not a jit-dispatch target — saying "unknown"
            # here sent users hunting for typos that weren't there
            raise SpecError(
                f"backend {backend!r} is registered but not jit-dispatchable "
                f"(auto_eligible=False); registered: {sorted(reg)}; "
                f"jit-dispatchable: {sorted(b.name for b in jit)}"
            )
        return backend
    cands = [b for b in jit if objective in b.objectives]
    if not cands:
        raise UnsupportedObjective(
            f"no registered auto-eligible backend implements objective "
            f"{objective!r} (registered: {sorted(b.name for b in jit)})"
        )
    dense_c = [b for b in cands if b.supports_dense]
    csr_c = [b for b in cands if b.supports_csr]
    kind = export_kind(graph)
    if kind == "csr":
        pool = csr_c
    elif kind == "dense":
        pool = dense_c
    else:
        from . import partition_jax as pj  # lazy: jax-heavy

        n = graph.n_tasks
        r = max((len(t.reads) for t in graph.tasks), default=0)
        w = max((len(t.writes) for t in graph.tasks), default=0)
        big = dense_export_nbytes(n, r, w) > pj._AUTO_DENSE_BYTES
        pool = (csr_c or dense_c) if big else (dense_c or csr_c)
    if not pool:
        # some backend implements the objective, just not for this layout —
        # that is an export problem, not an objective problem
        raise ExportMismatch(
            f"no backend implementing objective {objective!r} consumes a "
            f"{kind!r} export ({sorted(b.name for b in cands)} take "
            f"{'dense' if dense_c else 'csr'} or the TaskGraph itself); "
            f"pass the TaskGraph or re-export in the matching layout"
        )
    return pool[0].name


# ---------------------------------------------------------------------------
# The declarative spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class QGridSharding:
    """Shard the Q_max grid across ``n_shards`` device chunks.

    Mirrors the legacy ``sweep_jax_sharded`` / ``shard_plan_table``
    parameters: ``devices`` defaults to ``jax.local_devices()`` at solve
    time; with fewer devices than shards the same chunk decomposition runs
    sequentially (bit-identical either way). Only ``objective="sum"`` has a
    Q grid to shard; a spec combining sharding with ``minimax``/``exact_k``
    is rejected at construction (:class:`SpecError`).
    """

    n_shards: int
    devices: Optional[Tuple[Any, ...]] = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise SpecError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.devices is not None and not isinstance(self.devices, tuple):
            object.__setattr__(self, "devices", tuple(self.devices))

    # note: only objective="sum" has a Q grid to shard — PartitionSpec
    # rejects sharding for minimax/exact_k uniformly (SpecError), rather
    # than having backends silently ignore it


class _Unset:
    """Sentinel distinguishing 'q_max not given' from 'q_max=None=unbounded'."""

    def __repr__(self):  # pragma: no cover - repr only
        return "<unset>"


_UNSET = _Unset()


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionSpec:
    """Immutable, declarative description of one partitioning problem.

    Exactly one input source::

        PartitionSpec(graph=g, ...)                  # one graph / export
        PartitionSpec(graphs=(g1, g2), ...)          # a batch (one solve)
        PartitionSpec(config="qwen3-4b", shapes=((2, 24), (2, 48)),
                      kind="time", smoke=True, ...)  # model-zoo lowering

    and at most one Q axis: ``q_grid`` (a tuple of Q_max values, ``None`` =
    unbounded) or the single-point ``q_max`` convenience. ``objective`` picks
    the DP: ``"sum"`` minimizes E_total over the grid (the paper's DP),
    ``"minimax"`` computes Q_min (§4.4; no Q axis), ``"exact_k"`` solves the
    fixed-burst-count DP for ``n_bursts`` (``k_objective`` chooses the
    combine: ``"sum"`` for E_total, ``"max"`` for the pipeline bottleneck).

    ``cost`` is required for explicit graphs; config-lowered specs default it
    per ``kind`` exactly like the plan-table builders. ``cost`` also accepts
    a :class:`repro.core.calibration.MeasuredCostTable`, in which case
    ``confidence`` (a level in (0, 1)) prices every cut at measured
    mean + z·sigma; ``confidence=None`` prices at the plain mean, which is
    bit-identical to the analytical model when the measurements match it.
    ``backend`` names a registered backend or ``"auto"``; ``sharding``
    spreads the Q grid over a device mesh; ``interpret`` is forwarded to the
    Pallas kernel.

    ``placement`` adds the multi-node axis (ROADMAP "multi-device
    placement"): a :class:`repro.core.placement.PlacementSpec` describing a
    relay chain of harvesting nodes plus the link-bandwidth / memory / Q
    sweep grids. Placement solves carry their own budget axes, so
    ``q_grid=`` / ``q_max=`` / ``sharding=`` are rejected alongside it, the
    objective must stay ``"sum"`` (the placement DP minimizes swarm
    E_total), and inputs must be :class:`TaskGraph` objects (the per-node
    column sweeps walk the graph structure).
    """

    graph: Optional[AnyExport] = None
    graphs: Optional[Tuple[AnyExport, ...]] = None
    config: Optional[Any] = None          # ModelConfig or registry arch name
    shapes: Tuple[Tuple[int, int], ...] = ((1, 128),)
    kind: str = "time"
    smoke: bool = False
    cost: Optional[CostModel] = None
    q_grid: Optional[Tuple[Optional[float], ...]] = None
    q_max: Any = _UNSET
    objective: str = "sum"
    n_bursts: Optional[int] = None
    k_objective: str = "sum"
    backend: str = "auto"
    sharding: Optional[QGridSharding] = None
    interpret: Optional[bool] = None
    confidence: Optional[float] = None
    placement: Optional[PlacementSpec] = None

    def __post_init__(self):
        sources = [
            s for s, v in (
                ("graph", self.graph),
                ("graphs", self.graphs),
                ("config", self.config),
            ) if v is not None
        ]
        if len(sources) != 1:
            raise SpecError(
                f"exactly one of graph= / graphs= / config= must be given "
                f"(got {sources or 'none'})"
            )
        if self.graphs is not None:
            object.__setattr__(self, "graphs", tuple(self.graphs))
            if not self.graphs:
                raise SpecError("graphs= is empty")
        object.__setattr__(
            self, "shapes", tuple((int(b), int(s)) for (b, s) in self.shapes)
        )
        if self.config is not None and not self.shapes:
            raise SpecError("config= specs need at least one (batch, seq) shape")
        if self.q_grid is not None:
            object.__setattr__(self, "q_grid", tuple(self.q_grid))
            if not self.q_grid:
                raise SpecError("q_grid= is empty")
        if self.objective not in OBJECTIVES:
            raise SpecError(
                f"unknown objective {self.objective!r}; one of {OBJECTIVES}"
            )
        if self.q_grid is not None and self.q_max is not _UNSET:
            raise SpecError("give q_grid= or q_max=, not both")
        if self.objective == "minimax":
            if self.q_grid is not None or self.q_max is not _UNSET:
                raise SpecError(
                    "objective='minimax' computes Q_min and has no Q axis; "
                    "drop q_grid=/q_max="
                )
        if self.objective == "exact_k":
            if self.n_bursts is None or int(self.n_bursts) < 1:
                raise SpecError(
                    "objective='exact_k' needs n_bursts >= 1"
                )
            if self.q_grid is not None:
                raise SpecError(
                    "objective='exact_k' takes a single q_max, not a q_grid"
                )
        elif self.n_bursts is not None:
            raise SpecError("n_bursts= only applies to objective='exact_k'")
        if self.k_objective not in ("sum", "max"):
            raise SpecError(
                f"k_objective must be 'sum' or 'max', got {self.k_objective!r}"
            )
        if self.sharding is not None:
            if not isinstance(self.sharding, QGridSharding):
                raise SpecError(
                    f"sharding= must be a QGridSharding, got "
                    f"{type(self.sharding).__name__}"
                )
            if self.objective != "sum":
                raise SpecError(
                    f"sharding shards the Q grid, which only "
                    f"objective='sum' has; objective={self.objective!r} "
                    f"solves per graph — drop sharding="
                )
        if not isinstance(self.backend, str):
            raise SpecError(f"backend= must be a name, got {self.backend!r}")
        if self.cost is not None and not (
            isinstance(self.cost, CostModel) or hasattr(self.cost, "cost_model")
        ):
            raise SpecError(
                f"cost= must be a CostModel or a calibrated "
                f"MeasuredCostTable (anything with .cost_model(confidence)), "
                f"got {type(self.cost).__name__}"
            )
        if self.placement is not None:
            if not isinstance(self.placement, PlacementSpec):
                raise SpecError(
                    f"placement= must be a PlacementSpec, got "
                    f"{type(self.placement).__name__}"
                )
            if self.objective != "sum":
                raise SpecError(
                    f"placement= solves the multi-node E_total DP, which "
                    f"rides objective='sum'; objective="
                    f"{self.objective!r} has no placement form"
                )
            if self.q_grid is not None or self.q_max is not _UNSET:
                raise SpecError(
                    "placement= sweeps per-node budgets via "
                    "PlacementSpec.q_scales (each node's q_max × the scale "
                    "grid); drop q_grid=/q_max="
                )
            if self.sharding is not None:
                raise SpecError(
                    "placement= has no Q grid to shard (its grid axes are "
                    "links × memory_scales × q_scales); drop sharding="
                )
        if self.confidence is not None:
            try:
                c = float(self.confidence)
            except (TypeError, ValueError):
                raise SpecError(
                    f"confidence= must be a float in (0, 1), got "
                    f"{self.confidence!r}"
                ) from None
            if not 0.0 < c < 1.0 or c != c:
                raise SpecError(
                    f"confidence= must lie strictly in (0, 1), got "
                    f"{self.confidence!r}"
                )
            object.__setattr__(self, "confidence", c)

    # -- normalized views ---------------------------------------------------

    @property
    def batched(self) -> bool:
        """True when the spec describes a batch (graphs= or config=)."""
        return self.graph is None

    @property
    def q_values(self) -> Tuple[Optional[float], ...]:
        """The normalized Q axis: ``()`` for minimax, one entry per grid
        point otherwise (a lone ``None`` = unbounded when nothing was given).
        """
        if self.objective == "minimax":
            return ()
        if self.q_grid is not None:
            return self.q_grid
        return (None if self.q_max is _UNSET else self.q_max,)


# ---------------------------------------------------------------------------
# Solutions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Solution:
    """What :meth:`Engine.solve` returns: one payload per objective, with
    accessors reproducing the legacy entry points bit-for-bit.

    ``backend`` is the *resolved* backend name (``"scan+pallas"`` for a
    mixed ``auto`` batch); ``graphs`` / ``cost`` / ``q_values`` are the
    resolved inputs (config-lowered graphs included), so downstream pricing
    needs nothing but the solution object.
    """

    spec: PartitionSpec
    backend: str
    graphs: Tuple[AnyExport, ...]
    cost: CostModel
    q_values: Tuple[Optional[float], ...]
    sweeps: Optional[Tuple[Any, ...]] = None      # JaxSweep per graph (sum, jit)
    parts: Optional[Tuple[Tuple[Optional[Partition], ...], ...]] = None
    qmins: Optional[Tuple[float, ...]] = None     # minimax
    placements: Optional[Tuple[Any, ...]] = None  # PlacementSweep per graph

    @property
    def n_graphs(self) -> int:
        return len(self.graphs)

    def _one(self, what: Optional[tuple], label: str):
        if what is None:
            raise EngineError(
                f"this solution (objective={self.spec.objective!r}, "
                f"backend={self.backend!r}) carries no {label}"
            )
        return what

    @property
    def sweep(self):
        """The single :class:`~repro.core.partition_jax.JaxSweep` (one-graph
        specs on a jit backend) — the ``sweep_jax`` return value."""
        sweeps = self._one(self.sweeps, "JaxSweep results")
        if len(sweeps) != 1:
            raise EngineError(
                f"sweep is for single-graph specs; this one has "
                f"{len(sweeps)} — index .sweeps instead"
            )
        return sweeps[0]

    def partitions(self, graph_index: int = 0) -> List[Optional[Partition]]:
        """Per-Q :class:`Partition` objects for one graph (None where
        infeasible) — the ``optimal_partition_multi`` / ``sweep`` shape."""
        if self.spec.objective == "minimax":
            raise EngineError(
                "objective='minimax' yields Q_min values; use .q_min()"
            )
        if self.parts is not None:
            return list(self.parts[graph_index])
        g = self.graphs[graph_index]
        if not isinstance(g, TaskGraph):
            raise EngineError(
                "materializing Partition objects needs the TaskGraph; this "
                "spec was built from a pre-exported array layout — call "
                ".sweeps[i].to_partitions(graph, cost) with the source graph"
            )
        return self._one(self.sweeps, "sweeps")[graph_index].to_partitions(
            g, self.cost
        )

    def partition(self, graph_index: int = 0, q_index: int = 0) -> Partition:
        """One feasible :class:`Partition` — the ``optimal_partition`` /
        ``optimal_partition_jax`` / ``optimal_partition_k`` shape. Raises
        :class:`~repro.core.partition.Infeasible` identically across
        backends when that (graph, Q) cell has no partition."""
        p = self.partitions(graph_index)[q_index]
        if p is None:
            raise Infeasible(
                f"Q_max={self.q_values[q_index]} admits no partition"
            )
        return p

    def placement_sweep(self, graph_index: int = 0):
        """The solved :class:`~repro.core.placement.PlacementSweep` for one
        graph (specs with ``placement=``): the full links × memory × Q grid
        plus the raw DP tables the bit-identity gates compare."""
        return self._one(self.placements, "placement sweeps")[graph_index]

    def placement_plan(
        self,
        graph_index: int = 0,
        link_index: int = 0,
        memory_index: int = 0,
        q_index: int = 0,
    ):
        """One grid cell materialized as a
        :class:`~repro.core.placement.PlacementPlan` (spans, per-node burst
        schedules, hop costs); raises
        :class:`~repro.core.placement.PlacementError` where infeasible."""
        return self.placement_sweep(graph_index).plan(
            link_index, memory_index, q_index
        )

    def q_min(self, graph_index: int = 0) -> float:
        """The §4.4 storage minimum for one graph (objective='minimax')."""
        return self._one(self.qmins, "Q_min values")[graph_index]

    @property
    def q_mins(self) -> Tuple[float, ...]:
        return self._one(self.qmins, "Q_min values")

    def e_total(self, graph_index: int = 0) -> np.ndarray:
        """Optimal E_total per Q grid point (inf where infeasible)."""
        if self.sweeps is not None:
            return np.asarray(self.sweeps[graph_index].e_total)
        parts = self.partitions(graph_index)
        return np.array(
            [np.inf if p is None else p.e_total for p in parts]
        )

    def summary(self) -> str:
        return (
            f"Solution[{self.spec.objective}/{self.backend}] "
            f"{self.n_graphs} graph(s) × {max(len(self.q_values), 1)} Q"
        )


# ---------------------------------------------------------------------------
# Backends (self-registering)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class _SolveRequest:
    """Engine-resolved inputs handed to a backend's ``solve``."""

    graphs: Tuple[AnyExport, ...]
    cost: CostModel
    q_values: Tuple[Optional[float], ...]
    objective: str
    n_bursts: Optional[int]
    k_objective: str
    sharding: Optional[QGridSharding]
    interpret: Optional[bool]
    batched: bool
    backend: str                 # concrete name, or "auto" for a mixed batch
    placement: Optional[PlacementSpec] = None


@register_backend(
    "numpy",
    objectives=("sum", "minimax", "exact_k"),
    supports_sharding=False,
    supports_csr=False,
    supports_dense=False,        # the reference DP walks the TaskGraph itself
    supports_placement=True,
    auto_eligible=False,
)
class NumpyBackend:
    """The numpy reference DP (paper §4.3–§4.4) — the bit-exactness oracle.

    Consumes :class:`TaskGraph` objects only (the incremental column sweep
    needs the graph structure); explicit array exports raise
    :class:`ExportMismatch`. Every result is exactly what the legacy
    ``optimal_partition*`` / ``sweep`` / ``q_min`` functions returned.
    """

    name = "numpy"

    def solve(self, req: _SolveRequest) -> dict:
        from .partition import _optimal_k, _optimal_multi, q_min

        if req.placement is not None:
            from .placement import solve_placement_numpy

            return {
                "placements": tuple(
                    solve_placement_numpy(g, req.cost, req.placement)
                    for g in req.graphs
                )
            }
        if req.objective == "sum":
            return {
                "parts": tuple(
                    tuple(
                        _optimal_multi(
                            g, req.cost, list(req.q_values), raise_single=False
                        )
                    )
                    for g in req.graphs
                )
            }
        if req.objective == "minimax":
            return {
                "qmins": tuple(float(q_min(g, req.cost)) for g in req.graphs)
            }
        return {
            "parts": tuple(
                (
                    _optimal_k(
                        g,
                        req.cost,
                        req.n_bursts,
                        req.q_values[0],
                        objective=req.k_objective,
                    ),
                )
                for g in req.graphs
            )
        }


class _JitBackend:
    """Shared dispatch for the jit engines (scan / pallas / mixed-auto):
    the concrete backend string is threaded into the partition_jax
    implementations, which own upload caching and compilation."""

    name = "jit"

    def solve(self, req: _SolveRequest) -> dict:
        from . import partition_jax as pj

        if req.placement is not None:
            from .placement_jax import solve_placement_scan

            return {
                "placements": tuple(
                    solve_placement_scan(g, req.cost, req.placement)
                    for g in req.graphs
                )
            }
        if req.objective == "sum":
            qs = list(req.q_values)
            if req.sharding is not None:
                devices = req.sharding.devices
                sweeps = pj._sweep_jax_sharded(
                    list(req.graphs),
                    req.cost,
                    qs,
                    n_shards=req.sharding.n_shards,
                    devices=None if devices is None else list(devices),
                    backend=req.backend,
                    interpret=req.interpret,
                )
            elif req.batched:
                sweeps = pj._sweep_jax_batched(
                    list(req.graphs), req.cost, qs,
                    backend=req.backend, interpret=req.interpret,
                )
            else:
                sweeps = [
                    pj._sweep_jax(
                        req.graphs[0], req.cost, qs,
                        backend=req.backend, interpret=req.interpret,
                    )
                ]
            return {"sweeps": tuple(sweeps)}
        if req.objective == "minimax":
            return {
                "qmins": tuple(
                    pj._q_min_jit(
                        g, req.cost,
                        backend=req.backend, interpret=req.interpret,
                    )
                    for g in req.graphs
                )
            }
        return {
            "parts": tuple(
                (
                    pj._optimal_k_jit(
                        g,
                        req.cost,
                        req.n_bursts,
                        req.q_values[0],
                        objective=req.k_objective,
                        backend=req.backend,
                        interpret=req.interpret,
                    ),
                )
                for g in req.graphs
            )
        }


@register_backend(
    "scan",
    objectives=("sum", "minimax", "exact_k"),
    supports_sharding=True,
    supports_csr=False,
    supports_dense=True,
    supports_placement=True,     # the one-jit grid solver in placement_jax
)
class ScanBackend(_JitBackend):
    """The jitted ``lax.scan`` engine over dense :class:`GraphArrays`
    exports — Q-grid-heavy DSE on bounded-degree graphs, plus the scan
    re-expressions of the minimax and exact-K DPs (same columns, different
    combine — bit-identical to the numpy oracles on unroll-width graphs)."""

    name = "scan"


@register_backend(
    "pallas",
    objectives=("sum", "minimax", "exact_k"),
    supports_sharding=True,      # host-chunked Q sharding (see partition_jax)
    supports_csr=True,
    supports_dense=False,
)
class PallasBackend(_JitBackend):
    """The fused CSR column-sweep/DP kernel
    (:mod:`repro.kernels.partition_sweep`) over compressed
    :class:`GraphCSRArrays` exports — required for skewed-degree graphs
    (the 5458-task head count is ~1 GB dense, ~500 kB CSR). All three
    objectives are static kernel modes (the §4.4 minimax and exact-K
    combines ride the same slot-chunked column scan), each bit-identical
    to its numpy oracle in interpret mode."""

    name = "pallas"


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class Engine:
    """Resolve a :class:`PartitionSpec` and dispatch it to one backend.

    Stateless apart from its registry reference; the module-level
    :func:`default_engine` instance is what :func:`repro.api.solve` uses.
    """

    def __init__(self, registry: Optional[Dict[str, BackendInfo]] = None):
        self._registry = _REGISTRY if registry is None else registry

    # -- resolution ---------------------------------------------------------

    @staticmethod
    def _price_cost(spec: PartitionSpec, cost) -> CostModel:
        """Materialize the spec's priced CostModel.

        A calibrated source (anything with ``.cost_model(confidence)``, i.e.
        a :class:`repro.core.calibration.MeasuredCostTable` — duck-typed to
        keep the import lazy) is priced at ``spec.confidence``: each cut
        costs measured mean + z·sigma. A plain CostModel passes through —
        and combining it with ``confidence=`` is a typed error, because a
        datasheet model has no variance to price and the flag would
        silently do nothing.
        """
        if not isinstance(cost, CostModel) and hasattr(cost, "cost_model"):
            return cost.cost_model(spec.confidence)
        if spec.confidence is not None:
            raise SpecError(
                f"confidence= prices measured uncertainty and needs cost= "
                f"to be a MeasuredCostTable (repro.core.calibration); a "
                f"plain CostModel ({getattr(cost, 'name', cost)!r}) has no "
                f"variance to price"
            )
        return cost

    def _resolve_graphs(
        self, spec: PartitionSpec
    ) -> Tuple[Tuple[AnyExport, ...], CostModel]:
        if spec.config is not None:
            from ..configs import resolve_config
            from .calibration import measured_default
            from .layer_profile import default_cost_model, lower_config

            cfg = resolve_config(spec.config, smoke=spec.smoke)
            graphs = tuple(
                lower_config(cfg, batch=b, seq=s, kind=spec.kind)
                for (b, s) in spec.shapes
            )
            cost = spec.cost
            if cost is None:
                # an installed calibration is the default measured source, so
                # confidence= works on config-lowered specs without passing
                # the table explicitly
                cost = measured_default(spec.kind) or default_cost_model(spec.kind)
            return graphs, self._price_cost(spec, cost)
        if spec.cost is None:
            raise SpecError(
                "cost= is required for explicit graph specs (config-lowered "
                "specs default it per kind)"
            )
        graphs = (spec.graph,) if spec.graph is not None else spec.graphs
        for g in graphs:
            export_kind(g)  # typed error for non-graph inputs
        return graphs, self._price_cost(spec, spec.cost)

    def resolve_backend(
        self, spec: PartitionSpec, graphs: Sequence[AnyExport]
    ) -> Tuple[str, List[str]]:
        """(label, per-graph concrete names). ``label`` is the Solution's
        resolved-backend string — a concrete name, or ``"a+b"`` for a mixed
        ``auto`` batch (dispatched group-wise like the legacy batched
        entry point). Any explicitly named *registered* backend — including
        ones registered by downstream code — passes through directly."""
        if spec.backend != "auto":
            info = backend_info(spec.backend, self._registry)
            return info.name, [info.name] * len(graphs)
        if spec.placement is not None:
            # auto for placement: the first auto-eligible backend declaring
            # supports_placement (the scan grid solver in the default
            # registry) — the layout-based routing below is about per-graph
            # exports, which placement solves don't take
            cands = [
                b.name
                for b in self._registry.values()
                if b.auto_eligible and b.supports_placement
            ]
            if not cands:
                raise SpecError(
                    "no registered auto-eligible backend supports placement "
                    "solves; pass backend='numpy' or register one with "
                    "supports_placement"
                )
            return cands[0], [cands[0]] * len(graphs)
        per_graph = [
            resolve_jit_backend(g, "auto", spec.objective, self._registry)
            for g in graphs
        ]
        names = sorted(set(per_graph))
        return "+".join(names), per_graph

    # -- solve --------------------------------------------------------------

    def solve(self, spec: PartitionSpec) -> Solution:
        """The one entry point: validate, resolve, capability-check,
        dispatch, wrap. See the module docstring for the dispatch rules."""
        if not isinstance(spec, PartitionSpec):
            raise SpecError(
                f"Engine.solve takes a PartitionSpec, got "
                f"{type(spec).__name__}"
            )
        graphs, cost = self._resolve_graphs(spec)
        label, per_graph = self.resolve_backend(spec, graphs)

        infos = [backend_info(n, self._registry) for n in set(per_graph)]
        for info in infos:
            if spec.objective not in info.objectives:
                raise UnsupportedObjective(
                    f"backend {info.name!r} does not implement objective "
                    f"{spec.objective!r} (supported: "
                    f"{sorted(info.objectives)}); backends implementing it: "
                    f"{sorted(b.name for b in self._registry.values() if spec.objective in b.objectives)}"
                )
            if spec.sharding is not None and not info.supports_sharding:
                raise SpecError(
                    f"backend {info.name!r} does not support Q-grid "
                    f"sharding; use a backend registered with "
                    f"supports_sharding"
                )
            if spec.placement is not None and not info.supports_placement:
                raise SpecError(
                    f"backend {info.name!r} does not implement placement "
                    f"solves; backends with supports_placement: "
                    f"{sorted(b.name for b in self._registry.values() if b.supports_placement)}"
                )
        if spec.placement is not None:
            # backend-independent: the per-node column sweeps walk the graph
            # structure, so placement consumes TaskGraphs only
            for g in graphs:
                if not isinstance(g, TaskGraph):
                    raise ExportMismatch(
                        "placement= needs the TaskGraph (the per-node "
                        "column sweeps walk its structure); pass the graph "
                        "rather than a pre-exported layout"
                    )
        if spec.objective == "exact_k":
            # backend-independent: reconstructed bursts are priced on the
            # graph, so exact_k consumes TaskGraphs only — reject here, not
            # deep inside a backend after a full solve
            for g in graphs:
                if not isinstance(g, TaskGraph):
                    raise ExportMismatch(
                        "objective='exact_k' needs the TaskGraph to price "
                        "the reconstructed bursts; pass the graph rather "
                        "than a pre-exported layout"
                    )
        for g, name in zip(graphs, per_graph):
            _check_export(backend_info(name, self._registry), g,
                          self._registry)

        req = _SolveRequest(
            graphs=graphs,
            cost=cost,
            q_values=spec.q_values,
            objective=spec.objective,
            n_bursts=spec.n_bursts,
            k_objective=spec.k_objective,
            sharding=spec.sharding,
            interpret=spec.interpret,
            batched=spec.batched,
            backend="auto" if "+" in label else per_graph[0],
            placement=spec.placement,
        )
        with TRACER.span(
            "engine.solve",
            cat="engine",
            pid=PID_SOLVER,
            objective=spec.objective,
            backend=label,
            graphs=len(graphs),
            q_points=len(spec.q_values),
        ):
            with TRACER.span("engine.dispatch", cat="engine", pid=PID_SOLVER, backend=label):
                if "+" in label:
                    # mixed auto batch: the jit dispatcher groups per backend,
                    # exactly like the legacy batched entry point did
                    payload = _JitBackend().solve(req)
                else:
                    payload = backend_info(label, self._registry).factory().solve(req)
        return Solution(
            spec=spec,
            backend=label,
            graphs=graphs,
            cost=cost,
            q_values=spec.q_values,
            **payload,
        )


_DEFAULT_ENGINE = Engine()


def default_engine() -> Engine:
    """The process-wide engine over the global backend registry."""
    return _DEFAULT_ENGINE
