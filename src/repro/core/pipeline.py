"""Pipeline-stage partitioning via Julienning (DESIGN.md §2, item 3).

A K-stage pipeline assignment of a layer stack is exactly the paper's
problem: tasks = layers, packets = boundary activations, burst = stage,
E_r = the ICI hop moving the boundary activation to the next stage's
device, and the *minimax* objective (§4.4) with a fixed burst count K
minimizes the bottleneck stage — the quantity that sets pipeline
throughput. Dependency-awareness buys real wins on heterogeneous stacks:
cutting zamba2 after a Mamba block moves only the [B,S,d] activation,
while a cut that strands the shared-attention block's embedding input
re-sends it every microbatch.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from ..configs.base import ModelConfig
from .cost import tpu_pipeline_model
from .engine import PartitionSpec, default_engine
from .layer_profile import build_activation_graph, profile_model
from .partition import Partition

__all__ = ["PipelinePlan", "plan_pipeline"]


@dataclasses.dataclass
class PipelinePlan:
    cfg_name: str
    n_stages: int
    bounds: List[Tuple[int, int]]        # layer index ranges per stage (1-based)
    stage_seconds: List[float]           # compute+comm per stage
    stage_weight_bytes: List[int]
    comm_bytes: List[int]                # bytes entering each stage
    bottleneck_seconds: float
    total_seconds: float

    @property
    def balance(self) -> float:
        """bottleneck / mean — 1.0 is a perfectly balanced pipeline."""
        mean = self.total_seconds / max(self.n_stages, 1)
        return self.bottleneck_seconds / mean if mean else 1.0

    def summary(self) -> str:
        return (f"{self.cfg_name}: {self.n_stages} stages, bottleneck "
                f"{self.bottleneck_seconds * 1e3:.3f} ms, balance "
                f"{self.balance:.3f}, max stage weights "
                f"{max(self.stage_weight_bytes) / 1e9:.2f} GB")


def plan_pipeline(cfg: ModelConfig, batch: int, seq: int, n_stages: int,
                  objective: str = "max") -> PipelinePlan:
    profiles, long_lived = profile_model(cfg, batch, seq)
    graph = build_activation_graph(profiles, long_lived, kind="time")
    cm = tpu_pipeline_model()
    part: Partition = default_engine().solve(PartitionSpec(
        graph=graph, cost=cm, objective="exact_k", n_bursts=n_stages,
        k_objective=objective, backend="numpy",
    )).partition()
    stage_w = [
        sum(p.weight_bytes for p in profiles[i - 1 : j]) for (i, j) in part.bounds
    ]
    return PipelinePlan(
        cfg_name=cfg.name,
        n_stages=n_stages,
        bounds=part.bounds,
        stage_seconds=[b.total for b in part.bursts],
        stage_weight_bytes=stage_w,
        comm_bytes=[b.read_bytes for b in part.bursts],
        bottleneck_seconds=part.max_burst,
        total_seconds=part.e_total,
    )
