"""Identity-keyed weak cache for immutable numpy-holding exports.

The export dataclasses (:class:`~repro.core.graph.GraphArrays`,
:class:`~repro.core.graph.GraphCSRArrays`) hold numpy fields, so they are
unhashable — but they are immutable and created once per graph, so object
identity is a sound cache key as long as id() reuse after garbage collection
is guarded against. This helper centralizes that idiom (key by
``id(obj) + extras``, liveness-check the stored weakref, evict on
collection) for the engine's device-upload and padding caches.
"""

from __future__ import annotations

import weakref
from typing import Callable, Dict, Tuple, TypeVar

T = TypeVar("T")

__all__ = ["weak_id_cache"]


def weak_id_cache(
    store: Dict, obj: object, extra: Tuple, compute: Callable[[], T]
) -> T:
    """Return ``compute()`` memoized per live ``(obj, *extra)``.

    ``store`` maps ``(id(obj), *extra) -> (weakref(obj), value)``; the entry
    is dropped when ``obj`` is collected, and a stale id-reuse hit is
    detected by the ``is`` liveness check.
    """
    key = (id(obj), *extra)
    hit = store.get(key)
    if hit is not None and hit[0]() is obj:
        return hit[1]
    value = compute()

    def _evict(_ref, key=key):
        store.pop(key, None)

    store[key] = (weakref.ref(obj, _evict), value)
    return value
