"""Precomputed Q-grid segment-plan tables (the serving-path integration).

The paper's core claim is that energy-bounded execution cycles are planned
*ahead of time* and replayed cheaply at runtime (0.12% measured overhead).
This module is that split for the TPU serving path: an **offline** builder
solves the whole (shape-bucket × Q_max) design space in one batched engine
call (:func:`repro.core.partition_jax.sweep_jax_batched`), and the **online**
side (:mod:`repro.launch.planner` / :mod:`repro.launch.serve`) answers every
request with an O(1) table lookup — no DP solve, no retrace, no re-upload on
the request path.

Table contents, per (bucket b, Q index k):

* the reconstructed segment bounds (the julienne cut points — these double as
  offload boundaries, remat boundaries, and pipeline cuts for the planners in
  :mod:`repro.launch.planner`),
* the per-cycle energy of every segment (what one system activation must
  deliver), and
* ``e_total`` / ``feasible`` for the whole request shape.

Serialization is a single ``.npz`` whose ``header`` entry is a JSON document
carrying the format version, the architecture, the cost-model scalars, and a
config fingerprint; :func:`PlanTable.load` refuses stale versions
(:class:`StaleTableError`) and :func:`build_plan_table` keys its on-disk cache
by the fingerprint, so a table built for one (config, buckets, Q grid, cost
model) can never silently serve another.

Bit-exactness contract (tested in tests/test_plan_table.py): a table lookup
returns bounds bit-identical to a direct :func:`optimal_partition_jax` solve
of the same (graph, cost, Q) — the batched build pads graphs to a common
shape, but padded slots contribute exact zeros and the per-Q DP rows are
independent, so tabulated and direct plans agree bound-for-bound.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..configs.base import ModelConfig
from .burst import burst_cost
from .cost import CostModel, cost_scalars, tpu_host_offload_model
from .graph import TaskGraph
from .layer_profile import lower_config, memory_cost_model
from .partition import BUDGET_ABS, BUDGET_REL, Infeasible

__all__ = [
    "PLAN_TABLE_VERSION",
    "PlanTableError",
    "StaleTableError",
    "UnknownBucketError",
    "SegmentPlan",
    "PlanTable",
    "build_plan_table",
    "config_fingerprint",
    "BUILD_STATS",
]

PLAN_TABLE_VERSION = 1

# Offline-build observability (tests assert the fingerprint cache short-
# circuits the solve): bumped by build_plan_table only.
BUILD_STATS = {"built": 0, "cache_hits": 0}


class PlanTableError(ValueError):
    """Malformed, mismatched, or misused plan table."""


class StaleTableError(PlanTableError):
    """On-disk table was written by an incompatible format version."""


class UnknownBucketError(PlanTableError, KeyError):
    """Request shape maps to no tabulated (batch, seq) bucket."""


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One looked-up plan: the energy-bounded cycles for a request shape.

    ``bounds`` are 1-based inclusive task ranges over the lowered activation
    graph (the julienne cut points); ``cycle_energy[c]`` is the modeled energy
    of cycle ``c`` (E_s + loads + execution + stores — what one system
    activation must deliver); ``e_total`` is the whole request.
    """

    arch: str
    batch: int
    seq_bucket: int
    q_max: Optional[float]
    n_tasks: int
    bounds: Tuple[Tuple[int, int], ...]
    cycle_energy: Tuple[float, ...]
    e_total: float

    @property
    def n_cycles(self) -> int:
        return len(self.bounds)

    @property
    def max_cycle_energy(self) -> float:
        return max(self.cycle_energy, default=0.0)

    @property
    def cut_points(self) -> Tuple[int, ...]:
        """Interior segment ends — the pipeline/offload/remat cut points."""
        return tuple(j for (_, j) in self.bounds[:-1])

    def summary(self) -> str:
        q = "inf" if self.q_max is None else f"{self.q_max:.6g}"
        return (
            f"{self.arch} b{self.batch}/s{self.seq_bucket}: "
            f"{self.n_cycles} cycles @ Q≤{q}, "
            f"max cycle {self.max_cycle_energy:.6g}, "
            f"E_total {self.e_total:.6g}"
        )


def _q_list(q_values: Sequence[Optional[float]]) -> List[Optional[float]]:
    out: List[Optional[float]] = []
    for q in q_values:
        if q is None or (isinstance(q, float) and np.isinf(q)):
            out.append(None)
        else:
            out.append(float(q))
    return out


def config_fingerprint(
    cfg: ModelConfig,
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    kind: str,
    cost: CostModel,
) -> str:
    """Content hash keying the build cache and pinning table identity.

    Covers everything the solved plans depend on: the full ModelConfig, the
    bucket list, the Q grid (exact float reprs), the cost interpretation
    (``kind``) and the cost-model scalars, plus the table format version.
    """
    payload = {
        "version": PLAN_TABLE_VERSION,
        "cfg": dataclasses.asdict(cfg),
        "buckets": [[int(b), int(s)] for (b, s) in shape_buckets],
        "q_grid": [None if q is None else q.hex() for q in _q_list(q_values)],
        "kind": kind,
        "cost": {"name": cost.name, "scalars": [c.hex() for c in cost_scalars(cost)]},
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class PlanTable:
    """Immutable (bucket × Q) grid of precomputed segment plans.

    Construct via :func:`build_plan_table` or :meth:`load`; query via
    :meth:`lookup`. Storage is flat-ragged: entry ``(b, k)`` owns segment rows
    ``seg_ptr[b*nq+k] : seg_ptr[b*nq+k+1]`` of ``seg_start``/``seg_end``/
    ``cycle_energy`` (the CSR idiom the engine already uses for graphs).
    """

    def __init__(
        self,
        header: Dict,
        bucket_batch: np.ndarray,
        bucket_seq: np.ndarray,
        n_tasks: np.ndarray,
        q_grid: np.ndarray,
        feasible: np.ndarray,
        e_total: np.ndarray,
        seg_ptr: np.ndarray,
        seg_start: np.ndarray,
        seg_end: np.ndarray,
        cycle_energy: np.ndarray,
    ) -> None:
        self.header = dict(header)
        self.bucket_batch = np.asarray(bucket_batch, dtype=np.int64)
        self.bucket_seq = np.asarray(bucket_seq, dtype=np.int64)
        self.n_tasks = np.asarray(n_tasks, dtype=np.int64)
        self.q_grid = np.asarray(q_grid, dtype=np.float64)
        self.feasible = np.asarray(feasible, dtype=bool)
        self.e_total = np.asarray(e_total, dtype=np.float64)
        self.seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
        self.seg_start = np.asarray(seg_start, dtype=np.int32)
        self.seg_end = np.asarray(seg_end, dtype=np.int32)
        self.cycle_energy = np.asarray(cycle_energy, dtype=np.float64)
        nb, nq = self.feasible.shape
        if self.seg_ptr.shape[0] != nb * nq + 1:
            raise PlanTableError(
                f"seg_ptr length {self.seg_ptr.shape[0]} != {nb}*{nq}+1"
            )

    # -- identity ----------------------------------------------------------

    @property
    def arch(self) -> str:
        return self.header["arch"]

    @property
    def kind(self) -> str:
        return self.header["kind"]

    @property
    def fingerprint(self) -> str:
        return self.header["fingerprint"]

    @property
    def e_startup(self) -> float:
        """E_s of the cost model the table was priced under."""
        return float(self.header["cost_scalars"][0])

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_batch.shape[0])

    @property
    def n_q(self) -> int:
        return int(self.q_grid.shape[0])

    def buckets(self) -> List[Tuple[int, int]]:
        return [
            (int(b), int(s)) for b, s in zip(self.bucket_batch, self.bucket_seq)
        ]

    def q_values(self) -> List[Optional[float]]:
        return [None if np.isinf(q) else float(q) for q in self.q_grid]

    # -- lookup ------------------------------------------------------------

    def bucket_index(self, batch: int, seq: int) -> int:
        """Smallest tabulated seq-bucket covering ``seq`` at exactly ``batch``."""
        ok = (self.bucket_batch == int(batch)) & (self.bucket_seq >= int(seq))
        idx = np.flatnonzero(ok)
        if idx.size == 0:
            raise UnknownBucketError(
                f"no bucket covers (batch={batch}, seq={seq}); "
                f"tabulated: {self.buckets()}"
            )
        return int(idx[np.argmin(self.bucket_seq[idx])])

    def q_index(self, energy_budget: Optional[float]) -> int:
        """Largest tabulated Q_max that fits under ``energy_budget``.

        Any plan solved for Q' ≤ budget is feasible for the budget (every
        cycle ≤ Q' ≤ budget), and e_total is non-increasing in Q, so the
        largest fitting grid point is the best tabulated plan. ``None`` means
        unbounded and selects the largest grid entry.
        """
        if energy_budget is None:
            return int(np.argmax(self.q_grid))
        # vectorized within_budget(q, budget) over the grid (request path)
        cap = float(energy_budget) * (1 + BUDGET_REL) + BUDGET_ABS
        fits = np.flatnonzero(self.q_grid <= cap)
        if fits.size == 0:
            raise Infeasible(
                f"energy budget {energy_budget} is below the smallest "
                f"tabulated Q_max {self.q_grid.min():.6g}"
            )
        return int(fits[np.argmax(self.q_grid[fits])])

    def plan_at(self, b: int, k: int) -> SegmentPlan:
        """The stored plan for bucket index ``b`` at Q index ``k``."""
        if not self.feasible[b, k]:
            q = self.q_grid[k]
            raise Infeasible(
                f"bucket {self.buckets()[b]} infeasible at Q_max={q:.6g}"
            )
        e = b * self.n_q + k
        lo, hi = int(self.seg_ptr[e]), int(self.seg_ptr[e + 1])
        q = self.q_grid[k]
        return SegmentPlan(
            arch=self.arch,
            batch=int(self.bucket_batch[b]),
            seq_bucket=int(self.bucket_seq[b]),
            q_max=None if np.isinf(q) else float(q),
            n_tasks=int(self.n_tasks[b]),
            bounds=tuple(
                (int(i), int(j))
                for i, j in zip(self.seg_start[lo:hi], self.seg_end[lo:hi])
            ),
            cycle_energy=tuple(float(c) for c in self.cycle_energy[lo:hi]),
            e_total=float(self.e_total[b, k]),
        )

    def lookup(
        self, batch: int, seq: int, energy_budget: Optional[float] = None
    ) -> SegmentPlan:
        """O(1) request-path query: bucket the shape, pick the Q, return the
        precomputed plan. Raises :class:`UnknownBucketError` for untabulated
        shapes and :class:`Infeasible` for budgets below the grid."""
        return self.plan_at(
            self.bucket_index(batch, seq), self.q_index(energy_budget)
        )

    # -- serialization -----------------------------------------------------

    def save(self, path: str) -> str:
        """Write the table as one ``.npz`` with an embedded JSON header
        (atomic: write-to-temp + rename, same protocol as DirNVM)."""
        directory = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
        try:
            with os.fdopen(fd, "wb") as fh:
                np.savez(
                    fh,
                    header=np.array(json.dumps(self.header, sort_keys=True)),
                    bucket_batch=self.bucket_batch,
                    bucket_seq=self.bucket_seq,
                    n_tasks=self.n_tasks,
                    q_grid=self.q_grid,
                    feasible=self.feasible,
                    e_total=self.e_total,
                    seg_ptr=self.seg_ptr,
                    seg_start=self.seg_start,
                    seg_end=self.seg_end,
                    cycle_energy=self.cycle_energy,
                )
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    @classmethod
    def load(cls, path: str) -> "PlanTable":
        with np.load(path, allow_pickle=False) as z:
            try:
                header = json.loads(str(z["header"]))
            except (KeyError, json.JSONDecodeError) as e:
                raise PlanTableError(f"{path}: missing/corrupt header") from e
            version = header.get("version")
            if version != PLAN_TABLE_VERSION:
                raise StaleTableError(
                    f"{path}: table version {version} != supported "
                    f"{PLAN_TABLE_VERSION}; rebuild with build_plan_table()"
                )
            return cls(
                header=header,
                bucket_batch=z["bucket_batch"],
                bucket_seq=z["bucket_seq"],
                n_tasks=z["n_tasks"],
                q_grid=z["q_grid"],
                feasible=z["feasible"],
                e_total=z["e_total"],
                seg_ptr=z["seg_ptr"],
                seg_start=z["seg_start"],
                seg_end=z["seg_end"],
                cycle_energy=z["cycle_energy"],
            )

    def nbytes(self) -> int:
        return int(
            sum(
                a.nbytes
                for a in (
                    self.bucket_batch, self.bucket_seq, self.n_tasks,
                    self.q_grid, self.feasible, self.e_total, self.seg_ptr,
                    self.seg_start, self.seg_end, self.cycle_energy,
                )
            )
        )

    def summary(self) -> str:
        feas = int(self.feasible.sum())
        return (
            f"PlanTable[{self.arch}/{self.kind}] {self.n_buckets} buckets × "
            f"{self.n_q} Q points, {feas}/{self.feasible.size} feasible, "
            f"{self.nbytes() / 1e3:.1f} kB"
        )


def _default_cost(kind: str) -> CostModel:
    return memory_cost_model() if kind == "memory" else tpu_host_offload_model()


def build_plan_table(
    cfg: Union[ModelConfig, str],
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    *,
    kind: str = "time",
    cost: Optional[CostModel] = None,
    backend: str = "auto",
    cache_dir: Optional[str] = None,
    graphs: Optional[Sequence[TaskGraph]] = None,
) -> PlanTable:
    """Offline build: lower every (batch, seq) bucket via
    :func:`lower_config` and solve the whole bucket × Q grid in one
    batched engine call.

    ``kind`` picks the activation-graph cost interpretation ("time" seconds /
    "memory" working bytes — see :mod:`.layer_profile`); ``cost`` prices
    transfers and defaults per kind. With ``cache_dir``, the build is keyed by
    :func:`config_fingerprint` — a prior table for the identical inputs is
    loaded instead of re-solved, and stale or mismatched files are rebuilt in
    place. ``graphs``, if given, must be the buckets' own
    ``lower_config(cfg, b, s, kind=kind)`` results (one per bucket, in
    order) — callers that already lowered them (e.g. to derive the Q grid)
    skip the second lowering; identity is still pinned by the fingerprint
    over (cfg, buckets, kind).
    """
    from .partition_jax import sweep_jax_batched  # lazy: jax-heavy

    if isinstance(cfg, str):
        from ..configs import get_config

        cfg = get_config(cfg)
    buckets = [(int(b), int(s)) for (b, s) in shape_buckets]
    if not buckets:
        raise PlanTableError("shape_buckets is empty")
    if len(set(buckets)) != len(buckets):
        raise PlanTableError(f"duplicate shape buckets in {buckets}")
    qs = _q_list(q_values)
    if not qs:
        raise PlanTableError("q_values is empty")
    cm = cost if cost is not None else _default_cost(kind)
    fp = config_fingerprint(cfg, buckets, qs, kind, cm)

    cache_path = None
    if cache_dir is not None:
        cache_path = os.path.join(cache_dir, f"plan_{fp[:16]}.npz")
        if os.path.exists(cache_path):
            try:
                table = PlanTable.load(cache_path)
                if table.fingerprint == fp:
                    BUILD_STATS["cache_hits"] += 1
                    return table
            except PlanTableError:
                pass  # stale/corrupt cache entry: rebuild below

    if graphs is None:
        graphs = [lower_config(cfg, batch=b, seq=s, kind=kind) for (b, s) in buckets]
    elif len(graphs) != len(buckets):
        raise PlanTableError(
            f"{len(graphs)} pre-lowered graphs for {len(buckets)} buckets"
        )
    sweeps = sweep_jax_batched(graphs, cm, qs, backend=backend)

    nb, nq = len(buckets), len(qs)
    feasible = np.zeros((nb, nq), dtype=bool)
    e_total = np.full((nb, nq), np.inf, dtype=np.float64)
    seg_ptr = np.zeros(nb * nq + 1, dtype=np.int64)
    starts: List[int] = []
    ends: List[int] = []
    energies: List[float] = []
    for b, (graph, res) in enumerate(zip(graphs, sweeps)):
        for k in range(nq):
            e = b * nq + k
            bounds = res.bounds(k)
            if bounds is not None:
                feasible[b, k] = True
                e_total[b, k] = float(res.e_total[k])
                for (i, j) in bounds:
                    starts.append(i)
                    ends.append(j)
                    energies.append(burst_cost(graph, cm, i, j))
            seg_ptr[e + 1] = len(starts)

    header = {
        "version": PLAN_TABLE_VERSION,
        "arch": cfg.name,
        "kind": kind,
        "cost_name": cm.name,
        "cost_scalars": cost_scalars(cm).tolist(),
        "fingerprint": fp,
        "backend": backend,
    }
    table = PlanTable(
        header=header,
        bucket_batch=np.array([b for (b, _) in buckets], dtype=np.int64),
        bucket_seq=np.array([s for (_, s) in buckets], dtype=np.int64),
        n_tasks=np.array([g.n_tasks for g in graphs], dtype=np.int64),
        q_grid=np.array(
            [np.inf if q is None else q for q in qs], dtype=np.float64
        ),
        feasible=feasible,
        e_total=e_total,
        seg_ptr=seg_ptr,
        seg_start=np.array(starts, dtype=np.int32),
        seg_end=np.array(ends, dtype=np.int32),
        cycle_energy=np.array(energies, dtype=np.float64),
    )
    BUILD_STATS["built"] += 1
    if cache_path is not None:
        table.save(cache_path)
    return table
