"""Precomputed Q-grid segment-plan tables (the serving-path integration).

The paper's core claim is that energy-bounded execution cycles are planned
*ahead of time* and replayed cheaply at runtime (0.12% measured overhead).
This module is that split for the TPU serving path: an **offline** builder
solves the whole (shape-bucket × Q_max) design space in one batched façade
call (:func:`repro.api.solve` over a ``PartitionSpec``), and the **online**
side (:mod:`repro.launch.planner` / :mod:`repro.launch.serve`) answers every
request with an O(1) table lookup — no DP solve, no retrace, no re-upload on
the request path.

Table contents, per (bucket b, Q index k):

* the reconstructed segment bounds (the julienne cut points — these double as
  offload boundaries, remat boundaries, and pipeline cuts for the planners in
  :mod:`repro.launch.planner`),
* the per-cycle energy of every segment (what one system activation must
  deliver), and
* ``e_total`` / ``feasible`` for the whole request shape.

Serialization is a single ``.npz`` whose ``header`` entry is a JSON document
carrying the format version, the architecture, the cost-model scalars, and a
config fingerprint; :func:`PlanTable.load` refuses stale versions
(:class:`StaleTableError`) and :func:`build_plan_table` keys its on-disk cache
by the fingerprint, so a table built for one (config, buckets, Q grid, cost
model) can never silently serve another.

Design-space exploration at scale (the sharded DSE subsystem):

* ``build_plan_table(..., sharding=QGridSharding(...))`` partitions the Q
  grid across a device mesh (pmap over emulated or real devices) and gathers
  per-shard columns into one table whose content is **byte-identical** to
  the unsharded :func:`build_plan_table` result (compare with
  :meth:`PlanTable.content_digest`; :func:`shard_plan_table` survives as a
  deprecation shim);
* :func:`extend_plan_table` appends new buckets / Q points to an existing
  table *without re-solving any existing cell* — copied cells are byte-moved,
  only the genuinely new (bucket, Q) cells hit the engine, and the header's
  ``lineage`` fingerprint chain records each extension step;
* :func:`probe_plan_table` is the load-time staleness probe: it re-solves K
  random cells against the live engine and raises :class:`StaleTableError`
  on any bit mismatch (or on a mismatched engine config).

Tables are **canonical**: buckets sort by (batch, seq) and the Q grid sorts
ascending (unbounded last) at build time, so the same design-space *set* —
built single-host, sharded, or grown through any order of incremental
extensions — produces the same payload bytes (the differential/property tier
in tests/test_dse_shard.py pins this).

Bit-exactness contract (tested in tests/test_plan_table.py): a table lookup
returns bounds bit-identical to a direct :func:`optimal_partition_jax` solve
of the same (graph, cost, Q) — the batched build pads graphs to a common
shape, but padded slots contribute exact zeros and the per-Q DP rows are
independent, so tabulated and direct plans agree bound-for-bound.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..configs.base import ModelConfig
from ..obs.metrics import METRICS
from ..obs.trace import PID_SOLVER, TRACER
from ._deprecation import warn_legacy
from .burst import burst_cost
from .cost import CostModel, cost_scalars
from .graph import TaskGraph
from .layer_profile import default_cost_model, lower_config
from .partition import BUDGET_ABS, BUDGET_REL, Infeasible

__all__ = [
    "PLAN_TABLE_VERSION",
    "PlanTableError",
    "StaleTableError",
    "UnknownBucketError",
    "SegmentPlan",
    "PlanTable",
    "build_plan_table",
    "shard_plan_table",
    "extend_plan_table",
    "probe_plan_table",
    "config_fingerprint",
    "BUILD_STATS",
]

# v2: canonical bucket/Q ordering + the `lineage` fingerprint chain in the
# header (incremental-extension provenance). v1 tables must be rebuilt.
PLAN_TABLE_VERSION = 2

# Offline-build observability (tests assert the fingerprint cache short-
# circuits the solve and that extensions never rebuild existing cells).
# Registry-backed (repro.obs.metrics) but still a plain dict to consumers.
BUILD_STATS = METRICS.counter_dict(
    "plan_table.build_stats", ("built", "cache_hits", "extended")
)


class PlanTableError(ValueError):
    """Malformed, mismatched, or misused plan table."""


class StaleTableError(PlanTableError):
    """On-disk table is from an incompatible format version, or the staleness
    probe found a cell that no longer matches the live engine."""


class UnknownBucketError(PlanTableError, KeyError):
    """Request shape maps to no tabulated (batch, seq) bucket."""


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One looked-up plan: the energy-bounded cycles for a request shape.

    ``bounds`` are 1-based inclusive task ranges over the lowered activation
    graph (the julienne cut points); ``cycle_energy[c]`` is the modeled energy
    of cycle ``c`` (E_s + loads + execution + stores — what one system
    activation must deliver); ``e_total`` is the whole request.
    """

    arch: str
    batch: int
    seq_bucket: int
    q_max: Optional[float]
    n_tasks: int
    bounds: Tuple[Tuple[int, int], ...]
    cycle_energy: Tuple[float, ...]
    e_total: float

    @property
    def n_cycles(self) -> int:
        return len(self.bounds)

    @property
    def max_cycle_energy(self) -> float:
        return max(self.cycle_energy, default=0.0)

    @property
    def cut_points(self) -> Tuple[int, ...]:
        """Interior segment ends — the pipeline/offload/remat cut points."""
        return tuple(j for (_, j) in self.bounds[:-1])

    def summary(self) -> str:
        q = "inf" if self.q_max is None else f"{self.q_max:.6g}"
        return (
            f"{self.arch} b{self.batch}/s{self.seq_bucket}: "
            f"{self.n_cycles} cycles @ Q≤{q}, "
            f"max cycle {self.max_cycle_energy:.6g}, "
            f"E_total {self.e_total:.6g}"
        )


def _q_list(q_values: Sequence[Optional[float]]) -> List[Optional[float]]:
    out: List[Optional[float]] = []
    for q in q_values:
        if q is None or (isinstance(q, float) and np.isinf(q)):
            out.append(None)
        else:
            out.append(float(q))
    return out


def _q_key(q: Optional[float]) -> float:
    return np.inf if q is None else float(q)


def _canonical_grid(
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    graphs: Optional[Sequence[TaskGraph]] = None,
) -> Tuple[List[Tuple[int, int]], List[Optional[float]],
           Optional[List[TaskGraph]]]:
    """Validate and canonically order the design-space grid.

    Buckets sort by (batch, seq); Q values sort ascending with the unbounded
    entry last. Pre-lowered ``graphs`` (one per bucket, caller order) are
    permuted alongside their buckets. The canonical order is what makes the
    table content a pure function of the design-space *set* — sharded builds
    and shuffled incremental extensions land on identical bytes.
    """
    buckets = [(int(b), int(s)) for (b, s) in shape_buckets]
    if not buckets:
        raise PlanTableError("shape_buckets is empty")
    if len(set(buckets)) != len(buckets):
        raise PlanTableError(f"duplicate shape buckets in {buckets}")
    qs = _q_list(q_values)
    if not qs:
        raise PlanTableError("q_values is empty")
    keys = [_q_key(q) for q in qs]
    if len(set(keys)) != len(keys):
        raise PlanTableError(f"duplicate Q values in {q_values}")
    if graphs is not None and len(graphs) != len(buckets):
        raise PlanTableError(
            f"{len(graphs)} pre-lowered graphs for {len(buckets)} buckets"
        )
    order = sorted(range(len(buckets)), key=lambda i: buckets[i])
    buckets = [buckets[i] for i in order]
    if graphs is not None:
        graphs = [graphs[i] for i in order]
    qs = [qs[i] for i in np.argsort(np.asarray(keys), kind="stable")]
    return buckets, qs, graphs


def config_fingerprint(
    cfg: ModelConfig,
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    kind: str,
    cost: CostModel,
) -> str:
    """Content hash keying the build cache and pinning table identity.

    Covers everything the solved plans depend on: the full ModelConfig, the
    bucket set, the Q grid (exact float reprs), the cost interpretation
    (``kind``) and the cost-model scalars, plus the table format version.
    Buckets and Q values are hashed in canonical (sorted) order, so the
    fingerprint is a function of the design-space *set*, not the call order.
    """
    qs = sorted(_q_key(q) for q in _q_list(q_values))
    payload = {
        "version": PLAN_TABLE_VERSION,
        "cfg": dataclasses.asdict(cfg),
        "buckets": sorted([int(b), int(s)] for (b, s) in shape_buckets),
        "q_grid": [None if np.isinf(q) else q.hex() for q in qs],
        "kind": kind,
        "cost": {"name": cost.name, "scalars": [c.hex() for c in cost_scalars(cost)]},
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class PlanTable:
    """Immutable (bucket × Q) grid of precomputed segment plans.

    Construct via :func:`build_plan_table` / :func:`shard_plan_table` /
    :func:`extend_plan_table` or :meth:`load`; query via :meth:`lookup`.
    Storage is flat-ragged: entry ``(b, k)`` owns segment rows
    ``seg_ptr[b*nq+k] : seg_ptr[b*nq+k+1]`` of ``seg_start``/``seg_end``/
    ``cycle_energy`` (the CSR idiom the engine already uses for graphs).
    """

    def __init__(
        self,
        header: Dict,
        bucket_batch: np.ndarray,
        bucket_seq: np.ndarray,
        n_tasks: np.ndarray,
        q_grid: np.ndarray,
        feasible: np.ndarray,
        e_total: np.ndarray,
        seg_ptr: np.ndarray,
        seg_start: np.ndarray,
        seg_end: np.ndarray,
        cycle_energy: np.ndarray,
    ) -> None:
        self.header = dict(header)
        self.bucket_batch = np.asarray(bucket_batch, dtype=np.int64)
        self.bucket_seq = np.asarray(bucket_seq, dtype=np.int64)
        self.n_tasks = np.asarray(n_tasks, dtype=np.int64)
        self.q_grid = np.asarray(q_grid, dtype=np.float64)
        self.feasible = np.asarray(feasible, dtype=bool)
        self.e_total = np.asarray(e_total, dtype=np.float64)
        self.seg_ptr = np.asarray(seg_ptr, dtype=np.int64)
        self.seg_start = np.asarray(seg_start, dtype=np.int32)
        self.seg_end = np.asarray(seg_end, dtype=np.int32)
        self.cycle_energy = np.asarray(cycle_energy, dtype=np.float64)
        nb, nq = self.feasible.shape
        if self.seg_ptr.shape[0] != nb * nq + 1:
            raise PlanTableError(
                f"seg_ptr length {self.seg_ptr.shape[0]} != {nb}*{nq}+1"
            )

    # -- identity ----------------------------------------------------------

    @property
    def arch(self) -> str:
        return self.header["arch"]

    @property
    def kind(self) -> str:
        return self.header["kind"]

    @property
    def fingerprint(self) -> str:
        return self.header["fingerprint"]

    @property
    def lineage(self) -> List[str]:
        """Fingerprint chain: the fresh-build fingerprint followed by one
        entry per :func:`extend_plan_table` step (extension provenance)."""
        return list(self.header.get("lineage", [self.fingerprint]))

    @property
    def e_startup(self) -> float:
        """E_s of the cost model the table was priced under."""
        return float(self.header["cost_scalars"][0])

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_batch.shape[0])

    @property
    def n_q(self) -> int:
        return int(self.q_grid.shape[0])

    def buckets(self) -> List[Tuple[int, int]]:
        return [
            (int(b), int(s)) for b, s in zip(self.bucket_batch, self.bucket_seq)
        ]

    def q_values(self) -> List[Optional[float]]:
        return [None if np.isinf(q) else float(q) for q in self.q_grid]

    _PAYLOAD = (
        "bucket_batch", "bucket_seq", "n_tasks", "q_grid", "feasible",
        "e_total", "seg_ptr", "seg_start", "seg_end", "cycle_energy",
    )

    def content_digest(self) -> str:
        """sha256 over the table *content*: the identity header fields plus
        every payload array's dtype, shape, and raw bytes.

        Two tables with equal digests store bit-identical plans for the same
        design space under the same engine config. Build-provenance header
        fields (``lineage``, ``backend``) are excluded — a design space built
        single-host, sharded across 8 devices, or grown through any order of
        incremental extensions is *content-identical* by construction, and
        this digest is how the differential tier asserts that.
        """
        ident = {
            k: self.header[k]
            for k in ("version", "arch", "kind", "cost_name", "cost_scalars",
                      "fingerprint")
        }
        h = hashlib.sha256(
            json.dumps(ident, sort_keys=True, separators=(",", ":")).encode()
        )
        for name in self._PAYLOAD:
            a = getattr(self, name)
            h.update(f"{name}:{a.dtype.str}:{a.shape}".encode())
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()

    # -- lookup ------------------------------------------------------------

    def bucket_index(self, batch: int, seq: int) -> int:
        """Smallest tabulated seq-bucket covering ``seq`` at exactly ``batch``."""
        ok = (self.bucket_batch == int(batch)) & (self.bucket_seq >= int(seq))
        idx = np.flatnonzero(ok)
        if idx.size == 0:
            raise UnknownBucketError(
                f"no bucket covers (batch={batch}, seq={seq}); "
                f"tabulated: {self.buckets()}"
            )
        return int(idx[np.argmin(self.bucket_seq[idx])])

    def q_index(self, energy_budget: Optional[float]) -> int:
        """Largest tabulated Q_max that fits under ``energy_budget``.

        Any plan solved for Q' ≤ budget is feasible for the budget (every
        cycle ≤ Q' ≤ budget), and e_total is non-increasing in Q, so the
        largest fitting grid point is the best tabulated plan. ``None`` means
        unbounded and selects the largest grid entry.
        """
        if energy_budget is None:
            return int(np.argmax(self.q_grid))
        # vectorized within_budget(q, budget) over the grid (request path)
        cap = float(energy_budget) * (1 + BUDGET_REL) + BUDGET_ABS
        fits = np.flatnonzero(self.q_grid <= cap)
        if fits.size == 0:
            raise Infeasible(
                f"energy budget {energy_budget} is below the smallest "
                f"tabulated Q_max {self.q_grid.min():.6g}"
            )
        return int(fits[np.argmax(self.q_grid[fits])])

    def plan_at(self, b: int, k: int) -> SegmentPlan:
        """The stored plan for bucket index ``b`` at Q index ``k``."""
        if not self.feasible[b, k]:
            q = self.q_grid[k]
            raise Infeasible(
                f"bucket {self.buckets()[b]} infeasible at Q_max={q:.6g}"
            )
        e = b * self.n_q + k
        lo, hi = int(self.seg_ptr[e]), int(self.seg_ptr[e + 1])
        q = self.q_grid[k]
        return SegmentPlan(
            arch=self.arch,
            batch=int(self.bucket_batch[b]),
            seq_bucket=int(self.bucket_seq[b]),
            q_max=None if np.isinf(q) else float(q),
            n_tasks=int(self.n_tasks[b]),
            bounds=tuple(
                (int(i), int(j))
                for i, j in zip(self.seg_start[lo:hi], self.seg_end[lo:hi])
            ),
            cycle_energy=tuple(float(c) for c in self.cycle_energy[lo:hi]),
            e_total=float(self.e_total[b, k]),
        )

    def lookup(
        self, batch: int, seq: int, energy_budget: Optional[float] = None
    ) -> SegmentPlan:
        """O(1) request-path query: bucket the shape, pick the Q, return the
        precomputed plan. Raises :class:`UnknownBucketError` for untabulated
        shapes and :class:`Infeasible` for budgets below the grid."""
        if TRACER.enabled:  # guarded: keep the disabled hot path span-free
            with TRACER.span(
                "plan_table.lookup", cat="plan_table", pid=PID_SOLVER,
                batch=batch, seq=seq,
            ):
                return self.plan_at(
                    self.bucket_index(batch, seq), self.q_index(energy_budget)
                )
        return self.plan_at(
            self.bucket_index(batch, seq), self.q_index(energy_budget)
        )

    # -- serialization -----------------------------------------------------

    def save(self, path: str) -> str:
        """Write the table as one ``.npz`` with an embedded JSON header
        (atomic: write-to-temp + rename, same protocol as DirNVM)."""
        directory = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
        try:
            with os.fdopen(fd, "wb") as fh:
                np.savez(
                    fh,
                    header=np.array(json.dumps(self.header, sort_keys=True)),
                    **{name: getattr(self, name) for name in self._PAYLOAD},
                )
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return path

    @classmethod
    def load(cls, path: str) -> "PlanTable":
        with np.load(path, allow_pickle=False) as z:
            try:
                header = json.loads(str(z["header"]))
            except (KeyError, json.JSONDecodeError) as e:
                raise PlanTableError(f"{path}: missing/corrupt header") from e
            version = header.get("version")
            if version != PLAN_TABLE_VERSION:
                raise StaleTableError(
                    f"{path}: table version {version} != supported "
                    f"{PLAN_TABLE_VERSION}; rebuild with build_plan_table()"
                )
            return cls(header=header, **{name: z[name] for name in cls._PAYLOAD})

    def nbytes(self) -> int:
        return int(sum(getattr(self, name).nbytes for name in self._PAYLOAD))

    def summary(self) -> str:
        feas = int(self.feasible.sum())
        return (
            f"PlanTable[{self.arch}/{self.kind}] {self.n_buckets} buckets × "
            f"{self.n_q} Q points, {feas}/{self.feasible.size} feasible, "
            f"{self.nbytes() / 1e3:.1f} kB"
        )


# The per-kind default cost model now lives with the lowering
# (layer_profile.default_cost_model); this alias keeps the historical name
# importable for the CLIs and examples.
_default_cost = default_cost_model


# ---------------------------------------------------------------------------
# Cell blocks: vectorized (bucket × Q) assembly shared by build/shard/extend
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CellBlock:
    """Flat-ragged per-cell data: cell ``c`` owns segment rows
    ``ptr[c]:ptr[c+1]``. Cells are bucket-major, Q-minor."""

    feasible: np.ndarray
    e_total: np.ndarray
    ptr: np.ndarray
    start: np.ndarray
    end: np.ndarray
    energy: np.ndarray


def _segments_for_sweep(graph: TaskGraph, cm: CostModel, res) -> _CellBlock:
    """Vectorized extraction of one graph's (nq) cells from a JaxSweep.

    Replaces the per-cell Python loop (bounds reconstruction + burst pricing
    per (bucket, Q)) with array ops over the ``starts`` matrix — the segment
    rows come out in the same (Q-major, start-ascending) order and burst
    energies are priced once per distinct (i, j) pair, so the bytes are
    unchanged while 10⁵-Q builds stop being host-bound.
    """
    n = int(res.n_tasks)
    nq = len(res.q_values)
    feas = np.asarray(res.feasible, dtype=bool).copy()
    e_tot = np.where(feas, np.asarray(res.e_total, dtype=np.float64), np.inf)
    if n == 0:
        # An empty graph is trivially feasible everywhere with zero segments.
        return _CellBlock(
            feasible=feas,
            e_total=np.where(feas, 0.0, np.inf),
            ptr=np.zeros(nq + 1, dtype=np.int64),
            start=np.zeros(0, dtype=np.int32),
            end=np.zeros(0, dtype=np.int32),
            energy=np.zeros(0, dtype=np.float64),
        )
    sub = np.asarray(res.starts[:, 1 : n + 1], dtype=bool) & feas[:, None]
    q_idx, i0 = np.nonzero(sub)  # row-major: Q-major, start-ascending
    starts = (i0 + 1).astype(np.int32)
    nseg = starts.shape[0]
    # end of segment s = next start in the same Q row - 1, else n_tasks
    same_row = np.zeros(nseg, dtype=bool)
    if nseg:
        same_row[:-1] = q_idx[1:] == q_idx[:-1]
    nxt = np.empty(nseg, dtype=np.int32)
    if nseg:
        nxt[:-1] = starts[1:] - 1
        nxt[-1] = 0
    ends = np.where(same_row, nxt, np.int32(n))
    counts = sub.sum(axis=1).astype(np.int64)
    ptr = np.zeros(nq + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    if nseg:
        pairs = starts.astype(np.int64) * (n + 2) + ends.astype(np.int64)
        uniq, inv = np.unique(pairs, return_inverse=True)
        priced = np.array(
            [burst_cost(graph, cm, int(p // (n + 2)), int(p % (n + 2)))
             for p in uniq],
            dtype=np.float64,
        )
        energy = priced[inv]
    else:
        energy = np.zeros(0, dtype=np.float64)
    return _CellBlock(
        feasible=feas, e_total=e_tot, ptr=ptr,
        start=starts, end=ends, energy=energy,
    )


def _block_from_sweeps(
    graphs: Sequence[TaskGraph], cm: CostModel, sweeps: Sequence
) -> _CellBlock:
    return _block_concat(
        [_segments_for_sweep(g, cm, res) for g, res in zip(graphs, sweeps)]
    )


def _block_from_table(table: PlanTable) -> _CellBlock:
    return _CellBlock(
        feasible=table.feasible.reshape(-1),
        e_total=table.e_total.reshape(-1),
        ptr=table.seg_ptr,
        start=table.seg_start,
        end=table.seg_end,
        energy=table.cycle_energy,
    )


def _block_concat(blocks: Sequence[_CellBlock]) -> _CellBlock:
    ptr = np.zeros(sum(b.ptr.shape[0] - 1 for b in blocks) + 1, dtype=np.int64)
    pos, off = 1, 0
    for b in blocks:
        nc = b.ptr.shape[0] - 1
        ptr[pos : pos + nc] = b.ptr[1:] + off
        pos += nc
        off += int(b.ptr[-1])
    return _CellBlock(
        feasible=np.concatenate([b.feasible for b in blocks]),
        e_total=np.concatenate([b.e_total for b in blocks]),
        ptr=ptr,
        start=np.concatenate([b.start for b in blocks]),
        end=np.concatenate([b.end for b in blocks]),
        energy=np.concatenate([b.energy for b in blocks]),
    )


def _block_gather(block: _CellBlock, order: np.ndarray) -> _CellBlock:
    """Reorder ragged cells: cell ``c`` of the result is cell ``order[c]``
    of ``block`` (the standard CSR row-gather, fully vectorized)."""
    counts = np.diff(block.ptr)[order]
    ptr = np.zeros(order.shape[0] + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    total = int(ptr[-1])
    idx = (
        np.repeat(block.ptr[:-1][order], counts)
        + np.arange(total, dtype=np.int64)
        - np.repeat(ptr[:-1], counts)
    )
    return _CellBlock(
        feasible=block.feasible[order],
        e_total=block.e_total[order],
        ptr=ptr,
        start=block.start[idx],
        end=block.end[idx],
        energy=block.energy[idx],
    )


def _finish_table(
    cfg: ModelConfig,
    kind: str,
    cm: CostModel,
    fp: str,
    backend: str,
    buckets: Sequence[Tuple[int, int]],
    qs: Sequence[Optional[float]],
    n_tasks: Sequence[int],
    block: _CellBlock,
    lineage: Sequence[str],
) -> PlanTable:
    nb, nq = len(buckets), len(qs)
    header = {
        "version": PLAN_TABLE_VERSION,
        "arch": cfg.name,
        "kind": kind,
        "cost_name": cm.name,
        "cost_scalars": cost_scalars(cm).tolist(),
        "fingerprint": fp,
        "backend": backend,
        "lineage": list(lineage),
    }
    return PlanTable(
        header=header,
        bucket_batch=np.array([b for (b, _) in buckets], dtype=np.int64),
        bucket_seq=np.array([s for (_, s) in buckets], dtype=np.int64),
        n_tasks=np.asarray(n_tasks, dtype=np.int64),
        q_grid=np.array([_q_key(q) for q in qs], dtype=np.float64),
        feasible=block.feasible.reshape(nb, nq),
        e_total=block.e_total.reshape(nb, nq),
        seg_ptr=block.ptr,
        seg_start=block.start,
        seg_end=block.end,
        cycle_energy=block.energy,
    )


def _cache_lookup(cache_dir: Optional[str], fp: str, lineage: Sequence[str]):
    """(cache_path, hit-or-None) for a fingerprint-keyed on-disk cache.

    A hit must match the caller's expected ``lineage`` too: content is a
    pure function of the fingerprint, but provenance is not — a fresh build
    must not serve a cached extension's multi-link chain (or vice versa), so
    a lineage mismatch is treated as a miss and rebuilt in place.
    """
    if cache_dir is None:
        return None, None
    cache_path = os.path.join(cache_dir, f"plan_{fp[:16]}.npz")
    if os.path.exists(cache_path):
        try:
            table = PlanTable.load(cache_path)
            if table.fingerprint == fp and table.lineage == list(lineage):
                return cache_path, table
        except PlanTableError:
            pass  # stale/corrupt cache entry: rebuild
    return cache_path, None


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _facade_sweeps(graphs, cm, qs, backend, sharding):
    """One batched façade solve returning JaxSweeps — the cell assembly
    consumes sweep tables, so a Partition-producing backend (numpy) is a
    clear error here rather than a ``None`` downstream."""
    from ..api import PartitionSpec, solve  # lazy: jax-heavy

    sol = solve(PartitionSpec(
        graphs=tuple(graphs), cost=cm, q_grid=tuple(qs),
        backend=backend, sharding=sharding,
    ))
    if sol.sweeps is None:
        raise PlanTableError(
            f"plan tables need a JaxSweep-producing backend "
            f"(scan/pallas/auto); backend={backend!r} returns Partition "
            f"objects"
        )
    return sol.sweeps


def _build_table(
    cfg: Union[ModelConfig, str],
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    *,
    kind: str,
    cost: Optional[CostModel],
    backend: str,
    cache_dir: Optional[str],
    graphs: Optional[Sequence[TaskGraph]],
    sharding,
) -> PlanTable:
    from ..configs import resolve_config

    cfg = resolve_config(cfg)
    buckets, qs, graphs = _canonical_grid(shape_buckets, q_values, graphs)
    cm = cost if cost is not None else _default_cost(kind)
    fp = config_fingerprint(cfg, buckets, qs, kind, cm)

    cache_path, cached = _cache_lookup(cache_dir, fp, [fp])
    if cached is not None:
        BUILD_STATS["cache_hits"] += 1
        return cached

    with TRACER.span(
        "plan_table.build", cat="plan_table", pid=PID_SOLVER,
        cfg=cfg.name, buckets=len(buckets), q_points=len(qs),
    ):
        if graphs is None:
            graphs = [
                lower_config(cfg, batch=b, seq=s, kind=kind) for (b, s) in buckets
            ]
        sweeps = _facade_sweeps(graphs, cm, qs, backend, sharding)
        table = _finish_table(
            cfg, kind, cm, fp, backend, buckets, qs,
            [g.n_tasks for g in graphs], _block_from_sweeps(graphs, cm, sweeps),
            lineage=[fp],
        )
    BUILD_STATS["built"] += 1
    if cache_path is not None:
        table.save(cache_path)
    return table


def build_plan_table(
    cfg: Union[ModelConfig, str],
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    *,
    kind: str = "time",
    cost: Optional[CostModel] = None,
    backend: str = "auto",
    cache_dir: Optional[str] = None,
    graphs: Optional[Sequence[TaskGraph]] = None,
    sharding=None,
) -> PlanTable:
    """Offline build: lower every (batch, seq) bucket via
    :func:`lower_config` and solve the whole bucket × Q grid in one
    batched façade call (:func:`repro.api.solve`).

    ``kind`` picks the activation-graph cost interpretation ("time" seconds /
    "memory" working bytes — see :mod:`.layer_profile`); ``cost`` prices
    transfers and defaults per kind. With ``cache_dir``, the build is keyed by
    :func:`config_fingerprint` — a prior table for the identical inputs is
    loaded instead of re-solved, and stale or mismatched files are rebuilt in
    place. ``graphs``, if given, must be the buckets' own
    ``lower_config(cfg, b, s, kind=kind)`` results (one per bucket, in the
    caller's bucket order) — callers that already lowered them (e.g. to
    derive the Q grid) skip the second lowering; identity is still pinned by
    the fingerprint over (cfg, buckets, kind). Buckets and Q values are
    stored in canonical sorted order regardless of call order.

    ``sharding`` (a :class:`repro.api.QGridSharding`) splits the Q grid
    across a device mesh; the gathered per-shard columns assemble into a
    table **byte-identical** to the unsharded build of the same inputs
    (same fingerprint, same :meth:`PlanTable.content_digest` — the
    differential tier pins this on 1/2/4/8 emulated devices). With fewer
    devices than shards the same chunk decomposition runs sequentially
    (bit-identical either way), so a shard count tuned for an 8-device host
    is safe on a laptop.
    """
    return _build_table(
        cfg, shape_buckets, q_values, kind=kind, cost=cost, backend=backend,
        cache_dir=cache_dir, graphs=graphs, sharding=sharding,
    )


def shard_plan_table(
    cfg: Union[ModelConfig, str],
    shape_buckets: Sequence[Tuple[int, int]],
    q_values: Sequence[Optional[float]],
    *,
    n_shards: int,
    devices: Optional[Sequence] = None,
    kind: str = "time",
    cost: Optional[CostModel] = None,
    backend: str = "auto",
    cache_dir: Optional[str] = None,
    graphs: Optional[Sequence[TaskGraph]] = None,
) -> PlanTable:
    """Sharded offline build.

    .. deprecated:: use ``build_plan_table(...,
       sharding=QGridSharding(n_shards, devices))`` — byte-identical output
       (the two historical builders collapsed into one spec-shaped entry
       point).
    """
    warn_legacy(
        "repro.core.plan_table.shard_plan_table",
        "build_plan_table(..., sharding=QGridSharding(n_shards, devices))",
    )
    from ..api import QGridSharding  # lazy: avoids an import cycle

    return _build_table(
        cfg, shape_buckets, q_values, kind=kind, cost=cost, backend=backend,
        cache_dir=cache_dir, graphs=graphs,
        sharding=QGridSharding(
            int(n_shards), None if devices is None else tuple(devices)
        ),
    )


def extend_plan_table(
    base: Union[PlanTable, str],
    cfg: Union[ModelConfig, str],
    *,
    add_buckets: Sequence[Tuple[int, int]] = (),
    add_q_values: Sequence[Optional[float]] = (),
    cost: Optional[CostModel] = None,
    backend: str = "auto",
    cache_dir: Optional[str] = None,
    n_shards: Optional[int] = None,
    devices: Optional[Sequence] = None,
) -> PlanTable:
    """Incrementally extend a table with new buckets and/or Q points.

    Existing cells are **never re-solved**: their rows are byte-moved from
    ``base`` (pinned by ``SOLVE_COUNT`` in the DSE tests), and only the new
    (bucket, Q) cells hit the engine — one batched (or sharded, with
    ``n_shards``) solve for new buckets over the final Q grid plus one for
    old buckets over the new Q points. Additions already tabulated are
    ignored, so re-extending an untouched base returns it unchanged with
    zero engine calls.

    The result is canonical: **bit-identical content** to a fresh
    :func:`build_plan_table` of the final (bucket, Q) set, regardless of how
    the set was split into extension steps or in what order they were applied
    (the property tier shuffles them). The header's ``lineage`` chain gains
    the final fingerprint, recording the extension provenance.
    """
    from ..api import QGridSharding  # lazy: jax-heavy
    from ..configs import resolve_config

    if isinstance(base, str):
        base = PlanTable.load(base)
    cfg = resolve_config(cfg)
    kind = base.kind
    cm = cost if cost is not None else _default_cost(kind)
    base_buckets = base.buckets()
    base_qs = base.q_values()
    fp_base = config_fingerprint(cfg, base_buckets, base_qs, kind, cm)
    if fp_base != base.fingerprint:
        raise PlanTableError(
            f"base table fingerprint {base.fingerprint[:16]}… does not match "
            f"this engine config (cfg={cfg.name!r}, kind={kind!r}, "
            f"cost={cm.name!r} → {fp_base[:16]}…); refusing to extend"
        )

    old_b_index = {b: i for i, b in enumerate(base_buckets)}
    old_q_index = {_q_key(q): i for i, q in enumerate(base_qs)}
    new_buckets = []
    for b in [(int(x), int(s)) for (x, s) in add_buckets]:
        if b not in old_b_index and b not in new_buckets:
            new_buckets.append(b)
    new_qs = []
    for q in _q_list(add_q_values):
        if _q_key(q) not in old_q_index and _q_key(q) not in map(_q_key, new_qs):
            new_qs.append(q)
    if not new_buckets and not new_qs:
        return base  # untouched: zero engine calls, zero re-solves

    final_buckets, final_qs, _ = _canonical_grid(
        base_buckets + new_buckets, base_qs + new_qs
    )
    new_qs = sorted(new_qs, key=_q_key)
    fp = config_fingerprint(cfg, final_buckets, final_qs, kind, cm)
    lineage = base.lineage + [fp]
    cache_path, cached = _cache_lookup(cache_dir, fp, lineage)
    if cached is not None:
        BUILD_STATS["cache_hits"] += 1
        return cached

    sharding = (
        None if n_shards is None else QGridSharding(
            int(n_shards), None if devices is None else tuple(devices)
        )
    )

    def _solve(graphs, qs):
        # One span per engine call the extension actually makes (new-bucket
        # block and/or new-Q block); an untouched extend emits none.
        with TRACER.span(
            "plan_table.extend", cat="plan_table", pid=PID_SOLVER,
            graphs=len(graphs), q_points=len(qs),
        ):
            return _facade_sweeps(graphs, cm, qs, backend, sharding)

    new_buckets = sorted(new_buckets)
    new_b_index = {b: i for i, b in enumerate(new_buckets)}
    new_q_index = {_q_key(q): i for i, q in enumerate(new_qs)}
    nq_f, nq_old, nq_new = len(final_qs), len(base_qs), len(new_qs)
    nb_old = len(base_buckets)

    # Pool: [base cells | new-bucket × final-Q cells | old-bucket × new-Q
    # cells]; the gather below reorders it into canonical (bucket-major,
    # Q-minor) cell order without touching any copied bytes.
    blocks = [_block_from_table(base)]
    off_newb = nb_old * nq_old
    if new_buckets:
        new_graphs = [
            lower_config(cfg, batch=b, seq=s, kind=kind) for (b, s) in new_buckets
        ]
        blocks.append(_block_from_sweeps(new_graphs, cm, _solve(new_graphs, final_qs)))
    off_oldq = off_newb + len(new_buckets) * nq_f
    if new_qs:
        old_graphs = [
            lower_config(cfg, batch=b, seq=s, kind=kind) for (b, s) in base_buckets
        ]
        blocks.append(_block_from_sweeps(old_graphs, cm, _solve(old_graphs, new_qs)))
    pool = _block_concat(blocks)

    # Per-Q source row (same for every old bucket): base column or new-solve
    # column — vectorized so the merge stays O(cells) in numpy, not Python.
    q_keys = np.array([_q_key(q) for q in final_qs])
    q_is_old = np.array([k in old_q_index for k in q_keys])
    q_old_col = np.array([old_q_index.get(k, 0) for k in q_keys], dtype=np.int64)
    q_new_col = np.array([new_q_index.get(k, 0) for k in q_keys], dtype=np.int64)
    order = np.empty(len(final_buckets) * nq_f, dtype=np.int64)
    for bf, bucket in enumerate(final_buckets):
        row = slice(bf * nq_f, (bf + 1) * nq_f)
        if bucket in old_b_index:
            ob = old_b_index[bucket]
            order[row] = np.where(
                q_is_old,
                ob * nq_old + q_old_col,
                off_oldq + ob * nq_new + q_new_col,
            )
        else:
            jb = new_b_index[bucket]
            order[row] = off_newb + jb * nq_f + np.arange(nq_f)

    n_tasks = [
        int(base.n_tasks[old_b_index[b]]) if b in old_b_index
        else new_graphs[new_b_index[b]].n_tasks
        for b in final_buckets
    ]
    table = _finish_table(
        cfg, kind, cm, fp, backend, final_buckets, final_qs, n_tasks,
        _block_gather(pool, order), lineage=lineage,
    )
    BUILD_STATS["extended"] += 1
    if cache_path is not None:
        table.save(cache_path)
    return table


# ---------------------------------------------------------------------------
# Load-time staleness probe
# ---------------------------------------------------------------------------


def probe_plan_table(
    table: PlanTable,
    cfg: Union[ModelConfig, str],
    *,
    k: Optional[int] = 4,
    seed: int = 0,
    cost: Optional[CostModel] = None,
    backend: str = "auto",
    measured=None,
    drift_tol: float = 0.05,
) -> int:
    """Re-validate ``k`` random cells against the live engine (``k=None``
    probes every cell). Returns the number of probed cells.

    Raises :class:`StaleTableError` when the table's fingerprint does not
    match the given engine config (cfg / kind / cost-model scalars), or when
    any probed cell's feasibility, e_total, bounds, or cycle energies differ
    by even one bit from a fresh solve — the load-time guard for tables that
    outlived an engine or cost-model change the version field can't see.

    ``measured`` (a :class:`repro.core.calibration.MeasuredCostTable`, e.g.
    rebuilt from a fresh profile via ``launch/dse.py --calibrate``)
    additionally reprices every probed feasible cell's cycle energies under
    the measured mean model and rejects the table when any cycle's measured
    draw drifts from the tabulated value by more than ``drift_tol``
    (relative) — the staleness check against a refreshed profile. A clean
    calibration (measurements matching the table's cost model) materializes
    the tabulated model itself and always passes.
    """
    from ..api import PartitionSpec, solve  # lazy: jax-heavy
    from ..configs import resolve_config
    from .partition import BUDGET_ABS

    cfg = resolve_config(cfg)
    cm = cost if cost is not None else _default_cost(table.kind)
    fp = config_fingerprint(cfg, table.buckets(), table.q_values(), table.kind, cm)
    if fp != table.fingerprint:
        raise StaleTableError(
            f"table fingerprint {table.fingerprint[:16]}… does not match the "
            f"live engine config (cfg={cfg.name!r}, kind={table.kind!r}, "
            f"cost={cm.name!r} → {fp[:16]}…)"
        )
    m_cm = None
    if measured is not None:
        m_kind = getattr(measured, "kind", table.kind)
        if m_kind != table.kind:
            raise StaleTableError(
                f"calibration profile is kind={m_kind!r} but the table is "
                f"kind={table.kind!r}"
            )
        if drift_tol < 0:
            raise PlanTableError(f"drift_tol must be >= 0, got {drift_tol}")
        m_cm = measured.cost_model()
    nb, nq = table.n_buckets, table.n_q
    total = nb * nq
    if k is None or k >= total:
        cells = np.arange(total)
    else:
        if k < 1:
            raise PlanTableError(f"probe needs k >= 1 cells, got {k}")
        rng = np.random.default_rng(seed)
        cells = np.sort(rng.choice(total, size=k, replace=False))

    buckets = table.buckets()
    qs = table.q_values()
    for b in np.unique(cells // nq):
        q_sel = [int(c % nq) for c in cells if c // nq == b]
        batch, seq_b = buckets[int(b)]
        graph = lower_config(cfg, batch=batch, seq=seq_b, kind=table.kind)
        res = solve(PartitionSpec(
            graph=graph, cost=cm, q_grid=tuple(qs[j] for j in q_sel),
            backend=backend,
        )).sweep
        for qi, j in enumerate(q_sel):
            where = f"cell (bucket {buckets[int(b)]}, Q={qs[j]})"
            if graph.n_tasks != int(table.n_tasks[b]):
                raise StaleTableError(
                    f"stale {where}: n_tasks {table.n_tasks[b]} != "
                    f"{graph.n_tasks} from the live lowering"
                )
            if bool(res.feasible[qi]) != bool(table.feasible[b, j]):
                raise StaleTableError(
                    f"stale {where}: feasibility flag differs from live solve"
                )
            if not res.feasible[qi]:
                continue
            plan = table.plan_at(int(b), j)
            if float(res.e_total[qi]) != plan.e_total:
                raise StaleTableError(
                    f"stale {where}: e_total {plan.e_total!r} != live "
                    f"{float(res.e_total[qi])!r}"
                )
            bounds = res.bounds(qi)
            if list(plan.bounds) != bounds:
                raise StaleTableError(
                    f"stale {where}: bounds {list(plan.bounds)} != live {bounds}"
                )
            live_energy = tuple(
                burst_cost(graph, cm, i, jj) for (i, jj) in bounds
            )
            if plan.cycle_energy != live_energy:
                raise StaleTableError(
                    f"stale {where}: cycle energies differ from live pricing"
                )
            if m_cm is not None:
                for ci, ((i, jj), tab_e) in enumerate(zip(bounds, live_energy)):
                    meas_e = burst_cost(graph, m_cm, i, jj)
                    err = abs(meas_e - tab_e)
                    scale = max(abs(meas_e), abs(tab_e))
                    if err > drift_tol * scale + BUDGET_ABS:
                        raise StaleTableError(
                            f"stale {where}: cycle {ci} measured draw "
                            f"{meas_e!r} drifted {err / scale:.1%} from the "
                            f"tabulated {tab_e!r} (tolerance "
                            f"{drift_tol:.1%}) — recalibrate and rebuild"
                        )
    return int(len(cells))
