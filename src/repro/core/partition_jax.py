"""JAX-native, jit-compiled burst partitioning engine (paper §4.3–§4.4).

Reached through the :mod:`repro.api` façade: the ``scan`` and ``pallas``
registry backends (:mod:`repro.core.engine`) dispatch into the private
implementations here, and the historical public entry points (``sweep_jax``,
``sweep_jax_batched``, ``sweep_jax_sharded``, ``optimal_partition_jax``)
survive as thin :class:`DeprecationWarning` shims over the same code.

This is the batched re-expression of the two numpy reference paths:

* the incremental column sweep (:class:`repro.core.burst.ColumnSweep`)
  becomes a ``lax.scan`` over tasks, carrying the live column ``E⟨·,j⟩`` and
  applying each task's three piecewise-constant updates as masked adds over
  the dense arrays exported by :meth:`TaskGraph.to_arrays`;

* the forward DAG-DP (:func:`repro.core.partition.optimal_partition_multi`)
  rides in the same scan, broadcast across an arbitrary Q_max grid — one
  compiled kernel juliennes the whole design space in one shot.

A second ``vmap`` layer batches across *graphs*: :func:`sweep_jax_batched`
takes padded exports of different applications (the whole model zoo, lowered
via :func:`repro.core.layer_profile.lower_config`) and solves them together.

Two interchangeable backends drive the same host API (``backend=`` on
:func:`sweep_jax` / :func:`sweep_jax_batched` / :func:`optimal_partition_jax`):

* ``"scan"`` — the ``lax.scan`` engine below over the dense
  :meth:`TaskGraph.to_arrays` export. Best for Q-grid-heavy DSE on graphs
  whose read degree is bounded (the padded ``(N, R)`` rectangle stays small).
* ``"pallas"`` — the fused column-sweep/DP kernel in
  :mod:`repro.kernels.partition_sweep` over the compressed
  :meth:`TaskGraph.to_csr_arrays` export. Required for skewed-degree graphs:
  the full 5458-task head-count application has R ≈ 5452 (its sort task reads
  every score packet), which would dense-export ~1 GB; the CSR slot layout is
  ~400 kB and the kernel applies slot contributions in-register.
* ``"auto"`` (default) — picks "pallas" when the dense export would exceed
  ``_AUTO_DENSE_BYTES`` (or when handed a ``GraphCSRArrays``), else "scan".

Serving-path behavior (ROADMAP "hoist dtype handling"): graph uploads are
device-cached per export object, cost scalars per cost model, and both
backends' jitted callables are shape-keyed — so a serving loop re-solving the
same application across Q grids does no per-request re-trace, re-upload, or
global-config churn beyond the thread-local ``enable_x64`` flag entered once
per call (asserted by the no-retrace test in tests/test_partition_sweep.py).

The per-column recurrence, identical to :mod:`.burst` (all 1-based):

    E⟨i,j⟩ = E⟨i,j-1⟩ + E_task(j) + S(j)
           + Σ_{p ∈ reads(j)}  E_r(p) · [i > l_j(p)]            (new loads)
           - Σ_{p ∈ reads(j)}  E_w(p) · [l_∞(p) = j]
                                      · [1 ≤ writer(p)]
                                      · [i ≤ writer(p)]          (store freed)
    E⟨j,j⟩ = E_s + Σ_{p ∈ reads(j)} E_r(p) + E_task(j) + S(j)

with ``S(j) = Σ_{p ∈ writes(j), l_∞(p) > j} E_w(p)``, and the fused DP:

    dp[q, j]  = min_{1 ≤ i ≤ j, E⟨i,j⟩ ≤ Q_max[q]} dp[q, i-1] + E⟨i,j⟩

Numerics run in float64 under :func:`jax.experimental.enable_x64` so results
match the numpy oracles to ~ulp; infeasibility uses the same relative budget
tolerance as the numpy path. Tie-breaking (argmin picks the smallest burst
start) also matches, so reconstructed bounds agree bit-for-bit on generic
cost vectors.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from ..obs.metrics import METRICS
from ._cache import weak_id_cache
from ._deprecation import warn_legacy
from .cost import CostModel, cost_scalars
from .engine import ExportMismatch, resolve_jit_backend
from .graph import (
    GraphArrays,
    GraphCSRArrays,
    TaskGraph,
    stack_graph_arrays,
)
from .partition import (
    BUDGET_ABS,
    BUDGET_REL,
    Infeasible,
    Partition,
    _partition_from_bounds,
)

__all__ = [
    "JaxSweep",
    "sweep_jax",
    "sweep_jax_batched",
    "sweep_jax_sharded",
    "shard_q_grid",
    "optimal_partition_jax",
    "sweep_from_columns",
    "cost_scalars",
]

# Budget tolerance: the single source of truth lives in partition.py
# (BUDGET_REL/BUDGET_ABS) so every solver path masks identically.
_REL = BUDGET_REL
_ABS = BUDGET_ABS

# Read-slot count above which the scan backend's column update switches from
# the order-preserving unrolled loop to one masked 2-D reduction.
_UNROLL_MAX = 8

# backend="auto": route to the CSR/Pallas backend once the dense export would
# cross this size (the full head-count graph is ~1 GB dense, ~400 kB CSR).
_AUTO_DENSE_BYTES = 32 << 20

# Trace-count regression hooks (incremented at trace time only; see the
# no-retrace test in tests/test_partition_sweep.py). Registry-backed
# (repro.obs.metrics) but still plain dicts to consumers.
TRACE_COUNT = METRICS.counter_dict(
    "partition_jax.trace_count", ("dp_sweep", "qmin_sweep", "exactk_sweep")
)

# Host-side solve counters (incremented per engine entry, cached or not):
# the plan-table serving tests pin "zero partitioner solves on the request
# path" against these, and the DSE tests pin "extending an untouched table
# never re-solves existing cells".
SOLVE_COUNT = METRICS.counter_dict(
    "partition_jax.solve_count",
    (
        "sweep_jax",
        "sweep_jax_batched",
        "sweep_jax_sharded",
        "q_min_scan",
        "optimal_k_scan",
        "q_min_pallas",
        "optimal_k_pallas",
    ),
)


# ---------------------------------------------------------------------------
# The jitted engine
# ---------------------------------------------------------------------------


def _sweep_inputs(ga: dict, cost_vec):
    """Per-column scan inputs shared by every DP variant (sum / minimax /
    exact-K): slot transfer costs under the cost model, the store term S(j),
    and the stacked ``xs`` the scans consume. Returns ``(xs, e_s)``.
    """
    e_s, r_c0, r_c1, w_c0, w_c1 = (cost_vec[k] for k in range(5))
    N = ga["e_task"].shape[0]
    W = ga["write_bytes"].shape[1]

    # Per-slot transfer costs under this cost model (padding contributes 0).
    read_cost = ga["read_valid"] * (r_c0 * ga["read_c0w"] + r_c1 * ga["read_bytes"])
    # E_w of the *read* packet — charged back when the burst absorbs both the
    # writer and the last reader, making the intermediate store unnecessary.
    read_free = ga["read_valid"] * (w_c0 * ga["read_c0w"] + w_c1 * ga["read_bytes"])
    write_cost = ga["write_valid"] * (
        w_c0 * ga["write_c0w"] + w_c1 * ga["write_bytes"]
    )

    # S(j): accumulated write-slot by write-slot (left-to-right) so the
    # float64 rounding sequence is identical to ColumnSweep's Python sum —
    # that keeps dp tables (and argmin tie-breaks) bit-compatible with numpy.
    j_col = jnp.arange(1, N + 1)
    store_add = jnp.zeros(N)
    for w in range(W):
        keep = ga["write_linf"][:, w] > j_col
        store_add = jnp.where(keep, store_add + write_cost[:, w], store_add)

    xs = (
        jnp.arange(1, N + 1),
        ga["e_task"],
        store_add,
        read_cost,
        read_free,
        ga["read_lt"],
        ga["read_writer"],
        ga["read_linf"],
    )
    return xs, e_s


def _advance_column(col, xs, i_idx, e_s, R):
    """One task's updates to the live column E⟨·,j⟩ (identical op order to
    the numpy :class:`~repro.core.burst.ColumnSweep`, so columns — and hence
    every DP variant's tie-breaks — stay bit-compatible).

    1) extend all existing bursts ⟨i, j-1⟩ with task j. For small R the
    read-slot loop is unrolled at trace time and applies the adds in the
    same order as the numpy sweep, keeping columns bit-identical (so argmin
    tie-breaks — and hence bounds — match numpy exactly). Wide-reader graphs
    (R > ``_UNROLL_MAX``, e.g. head-count's 5k-reader sort task) use one
    masked 2-D reduction instead: same values to ~ulp (XLA's FMA contraction
    already perturbs those graphs anyway). 2) start the new single-task
    burst ⟨j,j⟩.
    """
    j, e_j, s_j, rcost, rfree, rlt, rwriter, rlinf = xs
    prev = (i_idx >= 1) & (i_idx < j)
    col = jnp.where(prev, col + (e_j + s_j), col)
    if R <= _UNROLL_MAX:
        sum_er = e_j * 0.0
        for r in range(R):
            col = jnp.where(prev & (i_idx > rlt[r]), col + rcost[r], col)
            freed = (rlinf[r] == j) & (rwriter[r] >= 1)
            col = jnp.where(
                prev & freed & (i_idx <= rwriter[r]), col - rfree[r], col
            )
            sum_er = sum_er + rcost[r]
    else:
        loads = (rcost[None, :] * (i_idx[:, None] > rlt[None, :])).sum(1)
        freed = (
            rfree[None, :]
            * ((rlinf == j) & (rwriter >= 1))[None, :]
            * (i_idx[:, None] <= rwriter[None, :])
        ).sum(1)
        col = jnp.where(prev, col + loads - freed, col)
        sum_er = rcost.sum()
    col = col.at[j].set(e_s + sum_er + e_j + s_j)
    return col


def _dp_sweep(ga: dict, n_tasks, cost_vec, qs):
    """Column sweep + multi-Q DP + bounds reconstruction for one graph.

    ``ga`` holds the GraphArrays fields as jnp arrays of static shape
    (N,), (N,R), (N,W); ``n_tasks`` is a traced scalar (≤ N); ``qs`` is the
    (nq,) Q_max grid. Returns (dp, parent, e_total, feasible, starts).
    """
    TRACE_COUNT["dp_sweep"] += 1
    N = ga["e_task"].shape[0]
    R = ga["read_bytes"].shape[1]
    nq = qs.shape[0]
    i_idx = jnp.arange(N + 1)
    xs, e_s = _sweep_inputs(ga, cost_vec)

    q_budget = qs * (1.0 + _REL) + _ABS
    i_tail = i_idx[1:]  # i = 1..N
    i_tail32 = i_tail.astype(jnp.int32)

    def make_step(Wc):
        """Scan body for the chunk whose steps all have j ≤ Wc: candidate
        tables are (nq, Wc) instead of (nq, N) — early chunks pay only for
        the bursts that can actually exist yet (~40% less DP work overall)."""

        def step(carry, x):
            col, dp = carry
            j = x[0]
            col = _advance_column(col, x, i_idx, e_s, R)

            # DP relaxation dp[q, j] = min_i dp[q, i-1] + E⟨i,j⟩ over the
            # whole Q grid at once. No i ≤ j mask is needed: dp columns ≥ j
            # are still inf from initialization, so candidates beyond the
            # diagonal are inf automatically.
            c = col[1 : Wc + 1]
            cand = dp[:, :Wc] + jnp.where(
                c[None, :] <= q_budget[:, None], c[None, :], jnp.inf
            )
            # Two single-operand reduces (XLA vectorizes those; its variadic
            # (value, index) reduce lowers to a scalar loop): the min, then
            # the smallest burst start achieving it — numpy's first-minimum
            # argmin, so parents tie-break identically on identical columns.
            mn = jnp.min(cand, axis=1)
            best = jnp.min(
                jnp.where(cand == mn[:, None], i_tail32[None, :Wc], N + 1),
                axis=1,
            )
            # dp carries columns 0..N-1 (column N is never a predecessor);
            # the final table is reassembled from the emitted mins below.
            dp = dp.at[:, j].set(mn, mode="drop")
            return (col, dp), (mn, best)

        return step

    dp0 = jnp.full((nq, N), jnp.inf).at[:, 0].set(0.0)
    carry = (jnp.zeros(N + 1), dp0)
    n_chunks = min(4, N)
    edges = sorted({-(-N * k // n_chunks) for k in range(1, n_chunks + 1)})
    mns_parts, bests_parts = [], []
    start = 0
    for end in edges:
        chunk_xs = tuple(a[start:end] for a in xs)
        carry, (mn_c, best_c) = lax.scan(make_step(end), carry, chunk_xs)
        mns_parts.append(mn_c)
        bests_parts.append(best_c)
        start = end
    mns = jnp.concatenate(mns_parts, axis=0)
    bests = jnp.concatenate(bests_parts, axis=0)

    dp = jnp.concatenate([jnp.zeros((nq, 1)), mns.T], axis=1)  # (nq, N+1)
    parent = jnp.zeros((nq, N + 1), dtype=jnp.int32).at[:, 1:].set(bests.T)
    e_total = lax.dynamic_index_in_dim(mns, n_tasks - 1, axis=0, keepdims=False)
    feasible = jnp.isfinite(e_total)

    # 4) walk the parent pointers back from task n: mark each burst start
    def reconstruct(pq):
        def back(j, _):
            i = jnp.where(j > 0, pq[j], 0)
            emit = jnp.where(j > 0, i, N + 1)  # N+1 = trash slot
            return jnp.where(j > 0, jnp.maximum(i - 1, 0), 0), emit

        _, emits = lax.scan(back, n_tasks, None, length=N)
        return jnp.zeros(N + 2, dtype=bool).at[emits].set(True)[: N + 1]

    starts = jax.vmap(reconstruct)(parent)
    return dp, parent, e_total, feasible, starts


_dp_sweep_jit = jax.jit(_dp_sweep)
_dp_sweep_vmap = jax.jit(
    jax.vmap(_dp_sweep, in_axes=(0, 0, None, None))
)


def _qmin_sweep(ga: dict, n_tasks, cost_vec):
    """§4.4 storage minimization as the same column scan with a minimax
    combine: mm[j] = min_i max(mm[i-1], E⟨i,j⟩). max/min are exact in
    float64, so the result is bit-identical to the numpy :func:`q_min`
    wherever the columns are (i.e. everywhere the sum DP is)."""
    TRACE_COUNT["qmin_sweep"] += 1
    N = ga["e_task"].shape[0]
    R = ga["read_bytes"].shape[1]
    i_idx = jnp.arange(N + 1)
    xs, e_s = _sweep_inputs(ga, cost_vec)

    def step(carry, x):
        col, mm = carry
        j = x[0]
        col = _advance_column(col, x, i_idx, e_s, R)
        # mm entries at positions ≥ j are still inf from initialization, so
        # candidates beyond the diagonal drop out exactly like the sum DP's.
        best = jnp.min(jnp.maximum(mm[:N], col[1 : N + 1]))
        mm = mm.at[j].set(best)
        return (col, mm), best

    mm0 = jnp.full(N + 1, jnp.inf).at[0].set(0.0)
    _, bests = lax.scan(step, (jnp.zeros(N + 1), mm0), xs)
    return lax.dynamic_index_in_dim(bests, n_tasks - 1, keepdims=False)


_qmin_sweep_jit = jax.jit(_qmin_sweep)


def _exactk_sweep(ga: dict, n_tasks, cost_vec, q, *, n_bursts, combine_max):
    """The exact-K pipeline DP riding the same column scan: dp[b, j] =
    min_i combine(dp[b-1, i-1], E⟨i,j⟩) with b ≤ ``n_bursts`` (static, so
    the b-loop unrolls at trace time) and the per-column budget mask applied
    before the combine, exactly like :func:`repro.core.partition._optimal_k`.
    Emits per-column (dp, parent) rows; the host walks the parents back so
    bounds reconstruct bit-identically to the numpy oracle.
    """
    TRACE_COUNT["exactk_sweep"] += 1
    del n_tasks  # the host indexes the emitted tables itself
    N = ga["e_task"].shape[0]
    R = ga["read_bytes"].shape[1]
    K = n_bursts
    i_idx = jnp.arange(N + 1)
    i_tail32 = jnp.arange(1, N + 1, dtype=jnp.int32)
    xs, e_s = _sweep_inputs(ga, cost_vec)
    q_budget = q * (1.0 + _REL) + _ABS

    def step(carry, x):
        col, dp = carry  # dp: (K+1, N) over predecessor columns 0..N-1
        j = x[0]
        col = _advance_column(col, x, i_idx, e_s, R)
        c = jnp.where(col[1 : N + 1] <= q_budget, col[1 : N + 1], jnp.inf)
        # dp rows beyond the diagonal are inf, so stale column entries at
        # i > j are masked exactly like the numpy 0:j slice.
        vals, bests = [jnp.asarray(jnp.inf)], [jnp.int32(0)]
        for b in range(1, K + 1):
            cand = jnp.maximum(dp[b - 1], c) if combine_max else dp[b - 1] + c
            mn = jnp.min(cand)
            # numpy's first-minimum argmin (+1 = burst start), as in _dp_sweep
            bests.append(jnp.min(jnp.where(cand == mn, i_tail32, N + 1)))
            vals.append(mn)
        val, bst = jnp.stack(vals), jnp.stack(bests)
        dp = dp.at[:, j].set(val, mode="drop")
        return (col, dp), (val, bst)

    dp0 = jnp.full((K + 1, N), jnp.inf).at[0, 0].set(0.0)
    _, (vals, bsts) = lax.scan(step, (jnp.zeros(N + 1), dp0), xs)
    return vals, bsts  # (N, K+1) each: dp[b, j] = vals[j-1, b]


_exactk_sweep_jit = jax.jit(
    _exactk_sweep, static_argnames=("n_bursts", "combine_max")
)


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JaxSweep:
    """Result of a jitted Q-grid sweep over one graph.

    ``dp`` / ``parent`` are the full DP tables ((nq, N+1)); ``starts[q, i]``
    is True iff some burst starts at task ``i`` under Q_max[q];
    ``e_total[q]`` is inf (and ``feasible[q]`` False) where no partition fits.
    """

    n_tasks: int
    q_values: List[Optional[float]]
    dp: np.ndarray
    parent: np.ndarray
    e_total: np.ndarray
    feasible: np.ndarray
    starts: np.ndarray

    def bounds(self, qi: int) -> Optional[List[Tuple[int, int]]]:
        """Reconstructed burst bounds for Q index ``qi`` (None = infeasible)."""
        if not self.feasible[qi]:
            return None
        s = np.flatnonzero(self.starts[qi, 1 : self.n_tasks + 1]) + 1
        ends = [int(e) for e in s[1:] - 1] + [self.n_tasks]
        return list(zip(s.tolist(), ends))

    def to_partitions(
        self, graph: TaskGraph, cost: CostModel
    ) -> List[Optional[Partition]]:
        """Full :class:`Partition` objects (numpy burst details) per Q value."""
        out: List[Optional[Partition]] = []
        for qi, q in enumerate(self.q_values):
            b = self.bounds(qi)
            if b is None:
                out.append(None)
                continue
            part = _partition_from_bounds(graph, cost, b, q)
            part.validate(graph)
            out.append(part)
        return out


AnyExport = Union[TaskGraph, GraphArrays, GraphCSRArrays]


def _as_arrays(graph: AnyExport) -> GraphArrays:
    """Coerce to the scan backend's dense export. Mixing layouts is a typed
    :class:`repro.core.engine.ExportMismatch` (a TypeError subclass), the
    same error the façade's registry capability check raises."""
    if isinstance(graph, GraphCSRArrays):
        raise ExportMismatch(
            "the scan backend consumes dense GraphArrays; pass the TaskGraph "
            "or use backend='pallas' for a GraphCSRArrays export"
        )
    return graph.to_arrays() if isinstance(graph, TaskGraph) else graph


def _as_csr(graph: AnyExport) -> GraphCSRArrays:
    """Coerce to the Pallas backend's CSR export (see :func:`_as_arrays`)."""
    if isinstance(graph, GraphArrays):
        raise ExportMismatch(
            "the pallas backend consumes GraphCSRArrays; pass the TaskGraph "
            "or use backend='scan' for a dense GraphArrays export"
        )
    return graph.to_csr_arrays() if isinstance(graph, TaskGraph) else graph


def _select_backend(
    graph: AnyExport, backend: str, objective: str = "sum"
) -> str:
    """Resolve ``backend="auto"`` per graph — delegates to the façade's
    backend registry (:func:`repro.core.engine.resolve_jit_backend`), which
    replaced the hand-rolled if-chain that used to live here. The size
    threshold stays in this module as ``_AUTO_DENSE_BYTES`` (read at call
    time, so tests can monkeypatch it)."""
    return resolve_jit_backend(graph, backend, objective)


# Serving-path upload caches (see core/_cache.py for the id+weakref idiom):
# jnp copies of an export, and re-padded CSR rows, are cached per source
# export object — TaskGraph.to_arrays()/to_csr_arrays() return a cached
# object per graph, so a serving loop hits these across requests, and the
# kernel wrapper's own id-keyed device cache (kernels/partition_sweep/ops.py)
# then sees stable objects too.
_GA_DEVICE_CACHE: dict = {}
_CSR_PAD_CACHE: dict = {}


def _padded_csr(a: GraphCSRArrays, n: int, r: int, w: int) -> GraphCSRArrays:
    if (a.n_pad, a.nnz_reads, a.nnz_writes) == (n, r, w):
        return a
    return weak_id_cache(
        _CSR_PAD_CACHE, a, (n, r, w), lambda: a.padded(n, r, w)
    )


def _ga_dict(arrays: GraphArrays) -> dict:
    return weak_id_cache(
        _GA_DEVICE_CACHE,
        arrays,
        (),
        lambda: {
            f.name: jnp.asarray(getattr(arrays, f.name))
            for f in dataclasses.fields(GraphArrays)
            if f.name != "n_tasks"
        },
    )


@functools.lru_cache(maxsize=None)
def _cost_vec(cost: CostModel):
    return jnp.asarray(cost_scalars(cost))


def _qs_array(q_values: Sequence[Optional[float]]) -> np.ndarray:
    return np.array(
        [np.inf if q is None else float(q) for q in q_values], dtype=np.float64
    )


def _empty_sweep(q_values: Sequence[Optional[float]]) -> JaxSweep:
    nq = len(q_values)
    return JaxSweep(
        n_tasks=0,
        q_values=list(q_values),
        dp=np.zeros((nq, 1)),
        parent=np.zeros((nq, 1), dtype=np.int32),
        e_total=np.zeros(nq),
        feasible=np.ones(nq, dtype=bool),
        starts=np.zeros((nq, 1), dtype=bool),
    )


def sweep_from_columns(
    n_tasks: int,
    q_values: Sequence[Optional[float]],
    mns: np.ndarray,
    bests: np.ndarray,
) -> JaxSweep:
    """Assemble a :class:`JaxSweep` from per-column DP tables.

    ``mns[j-1, q]`` = dp[q, j] and ``bests[j-1, q]`` = start of the last
    burst achieving it — the convention emitted by the Pallas sweep kernel
    (:mod:`repro.kernels.partition_sweep`) and its numpy CSR oracle. The
    numpy parent-walk here produces bit-identical bounds to the scan
    backend's in-jit reconstruction.
    """
    N, nq = mns.shape
    dp = np.concatenate([np.zeros((nq, 1)), mns.T], axis=1)
    parent = np.zeros((nq, N + 1), dtype=np.int32)
    parent[:, 1:] = bests.T
    e_total = mns[n_tasks - 1].copy() if n_tasks >= 1 else np.zeros(nq)
    feasible = np.isfinite(e_total)
    starts = np.zeros((nq, N + 1), dtype=bool)
    for qi in range(nq):
        if not feasible[qi]:
            continue
        j = n_tasks
        while j > 0:
            i = int(parent[qi, j])
            starts[qi, i] = True
            j = i - 1
    return JaxSweep(
        n_tasks=int(n_tasks),
        q_values=list(q_values),
        dp=dp,
        parent=parent,
        e_total=e_total,
        feasible=feasible,
        starts=starts,
    )


def _sweep_pallas(
    csr: GraphCSRArrays,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    interpret: Optional[bool],
) -> JaxSweep:
    from ..kernels.partition_sweep import ops as sweep_ops  # lazy: jax-heavy

    mns, bests = sweep_ops.sweep_columns(
        csr, cost, q_values, interpret=interpret
    )
    return sweep_from_columns(csr.n_tasks, q_values, mns, bests)


def sweep_jax(
    graph: AnyExport,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> JaxSweep:
    """One jitted pass: optimal E_total + bounds for every Q_max in the grid.

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       q_grid=qs, backend=...)).sweep`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition_jax.sweep_jax",
        "solve(PartitionSpec(graph=g, cost=cm, q_grid=qs)).sweep",
    )
    return _sweep_jax(graph, cost, q_values, backend=backend,
                      interpret=interpret)


def _sweep_jax(
    graph: AnyExport,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> JaxSweep:
    """Implementation behind ``sweep_jax`` and the façade's single-graph sum
    dispatch: optimal E_total + bounds for every Q_max in the grid.

    Drop-in analogue of :func:`repro.core.partition.sweep` /
    ``optimal_partition_multi`` — infeasible Q values come back with
    ``feasible == False`` instead of None. An empty graph is trivially
    feasible everywhere (matching the numpy path).

    ``backend`` selects the dense ``lax.scan`` engine, the CSR/Pallas sweep
    kernel, or lets ``"auto"`` route by dense-export size (module
    docstring); ``interpret`` is forwarded to the Pallas backend (``None``
    auto-selects interpret mode on CPU).
    """
    SOLVE_COUNT["sweep_jax"] += 1
    backend = _select_backend(graph, backend)
    if backend == "pallas":
        csr = _as_csr(graph)
        if csr.n_tasks == 0:
            return _empty_sweep(q_values)
        return _sweep_pallas(csr, cost, q_values, interpret)
    arrays = _as_arrays(graph)
    if arrays.n_tasks == 0:
        return _empty_sweep(q_values)
    with enable_x64():
        dp, parent, e_total, feasible, starts = _dp_sweep_jit(
            _ga_dict(arrays),
            jnp.asarray(arrays.n_tasks, dtype=jnp.int32),
            _cost_vec(cost),
            jnp.asarray(_qs_array(q_values)),
        )
        return JaxSweep(
            n_tasks=int(arrays.n_tasks),
            q_values=list(q_values),
            dp=np.asarray(dp),
            parent=np.asarray(parent),
            e_total=np.asarray(e_total),
            feasible=np.asarray(feasible),
            starts=np.asarray(starts),
        )


def sweep_jax_batched(
    graphs: Sequence[AnyExport],
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> List[JaxSweep]:
    """Solve many applications × many Q_max values with one compiled kernel.

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graphs=gs, cost=cm,
       q_grid=qs, backend=...)).sweeps`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition_jax.sweep_jax_batched",
        "solve(PartitionSpec(graphs=gs, cost=cm, q_grid=qs)).sweeps",
    )
    return _sweep_jax_batched(graphs, cost, q_values, backend=backend,
                              interpret=interpret)


def _sweep_jax_batched(
    graphs: Sequence[AnyExport],
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> List[JaxSweep]:
    """Implementation behind ``sweep_jax_batched`` and the façade's batched
    sum dispatch.

    Scan backend: graphs pad to a common (N, R, W) via
    :func:`stack_graph_arrays` and solve in one ``vmap``. Pallas backend:
    graphs pad to a common (N, nnz_r, nnz_w) — the padded rows are cached
    per export, and :func:`stack_csr_arrays` builds the same layout with a
    leading batch axis for vmap consumers — and the sweep kernel runs per
    graph: one compiled kernel (the padded shape is shared) applied
    sequentially, since the DP grid is already sequential per graph.
    ``backend="auto"`` resolves per member and solves each group with its
    own backend (a mixed batch of dense and CSR exports is legal), keeping
    one compilation per group.
    """
    SOLVE_COUNT["sweep_jax_batched"] += 1
    if backend == "auto":
        resolved = [_select_backend(g, "auto") for g in graphs]
        if "scan" in resolved and "pallas" in resolved:
            out: List[Optional[JaxSweep]] = [None] * len(graphs)
            for be in ("scan", "pallas"):
                idx = [k for k, r in enumerate(resolved) if r == be]
                group = _sweep_jax_batched(
                    [graphs[k] for k in idx], cost, q_values,
                    backend=be, interpret=interpret,
                )
                for k, res in zip(idx, group):
                    out[k] = res
            return out  # type: ignore[return-value]
        backend = resolved[0] if resolved else "scan"
    if backend == "pallas":
        csrs = [_as_csr(g) for g in graphs]
        out = [None] * len(csrs)
        nonempty = [(k, a) for k, a in enumerate(csrs) if a.n_tasks > 0]
        for k, a in enumerate(csrs):
            if a.n_tasks == 0:
                out[k] = _empty_sweep(q_values)
        if nonempty:
            n = max(a.n_pad for _, a in nonempty)
            r = max(max(a.nnz_reads for _, a in nonempty), 1)
            w = max(max(a.nnz_writes for _, a in nonempty), 1)
            for k, a in nonempty:
                out[k] = _sweep_pallas(
                    _padded_csr(a, n, r, w), cost, q_values, interpret
                )
        return out  # type: ignore[return-value]

    arrays = [_as_arrays(g) for g in graphs]
    nonempty = [(k, a) for k, a in enumerate(arrays) if a.n_tasks > 0]
    out = [None] * len(arrays)
    for k, a in enumerate(arrays):
        if a.n_tasks == 0:
            out[k] = _empty_sweep(q_values)
    if nonempty:
        stacked = stack_graph_arrays([a for _, a in nonempty])
        with enable_x64():
            dp, parent, e_total, feasible, starts = _dp_sweep_vmap(
                _ga_dict(stacked),
                jnp.asarray(stacked.n_tasks, dtype=jnp.int32),
                _cost_vec(cost),
                jnp.asarray(_qs_array(q_values)),
            )
        for b, (k, a) in enumerate(nonempty):
            out[k] = JaxSweep(
                n_tasks=int(a.n_tasks),
                q_values=list(q_values),
                dp=np.asarray(dp[b]),
                parent=np.asarray(parent[b]),
                e_total=np.asarray(e_total[b]),
                feasible=np.asarray(feasible[b]),
                starts=np.asarray(starts[b]),
            )
    return out  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Sharded (multi-device) sweeps — the offline DSE path
# ---------------------------------------------------------------------------
#
# The per-Q DP rows are fully independent (dp[q, j] only ever reads dp[q, ·]),
# so the Q grid is the natural shard axis for the offline design-space
# exploration: each device solves every graph for a contiguous Q chunk, and
# the gathered columns are bit-identical to the single-call solve. The pmap
# wrapper below maps the shard axis over devices; when fewer devices exist
# than shards (e.g. the fast test tier on one CPU device), the same padded
# chunks run sequentially through ``_dp_sweep_vmap`` — same decomposition,
# same bytes (asserted by tests/test_dse_shard.py on 1/2/4/8 devices).


def shard_q_grid(n_q: int, n_shards: int) -> List[Tuple[int, int]]:
    """Balanced contiguous ``[start, stop)`` chunks covering ``range(n_q)``.

    The first ``n_q % n_shards`` chunks are one element longer; ``n_shards``
    is clamped so every chunk is non-empty.
    """
    if n_q < 1:
        raise ValueError("shard_q_grid needs at least one Q point")
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n_shards = min(n_shards, n_q)
    base, rem = divmod(n_q, n_shards)
    edges = [0]
    for s in range(n_shards):
        edges.append(edges[-1] + base + (1 if s < rem else 0))
    return list(zip(edges[:-1], edges[1:]))


@functools.lru_cache(maxsize=None)
def _dp_sweep_pmap(devices: tuple):
    """pmap of the vmapped engine over a leading Q-shard axis.

    Graph arrays, task counts, and cost scalars broadcast (``in_axes=None``);
    only the ``(n_shards, q_pad)`` Q grid is mapped. Cached per device tuple
    (jax Devices are hashable); pmap itself caches per shape.
    """
    return jax.pmap(
        jax.vmap(_dp_sweep, in_axes=(0, 0, None, None)),
        in_axes=(None, None, None, 0),
        devices=devices,
    )


def _pad_q_shards(
    qs_np: np.ndarray, chunks: Sequence[Tuple[int, int]]
) -> np.ndarray:
    """Stack Q chunks into one rectangle, padding short chunks by repeating
    their last value (padded rows are solved and discarded — per-Q rows are
    independent, so they cannot perturb the real columns)."""
    q_pad = max(hi - lo for (lo, hi) in chunks)
    out = np.empty((len(chunks), q_pad), dtype=np.float64)
    for s, (lo, hi) in enumerate(chunks):
        out[s, : hi - lo] = qs_np[lo:hi]
        out[s, hi - lo :] = qs_np[hi - 1]
    return out


def _merge_sweeps(
    q_values: Sequence[Optional[float]],
    chunk_sweeps: Sequence[Sequence[JaxSweep]],
) -> List[JaxSweep]:
    """Concatenate per-chunk JaxSweeps (chunk-major) back into full-grid ones."""
    out: List[JaxSweep] = []
    for g in range(len(chunk_sweeps[0])):
        parts = [cs[g] for cs in chunk_sweeps]
        out.append(
            JaxSweep(
                n_tasks=parts[0].n_tasks,
                q_values=list(q_values),
                dp=np.concatenate([p.dp for p in parts], axis=0),
                parent=np.concatenate([p.parent for p in parts], axis=0),
                e_total=np.concatenate([p.e_total for p in parts], axis=0),
                feasible=np.concatenate([p.feasible for p in parts], axis=0),
                starts=np.concatenate([p.starts for p in parts], axis=0),
            )
        )
    return out


def sweep_jax_sharded(
    graphs: Sequence[AnyExport],
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    n_shards: int,
    devices: Optional[Sequence] = None,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> List[JaxSweep]:
    """Q-grid-sharded batched sweep: same results, many devices.

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graphs=gs, cost=cm,
       q_grid=qs, sharding=QGridSharding(n_shards, devices))).sweeps`` —
       bit-identical.
    """
    warn_legacy(
        "repro.core.partition_jax.sweep_jax_sharded",
        "solve(PartitionSpec(graphs=gs, cost=cm, q_grid=qs, "
        "sharding=QGridSharding(n_shards, devices))).sweeps",
    )
    return _sweep_jax_sharded(
        graphs, cost, q_values, n_shards=n_shards, devices=devices,
        backend=backend, interpret=interpret,
    )


def _sweep_jax_sharded(
    graphs: Sequence[AnyExport],
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    n_shards: int,
    devices: Optional[Sequence] = None,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> List[JaxSweep]:
    """Q-grid-sharded :func:`_sweep_jax_batched`: same results, many devices.

    The Q grid splits into ``n_shards`` contiguous chunks
    (:func:`shard_q_grid`); every device solves all graphs for one chunk and
    the gathered columns are **bit-identical** to the single-call batched
    solve (per-Q DP independence — the differential tier pins this).

    Scan backend: chunks pad to a common width and run under one
    ``pmap(vmap(...))`` when ``len(devices) >= n_shards``, else sequentially
    through the same vmapped kernel (one compile either way). Pallas/CSR
    backend (or a mixed ``auto`` batch): chunks run as host-side
    ``sweep_jax_batched`` calls — the kernel lanes the Q axis itself, so
    chunked solves are already bit-stable there.
    """
    SOLVE_COUNT["sweep_jax_sharded"] += 1
    qs_np = _qs_array(q_values)
    chunks = shard_q_grid(qs_np.shape[0], n_shards)
    if not graphs:
        return []

    resolved = {_select_backend(g, backend) for g in graphs}
    arrays = [_as_arrays(g) for g in graphs] if resolved == {"scan"} else None
    if arrays is None:
        # CSR/Pallas (or mixed) batch: host-sharded chunk loop.
        qs_list = list(q_values)
        chunk_sweeps = [
            _sweep_jax_batched(
                graphs, cost, qs_list[lo:hi], backend=backend,
                interpret=interpret,
            )
            for (lo, hi) in chunks
        ]
        return _merge_sweeps(q_values, chunk_sweeps)

    out: List[Optional[JaxSweep]] = [None] * len(arrays)
    nonempty = [(k, a) for k, a in enumerate(arrays) if a.n_tasks > 0]
    for k, a in enumerate(arrays):
        if a.n_tasks == 0:
            out[k] = _empty_sweep(q_values)
    if not nonempty:
        return out  # type: ignore[return-value]

    stacked = stack_graph_arrays([a for _, a in nonempty])
    qs_sh = _pad_q_shards(qs_np, chunks)
    devs = tuple(devices) if devices is not None else tuple(jax.local_devices())
    with enable_x64():
        ga = _ga_dict(stacked)
        nt = jnp.asarray(stacked.n_tasks, dtype=jnp.int32)
        cv = _cost_vec(cost)
        if len(chunks) > 1 and len(devs) >= len(chunks):
            fn = _dp_sweep_pmap(devs[: len(chunks)])
            shard_outs = fn(ga, nt, cv, jnp.asarray(qs_sh))
            per_shard = [
                tuple(np.asarray(o[s]) for o in shard_outs)
                for s in range(len(chunks))
            ]
        else:
            # Device-starved fallback: same padded chunks, same vmapped
            # kernel, run back to back — bit-identical by construction.
            per_shard = [
                tuple(
                    np.asarray(o)
                    for o in _dp_sweep_vmap(ga, nt, cv, jnp.asarray(qs_sh[s]))
                )
                for s in range(len(chunks))
            ]

    for b, (k, a) in enumerate(nonempty):
        def _cat(i: int) -> np.ndarray:
            return np.concatenate(
                [per_shard[s][i][b, : hi - lo]
                 for s, (lo, hi) in enumerate(chunks)],
                axis=0,
            )

        out[k] = JaxSweep(
            n_tasks=int(a.n_tasks),
            q_values=list(q_values),
            dp=_cat(0),
            parent=_cat(1),
            e_total=_cat(2),
            feasible=_cat(3),
            starts=_cat(4),
        )
    return out  # type: ignore[return-value]


def optimal_partition_jax(
    graph: TaskGraph,
    cost: CostModel,
    q_max: Optional[float] = None,
    *,
    backend: str = "auto",
) -> Partition:
    """Single-Q convenience mirroring the legacy ``optimal_partition``
    (raises :class:`Infeasible` when Q_max < Q_min).

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       q_max=q, backend=...)).partition()`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition_jax.optimal_partition_jax",
        "solve(PartitionSpec(graph=g, cost=cm, q_max=q)).partition()",
    )
    return _optimal_partition_jax(graph, cost, q_max, backend=backend)


def _optimal_partition_jax(
    graph: TaskGraph,
    cost: CostModel,
    q_max: Optional[float] = None,
    *,
    backend: str = "auto",
) -> Partition:
    res = _sweep_jax(graph, cost, [q_max], backend=backend)
    parts = res.to_partitions(graph, cost)
    if parts[0] is None:
        raise Infeasible(f"Q_max={q_max} admits no partition")
    return parts[0]


# ---------------------------------------------------------------------------
# Jit-backend minimax / exact-K — the façade's objective= axis (scan re-
# expressions + the Pallas kernel modes, routed per backend by the
# _q_min_jit / _optimal_k_jit dispatchers below)
# ---------------------------------------------------------------------------


def _q_min_scan(graph: AnyExport, cost: CostModel) -> float:
    """§4.4 storage minimization on the jitted scan engine — the façade's
    ``objective="minimax"`` on ``backend="scan"``. Bit-identical to the
    numpy :func:`repro.core.partition.q_min` on unroll-width graphs (the
    minimax combine is exact; only the shared columns can differ, and only
    for R > ``_UNROLL_MAX`` — same caveat as the sum DP)."""
    SOLVE_COUNT["q_min_scan"] += 1
    arrays = _as_arrays(graph)
    if arrays.n_tasks == 0:
        return 0.0
    with enable_x64():
        out = _qmin_sweep_jit(
            _ga_dict(arrays),
            jnp.asarray(arrays.n_tasks, dtype=jnp.int32),
            _cost_vec(cost),
        )
        return float(np.asarray(out))


def _optimal_k_scan(
    graph: AnyExport,
    cost: CostModel,
    n_bursts: int,
    q_max: Optional[float] = None,
    objective: str = "sum",
) -> Partition:
    """Exact-K partition on the jitted scan engine — the façade's
    ``objective="exact_k"`` on ``backend="scan"``. The emitted (dp, parent)
    tables reconstruct on the host with the same walk as the numpy
    :func:`repro.core.partition._optimal_k`, so bounds (and tie-breaks)
    match it bit-for-bit on unroll-width graphs."""
    SOLVE_COUNT["optimal_k_scan"] += 1
    if not isinstance(graph, TaskGraph):
        raise ExportMismatch(
            "exact_k needs the TaskGraph to price the reconstructed bursts; "
            "pass the graph rather than a pre-exported layout"
        )
    arrays = _as_arrays(graph)
    n = arrays.n_tasks
    if not 1 <= n_bursts <= max(n, 1):
        raise ValueError(f"n_bursts={n_bursts} out of range for {n} tasks")
    if n == 0:
        return Partition([], [], q_max)
    if objective not in ("sum", "max"):
        raise ValueError(f"objective must be 'sum' or 'max', got {objective!r}")
    q = np.inf if q_max is None else float(q_max)
    with enable_x64():
        vals, bsts = _exactk_sweep_jit(
            _ga_dict(arrays),
            jnp.asarray(n, dtype=jnp.int32),
            _cost_vec(cost),
            jnp.asarray(q, dtype=jnp.float64),
            n_bursts=int(n_bursts),
            combine_max=(objective == "max"),
        )
    vals = np.asarray(vals)  # (N, K+1): dp[b, j] = vals[j-1, b]
    bsts = np.asarray(bsts)
    if not np.isfinite(vals[n - 1, n_bursts]):
        raise Infeasible(f"no {n_bursts}-burst partition within Q_max={q_max}")
    bounds: List[Tuple[int, int]] = []
    j, b = n, n_bursts
    while j > 0:
        i = int(bsts[j - 1, b])
        bounds.append((i, j))
        j, b = i - 1, b - 1
    bounds.reverse()
    part = _partition_from_bounds(graph, cost, bounds, q_max)
    part.validate(graph)
    return part


def _q_min_pallas(
    graph: AnyExport, cost: CostModel, interpret: Optional[bool] = None
) -> float:
    """§4.4 storage minimization on the Pallas kernel's minimax mode — the
    façade's ``objective="minimax"`` on ``backend="pallas"``. The max/min
    combine is exact in float64, so Q_min is bit-identical to the numpy
    :func:`repro.core.partition.q_min` on *every* graph in interpret mode
    (no unroll-width caveat: the CSR kernel replays ColumnSweep's exact
    slot order)."""
    SOLVE_COUNT["q_min_pallas"] += 1
    csr = _as_csr(graph)
    if csr.n_tasks == 0:
        return 0.0
    from ..kernels.partition_sweep import ops as sweep_ops  # lazy: jax-heavy

    mns, _ = sweep_ops.sweep_columns(
        csr, cost, (), objective="minimax", interpret=interpret
    )
    return float(mns[csr.n_tasks - 1, 0])


def _optimal_k_pallas(
    graph: AnyExport,
    cost: CostModel,
    n_bursts: int,
    q_max: Optional[float] = None,
    objective: str = "sum",
    interpret: Optional[bool] = None,
) -> Partition:
    """Exact-K partition on the Pallas kernel's exact_k mode — the façade's
    ``objective="exact_k"`` on ``backend="pallas"``. The kernel's lane axis
    carries the burst count, so its (vals, bsts) tables have the layout of
    the scan backend's ``_exactk_sweep`` and reconstruct with the identical
    host walk — bounds and tie-breaks match the numpy
    :func:`repro.core.partition._optimal_k` bit-for-bit in interpret mode."""
    SOLVE_COUNT["optimal_k_pallas"] += 1
    if not isinstance(graph, TaskGraph):
        raise ExportMismatch(
            "exact_k needs the TaskGraph to price the reconstructed bursts; "
            "pass the graph rather than a pre-exported layout"
        )
    csr = _as_csr(graph)
    n = csr.n_tasks
    if not 1 <= n_bursts <= max(n, 1):
        raise ValueError(f"n_bursts={n_bursts} out of range for {n} tasks")
    if n == 0:
        return Partition([], [], q_max)
    if objective not in ("sum", "max"):
        raise ValueError(f"objective must be 'sum' or 'max', got {objective!r}")
    from ..kernels.partition_sweep import ops as sweep_ops  # lazy: jax-heavy

    vals, bsts = sweep_ops.sweep_columns(
        csr,
        cost,
        (q_max,),
        objective="exact_k",
        n_bursts=int(n_bursts),
        k_objective=objective,
        interpret=interpret,
    )
    if not np.isfinite(vals[n - 1, n_bursts]):
        raise Infeasible(f"no {n_bursts}-burst partition within Q_max={q_max}")
    bounds: List[Tuple[int, int]] = []
    j, b = n, n_bursts
    while j > 0:
        i = int(bsts[j - 1, b])
        bounds.append((i, j))
        j, b = i - 1, b - 1
    bounds.reverse()
    part = _partition_from_bounds(graph, cost, bounds, q_max)
    part.validate(graph)
    return part


def _q_min_jit(
    graph: AnyExport,
    cost: CostModel,
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> float:
    """Route the façade's ``objective="minimax"`` to the resolved jit
    backend (scan re-expression or Pallas kernel mode)."""
    if _select_backend(graph, backend, objective="minimax") == "pallas":
        return _q_min_pallas(graph, cost, interpret=interpret)
    return _q_min_scan(graph, cost)


def _optimal_k_jit(
    graph: AnyExport,
    cost: CostModel,
    n_bursts: int,
    q_max: Optional[float] = None,
    objective: str = "sum",
    *,
    backend: str = "auto",
    interpret: Optional[bool] = None,
) -> Partition:
    """Route the façade's ``objective="exact_k"`` to the resolved jit
    backend (scan re-expression or Pallas kernel mode)."""
    if _select_backend(graph, backend, objective="exact_k") == "pallas":
        return _optimal_k_pallas(
            graph, cost, n_bursts, q_max, objective, interpret=interpret
        )
    return _optimal_k_scan(graph, cost, n_bursts, q_max, objective)
