"""Optimal burst partitioning (paper §4.3–§4.4).

The paper reduces partitioning to a shortest path on the *state graph*:
nodes s_0..s_n, one edge (s_{i-1} → s_j) of weight E⟨i,j⟩ per candidate burst,
edges above Q_max removed. Because the state graph is a DAG whose nodes are
already in topological order, the shortest path is a simple forward DP — we
implement that as the fast path (fused with the incremental column sweep from
:mod:`.burst`), and also provide the paper's explicit Dijkstra on the state
graph plus an exhaustive search, both used to cross-validate optimality in
the test suite.

Also implemented:

* :func:`q_min` — storage minimization (§4.4): the minimax/bottleneck path,
  i.e. minimize (over partitions) the maximum single-burst cost.
* :func:`sweep` — design-space exploration over a Q_max range (paper §6.3),
  vectorized so the O(n²) column sweep is paid once for all Q values.
* :func:`single_task_partition` / :func:`whole_app_partition` — the paper's
  two baselines (§6.3), including the un-optimized state retention of the
  *Single Task* scheme (every burst saves and restores all application data).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ._deprecation import warn_legacy
from .burst import BurstDetail, ColumnSweep, burst_cost, burst_detail
from .cost import CostModel
from .graph import TaskGraph

__all__ = [
    "BUDGET_REL",
    "BUDGET_ABS",
    "within_budget",
    "Partition",
    "Infeasible",
    "optimal_partition",
    "optimal_partition_k",
    "optimal_partition_multi",
    "dijkstra_partition",
    "brute_force_partition",
    "q_min",
    "q_min_bruteforce",
    "sweep",
    "single_task_partition",
    "whole_app_partition",
]


class Infeasible(ValueError):
    """No partition satisfies the Q_max bound (Q_max < Q_min)."""


# Budget tolerance: incremental columns accumulate in a different order than
# the reference burst model, so exactly-at-budget bursts may sit a few ulp
# above Q_max. Single source of truth for every solver path — the numpy DP,
# Dijkstra, brute force, the jitted scan engine (partition_jax) and the
# CSR/Pallas sweep kernel (kernels/partition_sweep) all import these, which
# the cross-backend bit-equality guarantees depend on.
BUDGET_REL = 1e-9
BUDGET_ABS = 1e-12


def within_budget(value, q) -> bool:
    """Shared budget predicate: ``value`` fits under ``q`` up to the global
    tolerance. Every consumer comparing a cost against a capacity — solvers,
    planners (offload/remat), the plan-table lookup — must go through this
    (or the constants above) so feasibility masks agree across paths."""
    return value <= q * (1 + BUDGET_REL) + BUDGET_ABS


@dataclasses.dataclass
class Partition:
    """A partition of tasks 1..n into contiguous bursts, with full accounting.

    Figures of merit follow the paper's §6.1:
    ``e_total = e_startup_total + e_read_total + e_write_total + e_app``.
    """

    bounds: List[Tuple[int, int]]            # [(i,j)] inclusive, 1-based
    bursts: List[BurstDetail]
    q_max: Optional[float]

    @property
    def n_bursts(self) -> int:
        return len(self.bounds)

    @property
    def e_startup_total(self) -> float:
        return sum(b.e_startup for b in self.bursts)

    @property
    def e_read_total(self) -> float:
        return sum(b.e_read for b in self.bursts)

    @property
    def e_write_total(self) -> float:
        return sum(b.e_write for b in self.bursts)

    @property
    def e_app(self) -> float:
        return sum(b.e_task for b in self.bursts)

    @property
    def e_total(self) -> float:
        return sum(b.total for b in self.bursts)

    @property
    def e_overhead(self) -> float:
        """Everything that is not useful task execution."""
        return self.e_total - self.e_app

    @property
    def max_burst(self) -> float:
        return max((b.total for b in self.bursts), default=0.0)

    @property
    def transfer_bytes(self) -> int:
        return sum(b.read_bytes + b.write_bytes for b in self.bursts)

    def validate(self, graph: TaskGraph) -> None:
        """Structural sanity: contiguous cover of 1..n, budget respected."""
        expect = 1
        for (i, j) in self.bounds:
            if i != expect or j < i:
                raise AssertionError(f"non-contiguous partition at ⟨{i},{j}⟩")
            expect = j + 1
        if expect != graph.n_tasks + 1:
            raise AssertionError("partition does not cover all tasks")
        if self.q_max is not None:
            for b in self.bursts:
                if not within_budget(b.total, self.q_max):
                    raise AssertionError(
                        f"burst ⟨{b.i},{b.j}⟩ cost {b.total} exceeds Q_max {self.q_max}"
                    )

    def summary(self) -> str:
        return (
            f"bursts={self.n_bursts}  E_total={self.e_total:.6g}  "
            f"E_app={self.e_app:.6g}  overhead={self.e_overhead:.6g} "
            f"({100 * self.e_overhead / max(self.e_total, 1e-300):.3f}%)  "
            f"max_burst={self.max_burst:.6g}  bytes={self.transfer_bytes}"
        )


def _partition_from_bounds(
    graph: TaskGraph, cost: CostModel, bounds: Sequence[Tuple[int, int]],
    q_max: Optional[float],
) -> Partition:
    bursts = [burst_detail(graph, cost, i, j) for (i, j) in bounds]
    return Partition(list(bounds), bursts, q_max)


def _reconstruct(parent: np.ndarray, n: int) -> List[Tuple[int, int]]:
    bounds: List[Tuple[int, int]] = []
    j = n
    while j > 0:
        i = int(parent[j])
        bounds.append((i, j))
        j = i - 1
    bounds.reverse()
    return bounds


# ---------------------------------------------------------------------------
# Fast path: DAG-DP fused with the incremental column sweep
# ---------------------------------------------------------------------------


def optimal_partition(
    graph: TaskGraph, cost: CostModel, q_max: Optional[float] = None
) -> Partition:
    """Minimize E_total subject to every burst ≤ Q_max (None = unbounded).

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       q_max=q, backend="numpy")).partition()`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition.optimal_partition",
        "solve(PartitionSpec(graph=g, cost=cm, q_max=q, "
        "backend='numpy')).partition()",
    )
    return _optimal_multi(graph, cost, [q_max])[0]


def optimal_partition_multi(
    graph: TaskGraph, cost: CostModel, q_values: Sequence[Optional[float]]
) -> List[Optional[Partition]]:
    """One column sweep, many Q_max values (design-space exploration).

    Returns ``None`` for infeasible Q values instead of raising when more than
    one Q is requested; raises :class:`Infeasible` for a single infeasible Q.

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       q_grid=qs, backend="numpy")).partitions()`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition.optimal_partition_multi",
        "solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, "
        "backend='numpy')).partitions()",
    )
    return _optimal_multi(graph, cost, q_values)


def _optimal_multi(
    graph: TaskGraph,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    raise_single: bool = True,
) -> List[Optional[Partition]]:
    """Implementation behind ``optimal_partition*`` / ``sweep`` and the
    façade's numpy backend. ``raise_single`` keeps the historical contract
    (a lone infeasible Q raises) for the legacy shims; the façade passes
    False so infeasibility always comes back as ``None`` and surfaces as
    :class:`Infeasible` uniformly at ``Solution.partition()`` time.
    """
    n = graph.n_tasks
    nq = len(q_values)
    qs = np.array(
        [np.inf if q is None else float(q) for q in q_values], dtype=np.float64
    )
    if n == 0:
        empty = Partition([], [], None)
        return [empty for _ in q_values]

    # dp[q, b] = min cost to execute tasks 1..b;  parent[q, b] = start of last burst
    dp = np.full((nq, n + 1), np.inf, dtype=np.float64)
    dp[:, 0] = 0.0
    parent = np.zeros((nq, n + 1), dtype=np.int64)

    for j, col in zip(range(1, n + 1), ColumnSweep(graph, cost)):
        c = col[1 : j + 1]  # c[k] = E⟨k+1, j⟩, k = 0..j-1
        cand = dp[:, 0:j] + c[None, :]
        cand[c[None, :] > qs[:, None] * (1 + BUDGET_REL) + BUDGET_ABS] = np.inf
        best = np.argmin(cand, axis=1)
        dp[:, j] = cand[np.arange(nq), best]
        parent[:, j] = best + 1

    out: List[Optional[Partition]] = []
    for qi, q in enumerate(q_values):
        if not np.isfinite(dp[qi, n]):
            if nq == 1 and raise_single:
                raise Infeasible(f"Q_max={q} < Q_min={q_min(graph, cost):.6g}")
            out.append(None)
            continue
        bounds = _reconstruct(parent[qi], n)
        part = _partition_from_bounds(graph, cost, bounds, q)
        part.validate(graph)
        out.append(part)
    return out


def optimal_partition_k(
    graph: TaskGraph, cost: CostModel, n_bursts: int,
    q_max: Optional[float] = None, objective: str = "sum",
) -> Partition:
    """Optimal partition with *exactly* ``n_bursts`` bursts (beyond-paper
    extension used for pipeline-stage assignment: K stages = K bursts).

    ``objective="sum"`` minimizes E_total (the paper's objective);
    ``objective="max"`` minimizes the largest burst (pipeline bottleneck —
    the §4.4 minimax criterion with a fixed stage count).
    DP over (bursts used, last task): O(K·n²).

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       objective="exact_k", n_bursts=k, k_objective=..., q_max=q,
       backend="numpy")).partition()`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition.optimal_partition_k",
        "solve(PartitionSpec(graph=g, cost=cm, objective='exact_k', "
        "n_bursts=k, backend='numpy')).partition()",
    )
    return _optimal_k(graph, cost, n_bursts, q_max, objective)


def _optimal_k(
    graph: TaskGraph, cost: CostModel, n_bursts: int,
    q_max: Optional[float] = None, objective: str = "sum",
) -> Partition:
    n = graph.n_tasks
    if not 1 <= n_bursts <= max(n, 1):
        raise ValueError(f"n_bursts={n_bursts} out of range for {n} tasks")
    if n == 0:
        return Partition([], [], q_max)
    q = np.inf if q_max is None else float(q_max)
    combine = (lambda prev, c: prev + c) if objective == "sum" else np.maximum

    dp = np.full((n_bursts + 1, n + 1), np.inf)
    dp[0, 0] = 0.0
    parent = np.zeros((n_bursts + 1, n + 1), dtype=np.int64)
    for j, col in zip(range(1, n + 1), ColumnSweep(graph, cost)):
        c = col[1 : j + 1].copy()          # c[k] = E⟨k+1, j⟩
        c[c > q * (1 + BUDGET_REL) + BUDGET_ABS] = np.inf
        for b in range(1, n_bursts + 1):
            cand = combine(dp[b - 1, 0:j], c)
            best = int(np.argmin(cand))
            dp[b, j] = cand[best]
            parent[b, j] = best + 1
    if not np.isfinite(dp[n_bursts, n]):
        raise Infeasible(f"no {n_bursts}-burst partition within Q_max={q_max}")
    bounds: List[Tuple[int, int]] = []
    j, b = n, n_bursts
    while j > 0:
        i = int(parent[b, j])
        bounds.append((i, j))
        j, b = i - 1, b - 1
    bounds.reverse()
    part = _partition_from_bounds(graph, cost, bounds, q_max)
    part.validate(graph)
    return part


# ---------------------------------------------------------------------------
# Paper-faithful path: explicit state graph + Dijkstra (§4.3)
# ---------------------------------------------------------------------------


def dijkstra_partition(
    graph: TaskGraph, cost: CostModel, q_max: Optional[float] = None,
    prune: bool = True,
) -> Partition:
    """Dijkstra over the explicit state graph s_0..s_n.

    Implements the paper's pruning note: burst evaluation for a fixed start
    ``i`` stops as soon as the *execution-only* lower bound
    ``E_s + Σ E_task`` exceeds Q_max, since adding tasks never decreases it.
    O(n²) edges; intended for fidelity and tests (the fused DP above is the
    production path — they are asserted equal in tests/test_partition.py).
    """
    n = graph.n_tasks
    q = np.inf if q_max is None else float(q_max)
    # Edge costs from the reference burst model, with pruning.
    edges: List[List[Tuple[int, float]]] = [[] for _ in range(n + 1)]  # from s_{i-1}
    for i in range(1, n + 1):
        lower = cost.e_startup
        for j in range(i, n + 1):
            lower += graph.task(j).cost
            if prune and not within_budget(lower, q):
                break
            e = burst_cost(graph, cost, i, j)
            if within_budget(e, q):
                edges[i - 1].append((j, e))
    dist = np.full(n + 1, np.inf)
    parent = np.zeros(n + 1, dtype=np.int64)
    dist[0] = 0.0
    pq: List[Tuple[float, int]] = [(0.0, 0)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        if u == n:
            break
        for (v, w) in edges[u]:
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                parent[v] = u + 1  # burst starts at task u+1
                heapq.heappush(pq, (nd, v))
    if not np.isfinite(dist[n]):
        raise Infeasible(f"Q_max={q_max} admits no partition")
    bounds = _reconstruct(parent, n)
    part = _partition_from_bounds(graph, cost, bounds, q_max)
    part.validate(graph)
    return part


def brute_force_partition(
    graph: TaskGraph, cost: CostModel, q_max: Optional[float] = None
) -> Partition:
    """Exhaustive search over all 2^(n-1) partitions (test oracle; n ≤ 20)."""
    n = graph.n_tasks
    if n > 20:
        raise ValueError("brute force limited to n ≤ 20")
    q = np.inf if q_max is None else float(q_max)
    best: Optional[Partition] = None
    for mask in range(1 << max(n - 1, 0)):
        bounds = []
        start = 1
        for b in range(1, n):
            if mask & (1 << (b - 1)):
                bounds.append((start, b))
                start = b + 1
        bounds.append((start, n))
        part = _partition_from_bounds(graph, cost, bounds, q_max)
        if not within_budget(part.max_burst, q):
            continue
        if best is None or part.e_total < best.e_total:
            best = part
    if best is None:
        raise Infeasible(f"Q_max={q_max} admits no partition")
    return best


# ---------------------------------------------------------------------------
# Storage minimization (§4.4): minimax / bottleneck path
# ---------------------------------------------------------------------------


def q_min(graph: TaskGraph, cost: CostModel) -> float:
    """Smallest storage capacity admitting a feasible partition."""
    n = graph.n_tasks
    if n == 0:
        return 0.0
    mm = np.full(n + 1, np.inf)
    mm[0] = 0.0
    for j, col in zip(range(1, n + 1), ColumnSweep(graph, cost)):
        c = col[1 : j + 1]
        mm[j] = np.minimum(np.maximum(mm[0:j], c), np.inf).min()
    return float(mm[n])


def q_min_bruteforce(graph: TaskGraph, cost: CostModel) -> float:
    n = graph.n_tasks
    best = np.inf
    for mask in range(1 << max(n - 1, 0)):
        bounds = []
        start = 1
        for b in range(1, n):
            if mask & (1 << (b - 1)):
                bounds.append((start, b))
                start = b + 1
        bounds.append((start, n))
        worst = max(burst_cost(graph, cost, i, j) for (i, j) in bounds)
        best = min(best, worst)
    return float(best)


# ---------------------------------------------------------------------------
# Design-space exploration + baselines (§6.3)
# ---------------------------------------------------------------------------


def sweep(
    graph: TaskGraph, cost: CostModel, q_values: Sequence[float]
) -> List[Optional[Partition]]:
    """Optimal partitions across a Q_max range; None where infeasible.

    .. deprecated:: use ``repro.api.solve(PartitionSpec(graph=g, cost=cm,
       q_grid=qs, backend="numpy")).partitions()`` — bit-identical.
    """
    warn_legacy(
        "repro.core.partition.sweep",
        "solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, "
        "backend='numpy')).partitions()",
    )
    return _optimal_multi(graph, cost, list(q_values))


def single_task_partition(
    graph: TaskGraph, cost: CostModel, naive_state_retention: bool = True
) -> Partition:
    """Paper baseline: one task per burst.

    With ``naive_state_retention`` (the paper's *Single Task* scheme), state
    retention is *not* dependency-optimized: every burst restores and saves
    the entire application data region. We charge each burst a read and a
    write of ``graph.total_packet_bytes()`` (single coalesced DMA each way)
    on top of its execution cost, replacing the dependency-aware transfers.
    """
    bounds = [(i, i) for i in range(1, graph.n_tasks + 1)]
    bursts = [burst_detail(graph, cost, i, i) for (i, _) in bounds]
    if naive_state_retention:
        all_bytes = graph.total_packet_bytes()
        for b in bursts:
            b.e_read = cost.read.bytes_cost(all_bytes)
            b.e_write = cost.write.bytes_cost(all_bytes)
            b.read_bytes = all_bytes
            b.write_bytes = all_bytes
            b.loads = ["<all application data>"]
            b.stores = ["<all application data>"]
    return Partition(bounds, bursts, None)


def whole_app_partition(graph: TaskGraph, cost: CostModel) -> Partition:
    """Paper baseline: the entire application as one atomic burst."""
    n = graph.n_tasks
    return _partition_from_bounds(graph, cost, [(1, n)], None)
