"""Burst-based application execution (paper Algorithm 1).

The runtime executes a partitioned :class:`~repro.core.graph.TaskGraph`:

    while not done:
        wait for energy            (no-op here: the EMU trigger is the caller)
        start up, read burst index from NVM
        load the burst's input packets from NVM          (dependency-optimized)
        execute the burst's tasks                         (volatile memory only)
        store packets needed by later bursts to NVM
        atomically increment the burst index
        power off                                         (volatile memory cleared)

Key property (tested): bursts are **idempotent**. A power failure at any point
before the index commit loses only volatile state; re-running the burst writes
identical packets (tasks are pure functions of their declared inputs — the
Ladybirds no-side-effects contract), so recovery is simply "run again from the
committed index". This is the paper's consistency argument and the same
protocol used by the training checkpointer (`repro.checkpoint.burst_ckpt`).

Two NVM backends: in-memory (tests, fault-injection) and a directory on disk
(atomic commit via write-to-temp + ``os.replace``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile
from typing import Any, Callable, Dict, Mapping, Optional, Set, Tuple

from ..obs.metrics import METRICS
from ..obs.trace import PID_RUNTIME, TRACER
from .burst import burst_detail
from .cost import CostModel
from .graph import TaskGraph
from .partition import Partition

__all__ = [
    "PowerFailure",
    "MemoryNVM",
    "DirNVM",
    "BurstRuntime",
    "ExecutionStats",
    "execute_atomic",
    "COMMIT_STATS",
    "reset_commit_stats",
]

# Process-wide cycle/commit observability for harnesses that drive many
# runtimes at once (repro.launch.traffic): every committed burst and every
# replayed burst (a re-run of an index whose first attempt lost power before
# the commit) counts here, across all BurstRuntime instances. Consumers must
# snapshot-and-diff rather than read absolutes — see reset_commit_stats().
# Registry-backed (repro.obs.metrics) but still a plain dict to consumers.
COMMIT_STATS = METRICS.counter_dict("runtime.commit_stats", ("commits", "replays"))


def reset_commit_stats() -> None:
    """Zero the process-global commit counters (test isolation). This resets
    the *counters* only; NVM state and per-runtime ExecutionStats are
    untouched. Thin alias for the registry reset; one
    ``repro.obs.metrics.reset_all()`` covers this and every other counter."""
    COMMIT_STATS.reset()


class PowerFailure(RuntimeError):
    """Injected power loss: all volatile state is gone."""


class MemoryNVM:
    """Dict-backed NVM (tests / fault injection)."""

    def __init__(self) -> None:
        self._data: Dict[str, Any] = {}
        self._index: int = 0

    # -- packet storage --
    def write(self, name: str, value: Any) -> None:
        self._data[name] = value

    def read(self, name: str) -> Any:
        return self._data[name]

    def has(self, name: str) -> bool:
        return name in self._data

    # -- burst index (the commit point) --
    def read_index(self) -> int:
        return self._index

    def commit_index(self, index: int) -> None:
        self._index = index


class DirNVM:
    """Directory-backed NVM with atomic index commit (rename)."""

    def __init__(self, path: str) -> None:
        self.path = path
        os.makedirs(path, exist_ok=True)

    def _file(self, name: str) -> str:
        h = hashlib.sha1(name.encode()).hexdigest()[:16]
        return os.path.join(self.path, f"pkt_{h}.pkl")

    def write(self, name: str, value: Any) -> None:
        f = self._file(name)
        fd, tmp = tempfile.mkstemp(dir=self.path)
        with os.fdopen(fd, "wb") as fh:
            pickle.dump(value, fh)
        os.replace(tmp, f)

    def read(self, name: str) -> Any:
        with open(self._file(name), "rb") as fh:
            return pickle.load(fh)

    def has(self, name: str) -> bool:
        return os.path.exists(self._file(name))

    def read_index(self) -> int:
        f = os.path.join(self.path, "burst_index")
        if not os.path.exists(f):
            return 0
        with open(f) as fh:
            return int(fh.read().strip())

    def commit_index(self, index: int) -> None:
        f = os.path.join(self.path, "burst_index")
        fd, tmp = tempfile.mkstemp(dir=self.path)
        with os.fdopen(fd, "w") as fh:
            fh.write(str(index))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, f)


@dataclasses.dataclass
class ExecutionStats:
    """Observed behaviour, comparable against the model's predictions."""

    bursts_run: int = 0
    tasks_run: int = 0
    bytes_loaded: int = 0
    bytes_stored: int = 0
    energy: float = 0.0  # model-accounted energy of what actually ran
    replays: int = 0  # bursts re-entered after a pre-commit power failure


CrashHook = Callable[[int, str], None]
"""Called at (burst_index, phase) with phase ∈ {'loaded', 'executed', 'stored'};
raise :class:`PowerFailure` to simulate power loss at that point."""


class BurstRuntime:
    """Executes a partitioned task graph per Algorithm 1."""

    def __init__(
        self,
        graph: TaskGraph,
        partition: Partition,
        nvm: Optional[Any] = None,
        cost: Optional[CostModel] = None,
        crash_hook: Optional[CrashHook] = None,
        on_commit: Optional[Callable[[int], None]] = None,
    ) -> None:
        partition.validate(graph)
        self.graph = graph
        self.partition = partition
        self.nvm = nvm if nvm is not None else MemoryNVM()
        self.cost = cost
        self.crash_hook = crash_hook
        self.on_commit = on_commit
        self.stats = ExecutionStats()
        self._attempted: Set[int] = set()

    # -- one burst = one "energy quantum" --------------------------------------

    def _run_burst(self, b: int) -> None:
        # Tracing wrapper: one span per energy cycle on the runtime track,
        # with PowerFailure surfaced as an instant. Guarded on the enabled
        # flag so the disabled hot path pays one attribute check.
        if not TRACER.enabled:
            return self._run_burst_impl(b)
        with TRACER.span(
            "burst", cat="runtime", pid=PID_RUNTIME, index=b, replay=b in self._attempted
        ):
            try:
                self._run_burst_impl(b)
            except PowerFailure:
                TRACER.instant("power_failure", cat="runtime", pid=PID_RUNTIME, index=b)
                raise

    def _run_burst_impl(self, b: int) -> None:
        i, j = self.partition.bounds[b]
        g = self.graph
        detail = self.partition.bursts[b]
        volatile: Dict[str, Any] = {}
        if b in self._attempted:  # a prior attempt lost power before commit
            self.stats.replays += 1
            COMMIT_STATS["replays"] += 1
            if TRACER.enabled:
                TRACER.instant("replay", cat="runtime", pid=PID_RUNTIME, index=b)
        self._attempted.add(b)

        # DMA in: dependency-optimized load set
        load_set = self._load_set(i, j)
        for name in load_set:
            volatile[name] = self.nvm.read(name)
            self.stats.bytes_loaded += g.packets[name].nbytes
        self._maybe_crash(b, "loaded")

        # execute tasks on volatile memory only
        for k in range(i, j + 1):
            t = g.task(k)
            if t.fn is None:
                raise ValueError(f"task {t.name!r} has no runtime body (fn=None)")
            inputs = {name: volatile[name] for name in t.reads}
            outputs = t.fn(inputs)
            missing = set(t.writes) - set(outputs)
            if missing:
                raise ValueError(f"task {t.name!r} did not produce {sorted(missing)}")
            for name in t.writes:
                volatile[name] = outputs[name]
            self.stats.tasks_run += 1
        self._maybe_crash(b, "executed")

        # DMA out: packets needed by later bursts
        store_set = self._store_set(i, j)
        for name in store_set:
            self.nvm.write(name, volatile[name])
            self.stats.bytes_stored += g.packets[name].nbytes
        self._maybe_crash(b, "stored")

        # linearization point
        self.nvm.commit_index(b + 1)
        self.stats.bursts_run += 1
        COMMIT_STATS["commits"] += 1
        if TRACER.enabled:
            TRACER.instant("nvm_commit", cat="runtime", pid=PID_RUNTIME, index=b)
        if self.cost is not None:
            self.stats.energy += detail.total
        if self.on_commit is not None:
            # post-commit observer (progress streaming); runs after the
            # linearization point so a crash inside it cannot lose the burst
            self.on_commit(b)
        # power off: volatile memory is dropped on return

    def _load_set(self, i: int, j: int) -> Tuple[str, ...]:
        g = self.graph
        out = []
        seen: Set[str] = set()
        for k in range(i, j + 1):
            t = g.task(k)
            for name, lt in zip(t.reads, g.read_last_touch[k - 1]):
                if lt < i and name not in seen:
                    seen.add(name)
                    out.append(name)
        return tuple(out)

    def _store_set(self, i: int, j: int) -> Tuple[str, ...]:
        g = self.graph
        out = []
        for k in range(i, j + 1):
            for name in g.task(k).writes:
                if g.l_inf[name] > j:
                    out.append(name)
        return tuple(out)

    def _maybe_crash(self, b: int, phase: str) -> None:
        if self.crash_hook is not None:
            self.crash_hook(b, phase)

    # -- public API -------------------------------------------------------------

    def seed_inputs(self, inputs: Mapping[str, Any]) -> None:
        """Place external packets into NVM before the first activation."""
        for name, p in self.graph.packets.items():
            if p.external:
                if name not in inputs:
                    raise ValueError(f"missing external packet {name!r}")
                self.nvm.write(name, inputs[name])

    def step(self) -> bool:
        """Run exactly one uncommitted burst — one energy cycle / one system
        activation — and return True once every burst has committed.

        This is the unit the continuous-traffic harness schedules: cycles of
        many concurrent requests interleave by calling each runtime's
        ``step()`` in turn. A :class:`PowerFailure` raised mid-burst leaves
        the committed index unchanged, so the next ``step()`` replays the
        same burst (the idempotent-recovery contract). External inputs must
        already be seeded (:meth:`seed_inputs`).
        """
        b = self.nvm.read_index()
        if b >= self.partition.n_bursts:
            return True
        self._run_burst(b)
        return self.nvm.read_index() >= self.partition.n_bursts

    def run(self, inputs: Optional[Mapping[str, Any]] = None) -> Dict[str, Any]:
        """Execute to completion, resuming from the committed burst index.

        Safe to call repeatedly after :class:`PowerFailure` — each call is one
        or more "system activations".
        """
        if inputs is not None and self.nvm.read_index() == 0:
            self.seed_inputs(inputs)
        n = self.partition.n_bursts
        b = self.nvm.read_index()
        while b < n:
            self._run_burst(b)
            b = self.nvm.read_index()
        return self.outputs()

    def run_to_completion(
        self, inputs: Optional[Mapping[str, Any]] = None, max_activations: int = 10**6
    ) -> Dict[str, Any]:
        """Like :meth:`run`, but rides through injected power failures —
        models the EMU re-triggering the system when the capacitor refills."""
        first = True
        for _ in range(max_activations):
            try:
                return self.run(inputs if first else None)
            except PowerFailure:
                first = False
                continue
        raise RuntimeError("did not complete within max_activations")

    def outputs(self) -> Dict[str, Any]:
        return {
            name: self.nvm.read(name)
            for name, p in self.graph.packets.items()
            if p.keep
        }


def execute_atomic(graph: TaskGraph, inputs: Mapping[str, Any]) -> Dict[str, Any]:
    """Reference semantics: the whole application in one uninterrupted pass."""
    mem: Dict[str, Any] = dict(inputs)
    for t in graph.tasks:
        if t.fn is None:
            raise ValueError(f"task {t.name!r} has no runtime body")
        outs = t.fn({name: mem[name] for name in t.reads})
        for name in t.writes:
            mem[name] = outs[name]
    return {name: mem[name] for name, p in graph.packets.items() if p.keep}
