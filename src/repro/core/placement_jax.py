"""``lax.scan`` placement backend: the whole bandwidth × memory × Q grid
in one jitted call.

The batched re-expression of :func:`repro.core.placement.solve_placement_numpy`:

* the per-node burst DP (``S[i,b]`` over all span starts at once) becomes a
  ``lax.scan`` over the column index ``b``, carrying the full ``S`` table and
  emitting the parent column — ``vmap``-ed across every (node, q_scale) pair;
* the chain DP over node count becomes a ``lax.scan`` over ``k`` carrying
  ``dp_prev`` — ``vmap``-ed across every (link, memory, q) grid point, with
  the per-lane gathers (``S_all[:, z]``, ``memok[:, m]``, ``hop[l]``) inside
  the jit.

Bit-identity contract: this backend consumes the exact
:class:`~repro.core.placement.PlacementInputs` arrays the numpy solver does
and performs the same float64 operations in the same order (masked
candidates via the shared first-min idiom, the ``(dp + hop) + seg``
accumulation, ``x + 0.0`` for the hopless first node — exact on the
nonnegative energies involved). The full-width candidate rows here (``a`` up
to ``n`` with ``a > b`` masked to inf) are equivalent to numpy's ``a ≤ b``
slices: inf candidates never beat a finite min, and all-inf rows pick the
first index in both (``inf == inf``). tests/test_placement.py pins value
*and* parent arrays bitwise on every smoke config.

Numerics run in float64 under :func:`jax.experimental.enable_x64`, matching
:mod:`.partition_jax`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from .cost import CostModel
from .graph import TaskGraph
from .placement import (
    PLACEMENT_COUNT,
    PlacementInputs,
    PlacementSpec,
    PlacementSweep,
    _finalize,
    placement_inputs,
)

__all__ = ["solve_placement_scan"]


@functools.lru_cache(maxsize=None)
def _placement_kernel(n: int, N: int, L: int, M: int, Z: int):
    """One jitted callable per problem shape (the jit cache key)."""
    big = n + 2
    idx = jnp.arange(n + 2)
    a_arr = jnp.arange(1, n + 1)
    i_arr = jnp.arange(1, n + 1)
    j_arr = jnp.arange(n + 1)

    def inner(energy_k: jnp.ndarray, thresh: jnp.ndarray):
        """Span-start DP for one (node, q_scale): S (n+2, n+2), A parents."""
        ec = jnp.where(energy_k <= thresh, energy_k, jnp.inf)
        ec_cols = ec[1 : n + 1, 1 : n + 1].T  # row b-1 = ec[1:n+1, b]
        S0 = jnp.full((n + 2, n + 2), jnp.inf).at[idx[1:], idx[:-1]].set(0.0)

        def step(S, xs):
            b, ec_col = xs
            # cand[i, a] = S[i, a-1] + E_k⟨a,b⟩, full width with a > b masked
            cand = S[:, 0:n] + jnp.where(a_arr <= b, ec_col, jnp.inf)[None, :]
            mn = jnp.min(cand, axis=-1)
            first = jnp.min(
                jnp.where(cand == mn[:, None], a_arr, big), axis=-1
            ).astype(jnp.int32)
            init_col = jnp.where(idx == b + 1, 0.0, jnp.inf)
            new_col = jnp.where(idx <= b, mn, init_col)
            new_A = jnp.where(idx <= b, first, 0).astype(jnp.int32)
            return S.at[:, b].set(new_col), new_A

        S, A_cols = lax.scan(step, S0, (jnp.arange(1, n + 1), ec_cols))
        A = jnp.zeros((n + 2, n + 2), jnp.int32).at[:, 1 : n + 1].set(A_cols.T)
        return S, A

    def outer(S_all, memok_all, hop, li, mi, zi):
        """Chain DP for one grid point (per-lane gathers inside the jit)."""
        S_z = S_all[:, zi]        # (N, n+2, n+2)
        ok_m = memok_all[:, mi]   # (N, n+2, n+2)
        hop_l = hop[li]           # (n+1,)

        def step(dp_prev, xs):
            k, S_k, ok_k = xs
            seg = jnp.where(ok_k, S_k, jnp.inf)
            base = dp_prev[0:n] + jnp.where(k >= 2, hop_l[0:n], 0.0)
            cand = base[None, :] + seg[1 : n + 1, 0 : n + 1].T
            cand = jnp.where(i_arr[None, :] <= j_arr[:, None], cand, jnp.inf)
            mn = jnp.min(cand, axis=-1)
            first = jnp.min(
                jnp.where(cand == mn[:, None], i_arr, big), axis=-1
            ).astype(jnp.int32)
            return mn, (mn, first)

        dp0 = jnp.full(n + 1, jnp.inf).at[0].set(0.0)
        _, (dp, parent) = lax.scan(
            step, dp0, (jnp.arange(1, N + 1), S_z, ok_m)
        )
        return dp, parent

    def kernel(energy, q_thresh, mem, mem_thresh, hop_total, li_idx, mi_idx, zi_idx):
        en_rep = jnp.repeat(energy, Z, axis=0)          # (N·Z, n+2, n+2)
        S_flat, A_flat = jax.vmap(inner)(en_rep, q_thresh.reshape(-1))
        S_all = S_flat.reshape(N, Z, n + 2, n + 2)
        A_all = A_flat.reshape(N, Z, n + 2, n + 2)
        memok_all = mem[None, None] <= mem_thresh[:, :, None, None]
        dp, parent = jax.vmap(
            lambda li, mi, zi: outer(S_all, memok_all, hop_total, li, mi, zi)
        )(li_idx, mi_idx, zi_idx)
        return S_all, A_all, dp, parent

    return jax.jit(kernel)


def solve_placement_scan(
    graph: TaskGraph,
    cost: CostModel,
    spec: PlacementSpec,
    *,
    inputs: Optional[PlacementInputs] = None,
) -> PlacementSweep:
    """Solve the whole placement grid in one batched jitted call,
    bit-identical to :func:`~repro.core.placement.solve_placement_numpy`."""
    if inputs is None:
        inputs = placement_inputs(graph, cost, spec)
    PLACEMENT_COUNT["scan"] += 1
    n, N = inputs.n_tasks, inputs.n_nodes
    L, M, Z = inputs.grid_shape
    # C-order lane indices over the (link, memory, q) grid
    li_idx = np.repeat(np.arange(L), M * Z)
    mi_idx = np.tile(np.repeat(np.arange(M), Z), L)
    zi_idx = np.tile(np.arange(Z), L * M)
    kernel = _placement_kernel(n, N, L, M, Z)
    with enable_x64():
        S_all, A_all, dp, parent = kernel(
            jnp.asarray(inputs.energy),
            jnp.asarray(inputs.q_thresh),
            jnp.asarray(inputs.mem),
            jnp.asarray(inputs.mem_thresh),
            jnp.asarray(inputs.hop_total),
            jnp.asarray(li_idx),
            jnp.asarray(mi_idx),
            jnp.asarray(zi_idx),
        )
        inner_S = np.asarray(S_all)
        inner_A = np.asarray(A_all)
        outer_dp = np.asarray(dp).reshape(L, M, Z, N, n + 1)
        outer_parent = np.asarray(parent).reshape(L, M, Z, N, n + 1)
    e_total, k_used = _finalize(outer_dp, n, N)
    return PlacementSweep(
        inputs=inputs,
        backend="scan",
        e_total=e_total,
        k_used=k_used,
        outer_dp=outer_dp,
        outer_parent=outer_parent,
        inner_S=inner_S,
        inner_A=inner_A,
    )
