"""Julienning: memory-aware partitioning of sequential task graphs.

The paper's contribution (Gomez et al., 2021) as a composable library:
specification model (:mod:`.graph`), burst cost model (:mod:`.burst`),
optimal partitioning + storage minimization (:mod:`.partition`), the
burst execution runtime (:mod:`.runtime`), and the TPU-side applications
of the same optimizer (:mod:`.remat_policy`, :mod:`.offload`,
:mod:`.pipeline`).
"""

from .burst import BurstDetail, ColumnSweep, burst_cost, burst_detail
from .cost import (
    CostModel,
    LinearTransfer,
    PAPER_FRAM_MODEL,
    cost_scalars,
    paper_fram_model,
    tpu_host_offload_model,
    tpu_pipeline_model,
    tpu_remat_model,
)
from .engine import (
    Engine,
    EngineError,
    ExportMismatch,
    PartitionSpec,
    QGridSharding,
    Solution,
    SpecError,
    UnsupportedObjective,
    backend_names,
    default_engine,
    register_backend,
)
from .graph import (
    GraphArrays,
    GraphBuilder,
    GraphCSRArrays,
    Packet,
    Task,
    TaskGraph,
    dense_export_nbytes,
    stack_csr_arrays,
    stack_graph_arrays,
)
from .layer_profile import (
    build_activation_graph,
    default_cost_model,
    external_inputs,
    lower_config,
    lower_zoo,
    memory_cost_model,
    profile_model,
)
from .partition import (
    BUDGET_ABS,
    BUDGET_REL,
    Infeasible,
    Partition,
    brute_force_partition,
    dijkstra_partition,
    optimal_partition,
    optimal_partition_k,
    optimal_partition_multi,
    q_min,
    q_min_bruteforce,
    single_task_partition,
    sweep,
    whole_app_partition,
    within_budget,
)
from .placement import (
    PLACEMENT_TABLE_VERSION,
    LinkModel,
    NodeSpec,
    PlacementError,
    PlacementPlan,
    PlacementSpec,
    PlacementSweep,
    PlacementTable,
    exhaustive_placement,
    placement_inputs,
    solve_placement_numpy,
)
from .plan_table import (
    PLAN_TABLE_VERSION,
    PlanTable,
    PlanTableError,
    SegmentPlan,
    StaleTableError,
    UnknownBucketError,
    build_plan_table,
    config_fingerprint,
    extend_plan_table,
    probe_plan_table,
    shard_plan_table,
)
from .runtime import (
    COMMIT_STATS,
    BurstRuntime,
    DirNVM,
    ExecutionStats,
    MemoryNVM,
    PowerFailure,
    execute_atomic,
    reset_commit_stats,
)

__all__ = [k for k in dir() if not k.startswith("_")]

# The jitted partitioning engine imports jax; load it lazily (PEP 562) so
# pure-numpy analysis (`import repro.core`) stays jax-free.
_JAX_EXPORTS = (
    "JaxSweep",
    "sweep_jax",
    "sweep_jax_batched",
    "sweep_jax_sharded",
    "shard_q_grid",
    "optimal_partition_jax",
    "sweep_from_columns",
)
__all__ += list(_JAX_EXPORTS)


def __getattr__(name):
    if name in _JAX_EXPORTS:
        from . import partition_jax

        return getattr(partition_jax, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
