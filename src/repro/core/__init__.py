"""Julienning: memory-aware partitioning of sequential task graphs.

The paper's contribution (Gomez et al., 2021) as a composable library:
specification model (:mod:`.graph`), burst cost model (:mod:`.burst`),
optimal partitioning + storage minimization (:mod:`.partition`), the
burst execution runtime (:mod:`.runtime`), and the TPU-side applications
of the same optimizer (:mod:`.remat_policy`, :mod:`.offload`,
:mod:`.pipeline`).
"""

from .burst import BurstDetail, ColumnSweep, burst_cost, burst_detail
from .cost import (
    CostModel,
    LinearTransfer,
    PAPER_FRAM_MODEL,
    paper_fram_model,
    tpu_host_offload_model,
    tpu_pipeline_model,
    tpu_remat_model,
)
from .graph import GraphBuilder, Packet, Task, TaskGraph
from .partition import (
    Infeasible,
    Partition,
    brute_force_partition,
    dijkstra_partition,
    optimal_partition,
    optimal_partition_k,
    optimal_partition_multi,
    q_min,
    q_min_bruteforce,
    single_task_partition,
    sweep,
    whole_app_partition,
)
from .runtime import (
    BurstRuntime,
    DirNVM,
    ExecutionStats,
    MemoryNVM,
    PowerFailure,
    execute_atomic,
)

__all__ = [k for k in dir() if not k.startswith("_")]
