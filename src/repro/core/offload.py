"""Activation-offload scheduling via Julienning (DESIGN.md §2, item 2).

Volatile memory = HBM, NVM = host DRAM over PCIe — the paper's memory
hierarchy, one level up. The *same* activation graph is partitioned under
the **memory cost model** (burst "energy" = activation working set in
bytes, Q_max = the HBM activation budget), then the resulting partition is
*priced* under the **time cost model** (PCIe's ``c0 + bytes/bw``, the exact
shape of the paper's FRAM model). Sweeping Q_max reproduces the paper's
design-space exploration for HBM: the Pareto front of activation budget vs
offload overhead, with Q_min (§4.4) the smallest feasible budget.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from ..configs.base import ModelConfig
from .cost import tpu_host_offload_model
from .layer_profile import build_activation_graph, memory_cost_model, profile_model
from .partition import Infeasible, Partition, optimal_partition, q_min

__all__ = ["OffloadPlan", "plan_offload", "min_activation_budget"]


@dataclasses.dataclass
class OffloadPlan:
    cfg_name: str
    hbm_budget_bytes: float
    bounds: List[Tuple[int, int]]
    segment_peak_bytes: List[float]      # working set per segment (≤ budget)
    offload_bytes: List[int]             # bytes pushed to host at each boundary
    pcie_seconds: float                  # total offload+reload time
    compute_seconds: float               # total compute time (for overlap check)

    @property
    def n_segments(self) -> int:
        return len(self.bounds)

    @property
    def overhead_fraction(self) -> float:
        """PCIe time / compute time — < 1 means fully overlappable."""
        return self.pcie_seconds / max(self.compute_seconds, 1e-30)

    def summary(self) -> str:
        return (f"{self.cfg_name}: {self.n_segments} segments under "
                f"{self.hbm_budget_bytes / 1e9:.2f} GB, offload "
                f"{sum(self.offload_bytes) / 1e9:.2f} GB, PCIe "
                f"{self.pcie_seconds * 1e3:.2f} ms "
                f"({100 * self.overhead_fraction:.1f}% of compute)")


def min_activation_budget(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Q_min (§4.4) under the memory model: the smallest HBM activation
    budget for which any offload segmentation exists."""
    profiles, long_lived = profile_model(cfg, batch, seq)
    graph = build_activation_graph(profiles, long_lived, kind="memory")
    return q_min(graph, memory_cost_model())


def plan_offload(cfg: ModelConfig, batch: int, seq: int,
                 hbm_budget_bytes: float) -> OffloadPlan:
    profiles, long_lived = profile_model(cfg, batch, seq)
    mem_graph = build_activation_graph(profiles, long_lived, kind="memory")
    part: Partition = optimal_partition(mem_graph, memory_cost_model(),
                                        hbm_budget_bytes)

    # price the chosen partition under the PCIe time model
    pcie = tpu_host_offload_model()
    pcie_s = 0.0
    offload_bytes = []
    for b in part.bursts:
        w = sum(mem_graph.packets[n].nbytes for n in b.stores)
        r = sum(mem_graph.packets[n].nbytes for n in b.loads)
        pcie_s += (pcie.write.bytes_cost(w) if w else 0.0)
        pcie_s += (pcie.read.bytes_cost(r) if r else 0.0)
        offload_bytes.append(w)
    from .cost import PEAK_FLOPS

    compute_s = sum(p.flops for p in profiles) / PEAK_FLOPS
    return OffloadPlan(
        cfg_name=cfg.name,
        hbm_budget_bytes=hbm_budget_bytes,
        bounds=part.bounds,
        segment_peak_bytes=[b.total for b in part.bursts],
        offload_bytes=offload_bytes,
        pcie_seconds=pcie_s,
        compute_seconds=compute_s,
    )
