"""Activation-offload scheduling via Julienning (DESIGN.md §2, item 2).

Volatile memory = HBM, NVM = host DRAM over PCIe — the paper's memory
hierarchy, one level up. The *same* activation graph is partitioned under
the **memory cost model** (burst "energy" = activation working set in
bytes, Q_max = the HBM activation budget), then the resulting partition is
*priced* under the **time cost model** (PCIe's ``c0 + bytes/bw``, the exact
shape of the paper's FRAM model). Sweeping Q_max reproduces the paper's
design-space exploration for HBM: the Pareto front of activation budget vs
offload overhead, with Q_min (§4.4) the smallest feasible budget.

Solve and pricing are split so the serving path can reuse the pricing:
:func:`plan_offload` solves then prices; :func:`price_offload_bounds`
prices *given* segment bounds (e.g. the cut points stored in a
:class:`repro.core.plan_table.PlanTable`) without any DP solve. Budget
feasibility uses the global tolerance from :mod:`.partition`
(``BUDGET_REL``/``BUDGET_ABS``) — the same mask every solver applies.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from ..configs.base import ModelConfig
from .burst import burst_detail
from .cost import PEAK_FLOPS, tpu_host_offload_model
from .graph import TaskGraph
from .layer_profile import (
    LayerProfile,
    build_activation_graph,
    memory_cost_model,
    profile_model,
)
from .engine import PartitionSpec, default_engine
from .partition import Infeasible, Partition, q_min, within_budget

__all__ = ["OffloadPlan", "plan_offload", "price_offload_bounds",
           "min_activation_budget"]


@dataclasses.dataclass
class OffloadPlan:
    cfg_name: str
    hbm_budget_bytes: float
    bounds: List[Tuple[int, int]]
    segment_peak_bytes: List[float]      # working set per segment (≤ budget)
    offload_bytes: List[int]             # bytes pushed to host at each boundary
    pcie_seconds: float                  # total offload+reload time
    compute_seconds: float               # total compute time (for overlap check)

    @property
    def n_segments(self) -> int:
        return len(self.bounds)

    @property
    def overhead_fraction(self) -> float:
        """PCIe time / compute time — < 1 means fully overlappable."""
        return self.pcie_seconds / max(self.compute_seconds, 1e-30)

    def summary(self) -> str:
        return (f"{self.cfg_name}: {self.n_segments} segments under "
                f"{self.hbm_budget_bytes / 1e9:.2f} GB, offload "
                f"{sum(self.offload_bytes) / 1e9:.2f} GB, PCIe "
                f"{self.pcie_seconds * 1e3:.2f} ms "
                f"({100 * self.overhead_fraction:.1f}% of compute)")


def min_activation_budget(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Q_min (§4.4) under the memory model: the smallest HBM activation
    budget for which any offload segmentation exists."""
    profiles, long_lived = profile_model(cfg, batch, seq)
    graph = build_activation_graph(profiles, long_lived, kind="memory")
    return q_min(graph, memory_cost_model())


def price_offload_bounds(
    cfg_name: str,
    profiles: List[LayerProfile],
    mem_graph: TaskGraph,
    bounds: Sequence[Tuple[int, int]],
    hbm_budget_bytes: float,
) -> OffloadPlan:
    """Price a given segmentation under the PCIe time model — no DP solve.

    ``bounds`` may come from :func:`plan_offload`'s own solve or from a
    precomputed plan table; each segment's memory working set is validated
    against the budget with the shared solver tolerance, so a plan that a
    solver would accept prices here without spurious Infeasible flips.
    """
    mem = memory_cost_model()
    bursts = [burst_detail(mem_graph, mem, i, j) for (i, j) in bounds]
    for b in bursts:
        if not within_budget(b.total, hbm_budget_bytes):
            raise Infeasible(
                f"{cfg_name}: segment ⟨{b.i},{b.j}⟩ working set {b.total:.4g} B "
                f"exceeds the {hbm_budget_bytes:.4g} B HBM budget"
            )

    # price the segmentation under the PCIe time model
    pcie = tpu_host_offload_model()
    pcie_s = 0.0
    offload_bytes = []
    for b in bursts:
        w = sum(mem_graph.packets[n].nbytes for n in b.stores)
        r = sum(mem_graph.packets[n].nbytes for n in b.loads)
        pcie_s += (pcie.write.bytes_cost(w) if w else 0.0)
        pcie_s += (pcie.read.bytes_cost(r) if r else 0.0)
        offload_bytes.append(w)

    compute_s = sum(p.flops for p in profiles) / PEAK_FLOPS
    return OffloadPlan(
        cfg_name=cfg_name,
        hbm_budget_bytes=hbm_budget_bytes,
        bounds=list(bounds),
        segment_peak_bytes=[b.total for b in bursts],
        offload_bytes=offload_bytes,
        pcie_seconds=pcie_s,
        compute_seconds=compute_s,
    )


def plan_offload(cfg: ModelConfig, batch: int, seq: int,
                 hbm_budget_bytes: float) -> OffloadPlan:
    profiles, long_lived = profile_model(cfg, batch, seq)
    mem_graph = build_activation_graph(profiles, long_lived, kind="memory")
    part: Partition = default_engine().solve(PartitionSpec(
        graph=mem_graph, cost=memory_cost_model(), q_max=hbm_budget_bytes,
        backend="numpy",
    )).partition()
    return price_offload_bounds(
        cfg.name, profiles, mem_graph, part.bounds, hbm_budget_bytes
    )
