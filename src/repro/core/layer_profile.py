"""Analytic per-layer profiles of the model zoo → Ladybirds task graphs.

Turns a ModelConfig + (batch, seq) into the paper's specification model:
one task per layer, packets = boundary activations plus the *long-lived*
packets that make dependency-aware partitioning interesting —

* whisper: the encoder output, read by **every** decoder layer (its l_∞ is
  the last decoder layer, the exact analogue of the paper's image packet
  read by ~7300 CNN window tasks);
* llama-vision: the vision embeddings, read by every 5th layer;
* zamba2: the token embeddings, concat-read by all 13 shared-attention
  applications.

Two cost interpretations of the same graph (DESIGN.md §2):

* ``time_cost(profile)``  — E_task = seconds of compute at peak; transfers
  priced by the chosen CostModel (ICI hop, PCIe offload, recompute).
* ``memory_cost(profile)`` — E_task = transient working bytes; transfers =
  packet bytes; E_s = 0. A burst's "energy" is then its activation working
  set, so Q_max bounds per-segment memory and Q_min is the smallest
  feasible activation budget (§4.4 applied to HBM).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from ..configs.base import ModelConfig
from .cost import PEAK_FLOPS, CostModel, LinearTransfer
from .graph import GraphBuilder, TaskGraph

__all__ = ["LayerProfile", "profile_model", "build_activation_graph",
           "time_cost_model", "memory_cost_model", "default_cost_model",
           "lower_config", "lower_zoo", "external_inputs"]

BYTES_ACT = 2  # bf16 activations


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    name: str
    flops: float              # forward FLOPs of this layer
    weight_bytes: int         # parameter bytes (bf16 compute copy)
    act_bytes: int            # boundary activation it produces
    work_bytes: int           # transient working set while executing
    extra_reads: Tuple[str, ...] = ()  # long-lived packet names


def _attn_flops(cfg: ModelConfig, B: int, S: int, causal: bool = True) -> float:
    proj = 2 * B * S * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd \
        + 2 * B * S * cfg.n_heads * cfg.hd * cfg.d_model
    sc = 4 * B * S * S * cfg.n_heads * cfg.hd * (0.5 if causal else 1.0)
    return proj + sc


def _mlp_flops(cfg: ModelConfig, B: int, S: int, ff: Optional[int] = None,
               gated: bool = True) -> float:
    f = ff or cfg.d_ff
    return (3 if gated else 2) * 2 * B * S * cfg.d_model * f


def profile_model(cfg: ModelConfig, B: int, S: int) -> Tuple[
        List[LayerProfile], Dict[str, int]]:
    """Returns (per-layer profiles in execution order, long-lived packets)."""
    d = cfg.d_model
    act = B * S * d * BYTES_ACT
    long_lived: Dict[str, int] = {}
    out: List[LayerProfile] = []

    def attn_w() -> int:
        return (d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
                + cfg.n_heads * cfg.hd * d) * 2

    if cfg.family in ("dense", "vlm"):
        per_w = attn_w() + 3 * d * cfg.d_ff * 2
        fl = _attn_flops(cfg, B, S) + _mlp_flops(cfg, B, S)
        for i in range(cfg.n_layers):
            extra = ()
            flops_i, w_i = fl, per_w
            if cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0:
                long_lived.setdefault(
                    "vision", B * cfg.n_vision_tokens * d * BYTES_ACT)
                extra = ("vision",)
                flops_i += (2 * B * S * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
                            + 4 * B * S * cfg.n_vision_tokens * cfg.n_heads * cfg.hd)
                w_i += attn_w()
            out.append(LayerProfile(f"layer{i}", flops_i, w_i, act,
                                    4 * act, extra))
    elif cfg.family == "moe":
        m = cfg.moe
        assert m is not None
        per_w = attn_w() + m.n_experts * 3 * d * m.d_ff_expert * 2
        fl = _attn_flops(cfg, B, S) + m.top_k * _mlp_flops(cfg, B, S, m.d_ff_expert)
        for i in range(cfg.n_layers):
            out.append(LayerProfile(f"layer{i}", fl, per_w, act, 6 * act))
    elif cfg.family == "encdec":
        F = cfg.n_audio_frames
        enc_act = B * F * d * BYTES_ACT
        enc_fl = _attn_flops(cfg, B, F, causal=False) + _mlp_flops(cfg, B, F, gated=False)
        enc_w = attn_w() + 2 * d * cfg.d_ff * 2
        for i in range(cfg.n_encoder_layers):
            out.append(LayerProfile(f"enc{i}", enc_fl, enc_w, enc_act, 4 * enc_act))
        long_lived["enc_out"] = enc_act
        dec_fl = (_attn_flops(cfg, B, S)
                  + 2 * B * S * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
                  + 4 * B * S * F * cfg.n_heads * cfg.hd
                  + _mlp_flops(cfg, B, S, gated=False))
        dec_w = 2 * attn_w() + 2 * d * cfg.d_ff * 2
        for i in range(cfg.n_layers):
            out.append(LayerProfile(f"dec{i}", dec_fl, dec_w, act, 4 * act,
                                    ("enc_out",)))
    elif cfg.family == "ssm":  # xlstm
        d_in = 2 * d
        m_w = (2 * d * d_in + d_in * d + 2 * d * cfg.n_heads + d * d_in) * 2
        m_fl = 2 * B * S * d * (3 * d_in + d_in) + 4 * B * S * d_in * (d_in // cfg.n_heads)
        s_w = (4 * d * d + d * d + 3 * d * (4 * d // 3)) * 2
        s_fl = 2 * B * S * (4 * d * d + d * d + 2 * d * (4 * d // 3))
        for i in range(cfg.n_layers):
            slstm = cfg.slstm_every and (i + 1) % cfg.slstm_every == 0
            out.append(LayerProfile(
                f"{'slstm' if slstm else 'mlstm'}{i}",
                s_fl if slstm else m_fl, s_w if slstm else m_w, act, 4 * act))
    elif cfg.family == "hybrid":  # zamba2
        d_in = cfg.ssm_expand * d
        H = d_in // cfg.ssm_headdim
        m_w = (d * (2 * d_in + 2 * cfg.ssm_state + H) + d_in * d) * 2
        m_fl = 2 * B * S * d * (2 * d_in + 2 * cfg.ssm_state + H) \
            + 2 * B * S * d_in * d + 6 * B * S * d_in * cfg.ssm_state
        long_lived["embed0"] = act
        shared_w = (2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
                    + cfg.n_heads * cfg.hd * d + 3 * d * cfg.d_ff) * 2
        shared_fl = (2 * B * S * 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
                     + 2 * B * S * S * cfg.n_heads * cfg.hd
                     + _mlp_flops(cfg, B, S))
        n_groups = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        g = 0
        for i in range(cfg.n_layers):
            out.append(LayerProfile(f"mamba{i}", m_fl, m_w, act, 4 * act))
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0 and g < n_groups:
                g += 1
                out.append(LayerProfile(f"shared_attn{g}", shared_fl, shared_w,
                                        act, 4 * act, ("embed0",)))
    else:
        raise ValueError(cfg.family)
    return out, long_lived


def build_activation_graph(
    profiles: List[LayerProfile], long_lived: Dict[str, int],
    kind: str = "time",
) -> TaskGraph:
    """The paper's task graph: task i reads act_{i-1} (+long-lived packets),
    writes act_i. ``kind`` selects the E_task interpretation."""
    b = GraphBuilder()
    for name, nbytes in long_lived.items():
        b.packet(name, nbytes, external=True)
    prev = None
    for i, lp in enumerate(profiles):
        pkt = b.packet(f"act{i}", lp.act_bytes, keep=(i == len(profiles) - 1))
        # memory kind: E_task = the layer's activation retained across the
        # segment's backward sweep — additive over a segment, so a burst's
        # "energy" is its backward working set (saved boundaries are the
        # stores, accounted separately by the planners).
        cost = lp.flops / PEAK_FLOPS if kind == "time" else float(lp.act_bytes)
        reads = ((prev,) if prev else ()) + lp.extra_reads
        b.task(lp.name, reads=reads, writes=(pkt,), cost=cost)
        prev = pkt
    return b.build()


def _attach_bodies(
    profiles: List[LayerProfile], seed: int
) -> Dict[str, Callable[[Mapping[str, object]], Dict[str, object]]]:
    """Deterministic numeric bodies for a lowered graph (tests/fault injection).

    Each layer body is a pure function of its declared inputs — a fixed random
    projection of the input means through tanh — so partitioned execution must
    reproduce atomic execution bit-for-bit (the Ladybirds no-side-effects
    contract). Values are small (8,) float64 vectors: packet ``nbytes`` is cost
    metadata, the runtime stores whatever the body returns.
    """
    rng = np.random.RandomState(seed)
    fns: Dict[str, Callable[[Mapping[str, object]], Dict[str, object]]] = {}
    for i, lp in enumerate(profiles):
        w = rng.randn(8)
        b = float(rng.randn())
        out_name = f"act{i}"

        def fn(inp, w=w, b=b, out_name=out_name):
            acc = b
            for name in sorted(inp):
                acc += float(np.mean(np.asarray(inp[name], dtype=np.float64)))
            return {out_name: np.tanh(w * acc)}

        fns[lp.name] = fn
    return fns


def external_inputs(graph: TaskGraph, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic values for every external packet of a lowered graph."""
    rng = np.random.RandomState(seed + 1)
    return {
        name: rng.randn(8)
        for name, p in sorted(graph.packets.items())
        if p.external
    }


def lower_config(
    cfg: Union[ModelConfig, str],
    batch: int = 1,
    seq: int = 256,
    kind: str = "time",
    with_fns: bool = False,
    seed: int = 0,
) -> TaskGraph:
    """Lower a model-zoo config to a partitionable :class:`TaskGraph`.

    Accepts a :class:`ModelConfig` or a registry name. ``kind`` selects the
    E_task interpretation (``"time"`` seconds-at-peak / ``"memory"`` working
    bytes, see module docstring); ``with_fns`` attaches runnable bodies so
    the graph executes under :class:`repro.core.runtime.BurstRuntime`.
    """
    if isinstance(cfg, str):
        from ..configs import get_config

        cfg = get_config(cfg)
    profiles, long_lived = profile_model(cfg, batch, seq)
    graph = build_activation_graph(profiles, long_lived, kind=kind)
    if with_fns:
        fns = _attach_bodies(profiles, seed)
        tasks = [
            dataclasses.replace(t, fn=fns[t.name]) for t in graph.tasks
        ]
        graph = TaskGraph(tasks, graph.packets.values())
    return graph


def lower_zoo(
    batch: int = 1,
    seq: int = 256,
    kind: str = "time",
    with_fns: bool = False,
    configs: Optional[Mapping[str, ModelConfig]] = None,
) -> Dict[str, TaskGraph]:
    """Lower every registered architecture (name → TaskGraph), in one call.

    This is what opens the full model zoo as partitioning workloads: the
    resulting graphs batch together through
    :func:`repro.core.partition_jax.sweep_jax_batched`.
    """
    if configs is None:
        from ..configs import REGISTRY

        configs = REGISTRY
    return {
        name: lower_config(cfg, batch, seq, kind=kind, with_fns=with_fns)
        for name, cfg in sorted(configs.items())
    }


def time_cost_model(transfer: CostModel) -> CostModel:
    """Seconds everywhere: E_task already in seconds, transfers per ``transfer``."""
    return transfer


def memory_cost_model() -> CostModel:
    """Bytes everywhere: burst 'energy' = its activation working set."""
    return CostModel(
        e_startup=0.0,
        read=LinearTransfer(c0=0.0, c1=1.0),
        write=LinearTransfer(c0=0.0, c1=1.0),
        name="hbm-bytes",
    )


def analytical_cost_model(kind: str) -> CostModel:
    """The datasheet cost model per activation-graph ``kind`` (``"time"``
    prices PCIe offload transfers, ``"memory"`` counts working bytes) —
    what :func:`default_cost_model` falls back to when no measured
    calibration is installed."""
    if kind == "memory":
        return memory_cost_model()
    if kind == "time":
        from .cost import tpu_host_offload_model

        return tpu_host_offload_model()
    raise ValueError(f"unknown graph kind {kind!r}; 'time' or 'memory'")


def default_cost_model(kind: str) -> CostModel:
    """The standard cost model per activation-graph ``kind`` — the single
    default shared by the façade's config-lowered specs and the plan-table
    builders. When a measured calibration has been installed for this kind
    (:func:`repro.core.calibration.install_measured_default`), its
    mean-priced materialization takes precedence over the analytical model;
    a clean calibration loop materializes the analytical model itself, so
    fingerprints only move when the measurements did."""
    from .calibration import measured_default

    measured = measured_default(kind)
    if measured is not None:
        return measured.cost_model()
    return analytical_cost_model(kind)
