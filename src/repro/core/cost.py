"""Cost models for burst execution (paper §4.1).

The optimizer is unit-agnostic: "energy" is any additive scalar. The paper's
instance uses Joules measured on the FRAM/LPC54102 prototype; the TPU
instances use seconds (time-as-energy) with bytes moved across a memory
boundary priced by link bandwidth. See DESIGN.md §2 for the mapping.

All transfer models are linear with a fixed initiation term:
``E(p) = c0 * p.c0_weight + c1 * p.nbytes`` — exactly the paper's
``E_r(p) = 1.3 µJ + |p| * 7.6 nJ/B`` shape.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .graph import Packet

__all__ = [
    "LinearTransfer",
    "CostModel",
    "cost_scalars",
    "PAPER_FRAM_MODEL",
    "paper_fram_model",
    "tpu_host_offload_model",
    "tpu_remat_model",
    "tpu_pipeline_model",
]


@dataclasses.dataclass(frozen=True)
class LinearTransfer:
    """E(p) = c0 * weight(p) + c1 * nbytes(p)."""

    c0: float  # fixed initiation cost (per DMA batch; amortized via c0_weight)
    c1: float  # per-byte cost

    def __call__(self, p: Packet) -> float:
        return self.c0 * p.c0_weight + self.c1 * p.nbytes

    def bytes_cost(self, nbytes: int, c0_weight: float = 1.0) -> float:
        return self.c0 * c0_weight + self.c1 * nbytes


@dataclasses.dataclass(frozen=True)
class CostModel:
    """E_s + E_r(p)/E_w(p) per paper §4.1."""

    e_startup: float
    read: LinearTransfer
    write: LinearTransfer
    name: str = "cost-model"

    def e_r(self, p: Packet) -> float:
        return self.read(p)

    def e_w(self, p: Packet) -> float:
        return self.write(p)


def cost_scalars(cost: CostModel) -> np.ndarray:
    """(E_s, read c0, read c1, write c0, write c1) as a float64 vector.

    The array form the jitted engines consume (see
    :mod:`repro.core.partition_jax` and
    :mod:`repro.kernels.partition_sweep`): graph exports stay
    cost-model-independent and the five scalars are applied at solve time.
    """
    return np.array(
        [cost.e_startup, cost.read.c0, cost.read.c1, cost.write.c0, cost.write.c1],
        dtype=np.float64,
    )


# ---------------------------------------------------------------------------
# Paper-faithful instance (§6.2): LPC54102 + external Cypress FRAM.
# Units: Joules.
# ---------------------------------------------------------------------------

PAPER_FRAM_MODEL = CostModel(
    e_startup=9e-6,                            # E_s = 9 µJ measured boot cost
    read=LinearTransfer(c0=1.3e-6, c1=7.6e-9),  # E_r(p) = 1.3 µJ + |p| · 7.6 nJ/B
    write=LinearTransfer(c0=0.9e-6, c1=6.2e-9),  # E_w(p) = 0.9 µJ + |p| · 6.2 nJ/B
    name="paper-fram",
)


def paper_fram_model() -> CostModel:
    return PAPER_FRAM_MODEL


# ---------------------------------------------------------------------------
# TPU instances. Units: seconds. "Energy" = time, "NVM" = the far memory tier.
# Hardware constants from the assignment: TPU v5e-class chip,
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI. Host DMA (PCIe gen4-ish)
# ~25 GB/s effective per direction with ~5 µs initiation.
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
PCIE_BW = 25e9
DMA_INIT_S = 5e-6
LAUNCH_S = 10e-6  # per-segment dispatch/bookkeeping overhead


def tpu_host_offload_model(
    pcie_bw: float = PCIE_BW,
    dma_init_s: float = DMA_INIT_S,
    launch_s: float = LAUNCH_S,
) -> CostModel:
    """Activation offload: volatile = HBM, NVM = host DRAM over PCIe."""
    return CostModel(
        e_startup=launch_s,
        read=LinearTransfer(c0=dma_init_s, c1=1.0 / pcie_bw),
        write=LinearTransfer(c0=dma_init_s, c1=1.0 / pcie_bw),
        name="tpu-host-offload",
    )


def tpu_remat_model(
    recompute_s_per_byte: float,
    launch_s: float = LAUNCH_S,
) -> CostModel:
    """Rematerialization: a 'load' re-computes the activation instead of
    reading it back; a 'store' is free (nothing is written, the segment
    boundary simply forgets). ``recompute_s_per_byte`` converts activation
    bytes to the seconds of recompute producing them (graph-specific)."""
    return CostModel(
        e_startup=launch_s,
        read=LinearTransfer(c0=0.0, c1=recompute_s_per_byte),
        write=LinearTransfer(c0=0.0, c1=0.0),
        name="tpu-remat",
    )


def tpu_pipeline_model(ici_bw: float = ICI_BW, hop_init_s: float = 1e-6) -> CostModel:
    """Pipeline-stage partitioning: a burst = a stage; crossing a boundary
    sends the live set over ICI to the next stage's device."""
    return CostModel(
        e_startup=0.0,
        read=LinearTransfer(c0=hop_init_s, c1=1.0 / ici_bw),
        write=LinearTransfer(c0=0.0, c1=0.0),  # charge each hop once, on the read side
        name="tpu-pipeline",
    )
