"""Shared deprecation shim for the legacy solver entry points.

The pre-façade codebase grew ~10 solver entry points with divergent
signatures (``optimal_partition``, ``sweep_jax_batched``, ``shard_plan_table``,
…). They all survive as thin shims over the same private implementations the
:mod:`repro.api` façade dispatches to — bit-identical results, one
:class:`DeprecationWarning` per call — so the historical differential and
byte-identity suites keep pinning behavior while new code routes through
``Engine.solve(PartitionSpec(...))``.

The CI deprecation gate runs the non-shim test tier with
``-W error::DeprecationWarning``; any internal module that regresses to a
legacy entry point fails that step loudly.
"""

from __future__ import annotations

import warnings

__all__ = ["JulienningDeprecationWarning", "warn_legacy"]


class JulienningDeprecationWarning(DeprecationWarning):
    """Category of every legacy-entry-point warning this repo emits.

    A plain :class:`DeprecationWarning` subclass, so the ISSUE-specified CI
    gate (``-W error::DeprecationWarning``) catches it — but narrowly
    filterable (``-W error::repro.core._deprecation.JulienningDeprecationWarning``
    or ``ignore::``-same) when third-party libraries start deprecating
    things of their own.
    """


def warn_legacy(name: str, replacement: str, *, stacklevel: int = 3) -> None:
    """Emit the standard legacy-entry-point warning.

    ``name`` is the dotted public name being called; ``replacement`` is the
    façade spelling (a ``PartitionSpec`` sketch or the new keyword), shown so
    callers can migrate without opening the docs.
    """
    warnings.warn(
        f"{name} is a legacy Julienning entry point; build a PartitionSpec "
        f"and route through repro.api instead — {replacement} "
        f"(see the README 'Public API' migration table).",
        JulienningDeprecationWarning,
        stacklevel=stacklevel,
    )
