"""The paper's head-counting applications (§5–§6) as Ladybirds task graphs.

Two variants share everything except the image-acquisition kernel:

* **thermal** — FLIR Lepton, acquisition 131.9 mJ (Table 1)
* **visual**  — OV7670, acquisition 4.4 mJ (Table 1; the visual image is
  scaled down so both variants run the *same* CNN — §5, "the only difference
  between the two versions is the energy required for the image acquisition")

Task sequence (Table 2): sense → normalize → initialize → CNN1 ×4125 →
CNN2 ×936 → CNN3 ×391 → sort → nms → transmit, i.e. **5458 tasks** — which is
why the paper's *Single Task* baseline runs 5458 bursts.

Data model (reconstructed; the paper gives sizes for the image and the FRAM
cost model, not the full packet layout — see EXPERIMENTS.md §Paper-repro for
the fidelity discussion):

* ``img``       80×60 uint16 sensor frame, 9600 B (§6.2)
* ``norm``      normalized fixed-point frame, 9600 B
* ``ws``        detector workspace (thresholds), 64 B
* ``scores{s}`` per-window CNN scores, float32, one sub-packet per task,
                coalesced DMA (c0 amortized across the array)
* ``top``       sorted top-detections, 128 B
* ``headcount`` the application output (kept; transmitted over BLE)

CNN weights live in flash (the paper's 444 kB Text section): they are
closure constants of the kernel bodies, never packets — exactly the paper's
memory layout.

The runtime bodies implement a real (small) window CNN in JAX so the graph
*executes*, not just analyzes; `reduced()` scales the window counts down for
fast CPU tests while preserving the graph shape.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..cost import PAPER_FRAM_MODEL, CostModel
from ..graph import GraphBuilder, TaskGraph

__all__ = [
    "HeadCountSpec",
    "THERMAL",
    "VISUAL",
    "build_graph",
    "paper_cost_model",
    "cnn_weights",
]


@dataclasses.dataclass(frozen=True)
class HeadCountSpec:
    """Energy/structure parameters (paper Tables 1–2; Joules)."""

    name: str
    e_sense: float                   # image acquisition kernel
    e_transmit: float = 0.086e-3     # BLE transmission
    e_normalize: float = 0.043e-3
    e_initialize: float = 0.003e-3
    e_cnn: Tuple[float, float, float] = (0.396e-3, 0.396e-3, 0.403e-3)
    n_cnn: Tuple[int, int, int] = (4125, 936, 391)
    e_sort: float = 0.010e-3
    e_nms: float = 0.006e-3
    img_bytes: int = 9600            # 80×60 uint16 (Lepton frame)
    norm_bytes: int = 9600
    ws_bytes: int = 64
    score_bytes: int = 4             # float32 per window task
    top_bytes: int = 128
    out_bytes: int = 4

    @property
    def e_app(self) -> float:
        """Atomic application energy (no state-retention overhead)."""
        return (
            self.e_sense
            + self.e_normalize
            + self.e_initialize
            + sum(e * n for e, n in zip(self.e_cnn, self.n_cnn))
            + self.e_sort
            + self.e_nms
            + self.e_transmit
        )

    @property
    def n_tasks(self) -> int:
        return 6 + sum(self.n_cnn) + 0 + 0  # sense,normalize,init,sort,nms,transmit + CNNs

    def reduced(self, scale: int = 64) -> "HeadCountSpec":
        """Same graph shape with ~1/scale of the CNN window tasks (tests)."""
        n = tuple(max(2, c // scale) for c in self.n_cnn)
        return dataclasses.replace(self, name=f"{self.name}-reduced", n_cnn=n)


THERMAL = HeadCountSpec(name="thermal", e_sense=131.9e-3)
VISUAL = HeadCountSpec(name="visual", e_sense=4.4e-3)


def paper_cost_model() -> CostModel:
    return PAPER_FRAM_MODEL


# ---------------------------------------------------------------------------
# Runtime kernel bodies (a real, small window-CNN in JAX)
# ---------------------------------------------------------------------------

_IMG_H, _IMG_W = 60, 80
_WIN = 12          # window side
_SCALES = (1, 2, 3)  # pyramid decimation per CNN type


def cnn_weights(seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic CNN parameters — the 'flash Text section'.

    conv1 3×3×1×8 → relu → 2×2 pool → conv2 3×3×8×16 → relu → global pool →
    fc 16→1. Roughly the paper's ~50 k MAC/window budget.
    """
    r = np.random.RandomState(seed)
    return {
        "conv1": (r.randn(3, 3, 1, 8) * 0.3).astype(np.float32),
        "b1": np.zeros(8, np.float32),
        "conv2": (r.randn(3, 3, 8, 16) * 0.2).astype(np.float32),
        "b2": np.zeros(16, np.float32),
        "fc": (r.randn(16) * 0.5).astype(np.float32),
        "fc_b": np.zeros((), np.float32),
    }


@functools.lru_cache(maxsize=None)
def _jax_kernels():
    """Build (and cache) the jitted kernel bodies lazily so that pure
    partitioning analysis never imports JAX compute."""
    import jax
    import jax.numpy as jnp

    def window_score(win, w):
        # win: (WIN, WIN) float32
        x = win[None, :, :, None]
        x = jax.lax.conv_general_dilated(
            x, w["conv1"], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + w["b1"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        x = jax.lax.conv_general_dilated(
            x, w["conv2"], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        ) + w["b2"]
        x = jax.nn.relu(x)
        feat = x.mean(axis=(1, 2))[0]
        return feat @ w["fc"] + w["fc_b"]

    @jax.jit
    def normalize(img_u16):
        f = img_u16.astype(jnp.float32)
        lo, hi = f.min(), f.max()
        n = (f - lo) / jnp.maximum(hi - lo, 1.0)
        return jnp.round(n * 65535.0).astype(jnp.uint16)

    @functools.partial(jax.jit, static_argnums=(2,))
    def score_window(norm_u16, weights, scale, y, x):
        # scale stays static (it shapes the decimated image); y/x are traced
        # so all windows of one pyramid level share a single compilation —
        # 3 compiles total instead of one per window, which is what makes
        # the 500+-task reduced graphs executable in the soak tests.
        f = norm_u16.astype(jnp.float32) / 65535.0
        dec = f[::scale, ::scale]
        win = jax.lax.dynamic_slice(dec, (jnp.int32(y), jnp.int32(x)),
                                    (_WIN, _WIN))
        return window_score(win, weights)

    return normalize, score_window


def _window_coords(spec: HeadCountSpec, scale_idx: int) -> List[Tuple[int, int]]:
    """Deterministic window rasterization giving exactly n_cnn[scale_idx]
    windows at pyramid scale ``_SCALES[scale_idx]`` (stride chosen to fit)."""
    n_want = spec.n_cnn[scale_idx]
    s = _SCALES[scale_idx]
    h, w = _IMG_H // s, _IMG_W // s
    coords: List[Tuple[int, int]] = []
    # raster scan with stride 1, wrapping rows; repeat raster until n_want
    ys = max(h - _WIN, 1)
    xs = max(w - _WIN, 1)
    i = 0
    while len(coords) < n_want:
        y = (i // xs) % ys
        x = i % xs
        coords.append((y, x))
        i += 1
    return coords


# ---------------------------------------------------------------------------
# Graph construction
# ---------------------------------------------------------------------------


def build_graph(
    spec: HeadCountSpec,
    with_fns: bool = False,
    seed: int = 0,
    image: Optional[np.ndarray] = None,
) -> TaskGraph:
    """Build the head-counting application as a TaskGraph.

    With ``with_fns=True`` every task carries a runnable JAX body and the
    graph can be executed by :class:`repro.core.runtime.BurstRuntime`;
    ``image`` then provides the sensor frame "acquired" by the sense task.
    """
    b = GraphBuilder()
    b.packet("img", spec.img_bytes)
    b.packet("norm", spec.norm_bytes)
    b.packet("ws", spec.ws_bytes)
    n1, n2, n3 = spec.n_cnn
    s1 = b.packet_array("scores1", n1, spec.score_bytes)
    s2 = b.packet_array("scores2", n2, spec.score_bytes)
    s3 = b.packet_array("scores3", n3, spec.score_bytes)
    b.packet("top", spec.top_bytes)
    b.packet("headcount", spec.out_bytes, keep=True)

    fns: Dict[str, object] = {}
    if with_fns:
        normalize, score_window = _jax_kernels()
        weights = {k: np.asarray(v) for k, v in cnn_weights(seed).items()}
        frame = (
            image
            if image is not None
            else np.random.RandomState(seed).randint(
                0, 65535, size=(_IMG_H, _IMG_W), dtype=np.uint16
            )
        )
        coords = [_window_coords(spec, s) for s in range(3)]

        def mk_sense():
            def fn(inp):
                return {"img": frame.copy()}
            return fn

        def mk_normalize():
            def fn(inp):
                return {"norm": np.asarray(normalize(inp["img"]))}
            return fn

        def mk_initialize():
            def fn(inp):
                ws = np.zeros(spec.ws_bytes // 4, np.float32)
                # Detection threshold. The reference CNN ships untrained
                # (weights are a seeded stand-in for the paper's trained
                # 444 kB flash image), so the threshold sits below the score
                # range: the head count is then determined by score ordering
                # + NMS geometry, which makes partitioned-vs-atomic equality
                # tests sensitive to any packet corruption.
                ws[0] = -1e30

                ws[1] = float(_WIN)  # NMS suppression radius
                return {"ws": ws}
            return fn

        def mk_cnn(scale_idx, t, out_name):
            y, x = coords[scale_idx][t]
            scale = _SCALES[scale_idx]

            def fn(inp):
                v = score_window(inp["norm"], weights, scale, y, x)
                return {out_name: np.float32(v)}

            return fn

        def mk_sort():
            all_names = s1 + s2 + s3
            all_coords = (
                [(0, yx) for yx in coords[0]]
                + [(1, yx) for yx in coords[1]]
                + [(2, yx) for yx in coords[2]]
            )

            def fn(inp):
                vals = np.array([float(inp[n]) for n in all_names], np.float32)
                order = np.argsort(-vals)[: spec.top_bytes // 8]
                top = np.zeros((len(order), 2), np.float32)
                for r, idx in enumerate(order):
                    top[r, 0] = vals[idx]
                    top[r, 1] = idx
                return {"top": top}

            fns["__all_coords"] = all_coords  # stashed for NMS
            return fn

        def mk_nms():
            def fn(inp):
                top = inp["top"]
                ws = inp["ws"]
                thresh, radius = float(ws[0]), float(ws[1])
                all_coords = fns["__all_coords"]
                kept: List[Tuple[int, int, int]] = []
                count = 0
                for row in top:
                    score, idx = float(row[0]), int(row[1])
                    if score <= thresh:
                        continue
                    sc, (y, x) = all_coords[idx]
                    s = _SCALES[sc]
                    cy, cx = (y + _WIN / 2) * s, (x + _WIN / 2) * s
                    if any(
                        abs(cy - ky) < radius and abs(cx - kx) < radius
                        for (_, ky, kx) in kept
                    ):
                        continue
                    kept.append((sc, cy, cx))
                    count += 1
                return {"headcount": np.int32(count)}

            return fn

        def mk_transmit():
            def fn(inp):
                return {}  # BLE send: consumes headcount, produces nothing

            return fn

        fns["sense"] = mk_sense()
        fns["normalize"] = mk_normalize()
        fns["initialize"] = mk_initialize()
        fns["sort"] = mk_sort()
        fns["nms"] = mk_nms()
        fns["transmit"] = mk_transmit()
        for sc in range(3):
            for t in range(spec.n_cnn[sc]):
                out = (s1, s2, s3)[sc][t]
                fns[f"cnn{sc + 1}_{t}"] = mk_cnn(sc, t, out)

    def fn_of(name):
        return fns.get(name) if with_fns else None

    b.task("sense", reads=(), writes=("img",), cost=spec.e_sense, fn=fn_of("sense"))
    b.task("normalize", reads=("img",), writes=("norm",), cost=spec.e_normalize,
           fn=fn_of("normalize"))
    b.task("initialize", reads=(), writes=("ws",), cost=spec.e_initialize,
           fn=fn_of("initialize"))
    for sc, (names, e) in enumerate(zip((s1, s2, s3), spec.e_cnn)):
        for t, out in enumerate(names):
            b.task(
                f"cnn{sc + 1}_{t}", reads=("norm",), writes=(out,), cost=e,
                fn=fn_of(f"cnn{sc + 1}_{t}"),
            )
    b.task("sort", reads=tuple(s1 + s2 + s3), writes=("top",), cost=spec.e_sort,
           fn=fn_of("sort"))
    b.task("nms", reads=("top", "ws"), writes=("headcount",), cost=spec.e_nms,
           fn=fn_of("nms"))
    b.task("transmit", reads=("headcount",), writes=(), cost=spec.e_transmit,
           fn=fn_of("transmit"))
    return b.build()
