"""Burst energy model E⟨i,j⟩ (paper §4.2).

Two implementations:

* :func:`burst_cost` / :func:`burst_detail` — a direct transliteration of the
  paper's equations (the test oracle).

* :class:`ColumnSweep` — an incremental algorithm that produces, for
  j = 1..n_t, the full column ``E⟨i,j⟩ for all i ≤ j`` in amortized
  O(reads(j) + writes(j)) numpy range updates per step. Total complexity
  O(n_t² + n_t·r̄) element operations versus the paper's O(n_t³·|P|) —
  a beyond-paper algorithmic improvement that makes the 5458-task
  head-count application and 10⁵-layer sweeps tractable (see DESIGN.md).

Derivation of the incremental update (all indices 1-based, burst = tasks i..j):

    E⟨i,j⟩ = E⟨i,j-1⟩
           + E_task(j)
           + Σ E_r(p)   for p ∈ reads(j) with l_j(p) < i          (new loads)
           + Σ E_w(p)   for p ∈ writes(j) with l_∞(p) > j         (new stores)
           - Σ E_w(p)   for p ∈ reads(j) with i ≤ writer(p) < j
                                         and l_∞(p) == j          (store no longer needed)

The three Σ-terms are constant or piecewise-constant in ``i`` with a single
threshold each, so each packet touched by task j contributes exactly one numpy
slice update to the column.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from .cost import CostModel
from .graph import TaskGraph

__all__ = ["burst_cost", "burst_detail", "BurstDetail", "ColumnSweep"]


# ---------------------------------------------------------------------------
# Reference implementation (paper equations, used as the oracle in tests)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BurstDetail:
    """Full accounting for one burst ⟨i,j⟩."""

    i: int
    j: int
    e_startup: float
    e_read: float
    e_write: float
    e_task: float
    loads: List[str]
    stores: List[str]
    read_bytes: int
    write_bytes: int

    @property
    def total(self) -> float:
        return self.e_startup + self.e_read + self.e_write + self.e_task


def burst_detail(graph: TaskGraph, cost: CostModel, i: int, j: int) -> BurstDetail:
    """E⟨i,j⟩ with a full load/store breakdown (paper §4.2, verbatim)."""
    if not (1 <= i <= j <= graph.n_tasks):
        raise ValueError(f"invalid burst ⟨{i},{j}⟩ for n_t={graph.n_tasks}")
    e_read = e_write = e_task = 0.0
    loads: List[str] = []
    stores: List[str] = []
    rbytes = wbytes = 0
    for k in range(i, j + 1):
        t = graph.task(k)
        lts = graph.read_last_touch[k - 1]
        for name, lt in zip(t.reads, lts):
            if lt < i:  # P_k^r⟨i,j⟩ : last use prior to burst start → load from NVM
                p = graph.packets[name]
                e_read += cost.e_r(p)
                rbytes += p.nbytes
                loads.append(name)
        e_task += t.cost
        for name in t.writes:
            if graph.l_inf[name] > j:  # P_k^w⟨i,j⟩ : used after the burst → store
                p = graph.packets[name]
                e_write += cost.e_w(p)
                wbytes += p.nbytes
                stores.append(name)
    return BurstDetail(
        i=i, j=j,
        e_startup=cost.e_startup,
        e_read=e_read, e_write=e_write, e_task=e_task,
        loads=loads, stores=stores,
        read_bytes=rbytes, write_bytes=wbytes,
    )


def burst_cost(graph: TaskGraph, cost: CostModel, i: int, j: int) -> float:
    """E⟨i,j⟩ (scalar)."""
    return burst_detail(graph, cost, i, j).total


# ---------------------------------------------------------------------------
# Incremental column sweep
# ---------------------------------------------------------------------------


class ColumnSweep:
    """Iterates j = 1..n_t, yielding the column ``E⟨·,j⟩``.

    After ``col = next(sweep)``, ``col[i]`` equals ``E⟨i,j⟩`` for
    ``1 <= i <= j`` (entries outside that range are undefined). The array
    yielded is a live buffer — callers must not mutate it.
    """

    def __init__(self, graph: TaskGraph, cost: CostModel):
        self.graph = graph
        self.cost = cost
        n = graph.n_tasks
        self._col = np.full(n + 2, np.nan, dtype=np.float64)
        # Precompute per-task constants.
        self._e_task = np.array([t.cost for t in graph.tasks], dtype=np.float64)
        self._store_add = np.zeros(n + 1, dtype=np.float64)  # Σ E_w over writes with l_inf > j
        for j in range(1, n + 1):
            t = graph.task(j)
            self._store_add[j] = sum(
                cost.e_w(graph.packets[w]) for w in t.writes if graph.l_inf[w] > j
            )

    def __iter__(self) -> Iterator[np.ndarray]:
        g, c = self.graph, self.cost
        col = self._col
        for j in range(1, g.n_tasks + 1):
            t = g.task(j)
            e_task_j = self._e_task[j - 1]
            store_j = self._store_add[j]
            lts = g.read_last_touch[j - 1]
            # 1) extend all existing bursts ⟨i, j-1⟩ with task j
            if j > 1:
                col[1:j] += e_task_j + store_j
                sum_er = 0.0
                for name, lt in zip(t.reads, lts):
                    p = g.packets[name]
                    er = c.e_r(p)
                    sum_er += er
                    if lt + 1 < j:  # loads appear for bursts starting after last touch
                        col[lt + 1 : j] += er
                    if g.l_inf[name] == j:
                        w = g.writer(name)
                        if w >= 1:  # store of p is no longer needed when writer in burst
                            col[1 : w + 1] -= c.e_w(p)
            else:
                sum_er = sum(c.e_r(g.packets[name]) for name in t.reads)
            # 2) the new single-task burst ⟨j,j⟩
            col[j] = c.e_startup + sum_er + e_task_j + store_j
            yield col
