"""Swarm placement: bandwidth-aware multi-node partitioning (ROADMAP item).

The paper partitions one batteryless node's *timeline* into energy-bounded
bursts. This module opens the same method to sensor swarms — NS-Optimizer
style relay chains of cooperating harvesting nodes (batteryless cameras)
that split one :class:`~repro.core.graph.TaskGraph` *across* devices:

* a placement assigns tasks ``1..n`` to an ordered chain of nodes as
  ``k ≤ N`` contiguous, non-empty spans (trailing nodes stay dark);
* each node's span is itself burst-partitioned under that node's energy
  budget ``q_max`` and cost model — the paper's DP, run per node;
* crossing a span boundary ships the boundary's *live set* (exactly the
  packets an NVM commit would persist there) over a :class:`LinkModel`:
  bandwidth in mbps → per-byte transfer energy + per-hop latency, TX
  charged to the sender and RX to the receiver;
* a node's NVM must hold every packet whose live interval intersects its
  span — including pass-through packets it only relays — bounded by the
  node's ``memory_bytes``.

Two solver paths share one set of host-precomputed inputs
(:func:`placement_inputs`): the numpy grid DP (:func:`solve_placement_numpy`,
the reference oracle) and the ``lax.scan`` backend
(:mod:`repro.core.placement_jax`), which sweeps the whole
bandwidth × memory × Q grid in one jitted call. Both are reached through
``Engine.solve(PartitionSpec(..., placement=PlacementSpec(...)))`` and are
bit-identical — including argmin tie-breaks — which
:func:`exhaustive_placement` (full enumeration with the DP's exact
accumulation order and tie-break key) pins on small graphs in
tests/test_placement.py.

Tie-break contract (matching the single-node DPs' "smallest burst start
wins"): among minimum-energy placements the solver returns the one with the
fewest nodes, then lexicographically smallest span starts *read from the
end* (the DP reconstructs right-to-left, taking the first-min parent at
every step); each span's internal burst partition ties the same way.

Numpy + stdlib only — the jax half lives in :mod:`.placement_jax` so this
module stays importable without jax (mirrors :mod:`.partition`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .burst import ColumnSweep
from .cost import CostModel, LinearTransfer, cost_scalars
from .graph import TaskGraph
from .partition import BUDGET_ABS, BUDGET_REL
from ..obs.metrics import METRICS

__all__ = [
    "PLACEMENT_TABLE_VERSION",
    "PlacementError",
    "LinkModel",
    "NodeSpec",
    "PlacementSpec",
    "PlacementInputs",
    "PlacementSweep",
    "PlacementPlan",
    "PlacementTable",
    "placement_inputs",
    "solve_placement_numpy",
    "exhaustive_placement",
]

PLACEMENT_TABLE_VERSION = 1

#: Solve counters (one cell per backend), registered with the obs registry.
PLACEMENT_COUNT = METRICS.counter_dict(
    "placement_solves", ("numpy", "scan"),
    "placement grid solves per backend",
)

# Sentinel index used by the shared first-min argmin idiom (see _first_min):
# must exceed any real candidate index, identically in numpy and jax.
_NO_PARENT = 0


class PlacementError(ValueError):
    """Malformed placement specs, grids, or tables."""


# ---------------------------------------------------------------------------
# The model: links and nodes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One inter-node link: bandwidth (mbps) → transfer energy + latency.

    A hop at boundary ``b`` ships the live set L(b) — the packets an NVM
    commit would persist there. The sender pays
    ``tx = init_energy·ΣW + per_byte·ΣB`` (same linear shape as the paper's
    NVM transfer model: ``c0_weight`` amortizes the initiation term across
    coalesced sub-packets) and the receiver pays ``rx_fraction·tx``
    (radios listen roughly as expensively as they talk; 1.0 by default).

    ``energy_per_byte`` defaults to ``8 / (bandwidth_mbps · 1e6)`` — one
    byte's share of link time, i.e. "energy = seconds on the link", matching
    the repo's TPU cost models pricing bytes at ``1/bandwidth``. Pass an
    explicit Joules-per-byte figure for a physical radio.

    ``latency_s`` is reporting-only (it never enters the energy DP):
    ``init_s + nbytes·8/(bandwidth_mbps·1e6)``.
    """

    bandwidth_mbps: float
    energy_per_byte: Optional[float] = None
    init_energy: float = 0.0
    rx_fraction: float = 1.0
    init_s: float = 0.0
    name: str = ""

    def __post_init__(self) -> None:
        if not (self.bandwidth_mbps > 0.0) or math.isinf(self.bandwidth_mbps):
            raise PlacementError(
                f"bandwidth_mbps must be positive and finite, got "
                f"{self.bandwidth_mbps!r}"
            )
        for field in ("energy_per_byte", "init_energy", "rx_fraction", "init_s"):
            v = getattr(self, field)
            if v is None:
                continue
            if not math.isfinite(float(v)) or float(v) < 0.0:
                raise PlacementError(
                    f"{field} must be finite and >= 0, got {v!r}"
                )
        if not self.name:
            object.__setattr__(
                self, "name", f"link-{float(self.bandwidth_mbps):g}mbps"
            )

    @property
    def per_byte(self) -> float:
        """Energy per transferred byte (defaulted from the bandwidth)."""
        if self.energy_per_byte is not None:
            return float(self.energy_per_byte)
        return 8.0 / (float(self.bandwidth_mbps) * 1e6)

    def transfer(self) -> LinearTransfer:
        """The hop's TX cost as the repo-standard linear transfer model."""
        return LinearTransfer(c0=float(self.init_energy), c1=self.per_byte)

    def tx_energy(self, nbytes: float, c0_weight: float = 1.0) -> float:
        return float(self.init_energy) * float(c0_weight) + self.per_byte * float(nbytes)

    def hop_energy(self, nbytes: float, c0_weight: float = 1.0) -> float:
        """TX + RX for one live set (what the placement DP prices per cut)."""
        tx = self.tx_energy(nbytes, c0_weight)
        return tx + float(self.rx_fraction) * tx

    def latency_s(self, nbytes: float) -> float:
        """Store-and-forward hop latency for ``nbytes`` (reporting only)."""
        return float(self.init_s) + float(nbytes) * 8.0 / (
            float(self.bandwidth_mbps) * 1e6
        )


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """One harvesting node in the relay chain.

    ``q_max`` — the node's per-burst energy budget (its harvest capacitor),
    ``None`` = unbounded; scaled by :attr:`PlacementSpec.q_scales`.
    ``memory_bytes`` — NVM capacity bounding the packets whose live interval
    intersects the node's span (relayed packets included); ``None`` =
    unbounded; scaled by :attr:`PlacementSpec.memory_scales`.
    ``cost`` — the node's transfer cost model (defaults to the spec-level
    model, so a homogeneous swarm needs no per-node models).
    ``compute_scale`` — multiplier on task execution energy (a slower or
    lower-voltage node runs the same kernels at a different cost).
    """

    q_max: Optional[float] = None
    memory_bytes: Optional[float] = None
    cost: Optional[CostModel] = None
    compute_scale: float = 1.0
    name: str = ""

    def __post_init__(self) -> None:
        if self.q_max is not None and not (float(self.q_max) > 0.0):
            raise PlacementError(f"q_max must be > 0 or None, got {self.q_max!r}")
        if self.memory_bytes is not None and not (float(self.memory_bytes) >= 0.0):
            raise PlacementError(
                f"memory_bytes must be >= 0 or None, got {self.memory_bytes!r}"
            )
        if not (
            math.isfinite(float(self.compute_scale))
            and float(self.compute_scale) > 0.0
        ):
            raise PlacementError(
                f"compute_scale must be positive and finite, got "
                f"{self.compute_scale!r}"
            )
        if self.cost is not None and not isinstance(self.cost, CostModel):
            raise PlacementError(
                f"cost must be a CostModel, got {type(self.cost).__name__}"
            )


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementSpec:
    """The placement axis of a :class:`~repro.core.engine.PartitionSpec`.

    ``nodes`` — an int (that many default :class:`NodeSpec` nodes) or an
    explicit per-node tuple; the chain order is the relay order.
    ``link`` / ``links`` — exactly one: a single :class:`LinkModel` or the
    bandwidth-sweep tuple (one grid axis per link).
    ``q_scales`` / ``memory_scales`` — multiplier grids applied to every
    node's ``q_max`` / ``memory_bytes`` (the Q and memory sweep axes).

    The solved grid is ``links × memory_scales × q_scales`` — one batched
    ``Engine.solve`` call covers the whole design space.
    """

    nodes: Union[int, Tuple[NodeSpec, ...]] = 2
    link: Optional[LinkModel] = None
    links: Optional[Tuple[LinkModel, ...]] = None
    q_scales: Tuple[float, ...] = (1.0,)
    memory_scales: Tuple[float, ...] = (1.0,)

    def __post_init__(self) -> None:
        if isinstance(self.nodes, int):
            if self.nodes < 1:
                raise PlacementError(f"nodes must be >= 1, got {self.nodes}")
            object.__setattr__(
                self, "nodes", tuple(NodeSpec() for _ in range(self.nodes))
            )
        else:
            object.__setattr__(self, "nodes", tuple(self.nodes))
            if not self.nodes:
                raise PlacementError("nodes= is empty")
            for nd in self.nodes:
                if not isinstance(nd, NodeSpec):
                    raise PlacementError(
                        f"nodes= entries must be NodeSpec, got "
                        f"{type(nd).__name__}"
                    )
        if (self.link is None) == (self.links is None):
            raise PlacementError(
                "give exactly one of link= (single) or links= (sweep)"
            )
        links = (self.link,) if self.link is not None else tuple(self.links)
        object.__setattr__(self, "links", links)
        object.__setattr__(self, "link", None)
        if not links:
            raise PlacementError("links= is empty")
        for lk in links:
            if not isinstance(lk, LinkModel):
                raise PlacementError(
                    f"links= entries must be LinkModel, got "
                    f"{type(lk).__name__}"
                )
        for field in ("q_scales", "memory_scales"):
            vals = tuple(float(v) for v in getattr(self, field))
            if not vals:
                raise PlacementError(f"{field}= is empty")
            for v in vals:
                if not (math.isfinite(v) and v > 0.0):
                    raise PlacementError(
                        f"{field} entries must be positive and finite, "
                        f"got {v!r}"
                    )
            object.__setattr__(self, field, vals)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        """(links, memory_scales, q_scales) — the solved grid's shape."""
        return (len(self.links), len(self.memory_scales), len(self.q_scales))


# ---------------------------------------------------------------------------
# Shared host precompute: both backends (and the exhaustive oracle) consume
# exactly these arrays, which is what makes bit-identity achievable — the
# only arithmetic a backend performs is the two DPs.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementInputs:
    """Host-precomputed placement problem (see :func:`placement_inputs`).

    Index conventions (all 1-based like the paper): ``energy[k-1, a, b]`` is
    node ``k``'s burst cost E_k⟨a,b⟩ (inf outside ``1 ≤ a ≤ b ≤ n``);
    ``mem[i, j]`` the NVM bytes node spanning ``i..j`` must hold;
    boundary arrays are indexed by the boundary ``b = 0..n`` (the cut after
    task ``b``). ``q_thresh`` / ``mem_thresh`` are budget thresholds with
    the solver tolerance already folded in
    (``cap·(1+BUDGET_REL)+BUDGET_ABS``), so backends compare with plain
    ``<=`` and agree bitwise.
    """

    graph: TaskGraph
    spec: PlacementSpec
    cost: CostModel                       # spec-level default node cost model
    node_costs: Tuple[CostModel, ...]     # resolved per node
    energy: np.ndarray      # (N, n+2, n+2) f64  E_k⟨a,b⟩
    q_thresh: np.ndarray    # (N, Z) f64         per (node, q_scale) budget
    mem: np.ndarray         # (n+2, n+2) f64     span NVM footprint
    mem_thresh: np.ndarray  # (N, M) f64         per (node, memory_scale)
    live_bytes: np.ndarray  # (n+1,) f64         ΣB of the live set per boundary
    live_c0w: np.ndarray    # (n+1,) f64         ΣW (c0 weights) per boundary
    hop_tx: np.ndarray      # (L, n+1) f64       sender energy per boundary
    hop_rx: np.ndarray      # (L, n+1) f64       receiver energy per boundary
    hop_total: np.ndarray   # (L, n+1) f64       tx + rx (what the DP adds)
    hop_latency: np.ndarray  # (L, n+1) f64      store-and-forward seconds

    @property
    def n_tasks(self) -> int:
        return self.graph.n_tasks

    @property
    def n_nodes(self) -> int:
        return len(self.node_costs)

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        return self.spec.grid_shape


def _scaled_graph(graph: TaskGraph, scale: float) -> TaskGraph:
    """The graph with every task's execution cost scaled (compute_scale):
    burst costs then come out of the *paper's* recurrence unchanged."""
    if scale == 1.0:
        return graph
    tasks = [dataclasses.replace(t, cost=t.cost * scale) for t in graph.tasks]
    return TaskGraph(tasks, graph.packets.values())


def _burst_matrix(graph: TaskGraph, cost: CostModel) -> np.ndarray:
    """E⟨a,b⟩ for ``1 ≤ a ≤ b ≤ n`` from one incremental column sweep;
    inf everywhere else (so infeasible spans mask themselves)."""
    n = graph.n_tasks
    out = np.full((n + 2, n + 2), np.inf, dtype=np.float64)
    for b, col in zip(range(1, n + 1), ColumnSweep(graph, cost)):
        out[1 : b + 1, b] = col[1 : b + 1]
    return out


def placement_inputs(
    graph: TaskGraph, cost: CostModel, spec: PlacementSpec
) -> PlacementInputs:
    """Precompute every array both backends consume (see the class doc).

    One :class:`~repro.core.burst.ColumnSweep` per *distinct*
    (cost model, compute_scale) pair — a homogeneous N-node swarm pays for
    one sweep, not N.
    """
    if not isinstance(graph, TaskGraph):
        raise PlacementError(
            f"placement needs the TaskGraph (the per-node column sweeps walk "
            f"its structure), got {type(graph).__name__}"
        )
    n = graph.n_tasks
    if n == 0:
        raise PlacementError("placement needs at least one task")
    nodes = spec.nodes
    N = len(nodes)
    L, M, Z = spec.grid_shape

    node_costs = tuple(nd.cost if nd.cost is not None else cost for nd in nodes)
    energy = np.empty((N, n + 2, n + 2), dtype=np.float64)
    cache: Dict[Tuple[int, float], np.ndarray] = {}
    for k, nd in enumerate(nodes):
        key = (id(node_costs[k]), float(nd.compute_scale))
        mat = cache.get(key)
        if mat is None:
            mat = _burst_matrix(
                _scaled_graph(graph, float(nd.compute_scale)), node_costs[k]
            )
            cache[key] = mat
        energy[k] = mat

    # Budget thresholds with the shared solver tolerance folded in once, so
    # every backend's feasibility mask is a plain `<=` on identical floats.
    q_caps = np.array(
        [np.inf if nd.q_max is None else float(nd.q_max) for nd in nodes]
    )
    q_thresh = (
        q_caps[:, None] * np.asarray(spec.q_scales)[None, :] * (1.0 + BUDGET_REL)
        + BUDGET_ABS
    )
    m_caps = np.array(
        [
            np.inf if nd.memory_bytes is None else float(nd.memory_bytes)
            for nd in nodes
        ]
    )
    mem_thresh = (
        m_caps[:, None] * np.asarray(spec.memory_scales)[None, :]
        * (1.0 + BUDGET_REL)
        + BUDGET_ABS
    )

    # Span NVM footprint: packet p (writer w, last use l) occupies the node
    # spanning i..j iff its live interval [w, l] intersects [i, j] — i.e.
    # w <= j and l >= i. One rectangle add per packet.
    mem = np.zeros((n + 2, n + 2), dtype=np.float64)
    live_bytes = np.zeros(n + 1, dtype=np.float64)
    live_c0w = np.zeros(n + 1, dtype=np.float64)
    for name, p in graph.packets.items():
        w = graph.writer(name)
        l = graph.l_inf[name]
        mem[1 : min(l, n) + 1, max(w, 1) : n + 1] += float(p.nbytes)
        # Live at boundary b (between tasks b and b+1) iff w <= b < l —
        # exactly TaskGraph.live_packets(b), vectorized as a range add.
        lo, hi = max(w, 0), min(l - 1, n)
        if lo <= hi:
            live_bytes[lo : hi + 1] += float(p.nbytes)
            live_c0w[lo : hi + 1] += float(p.c0_weight)

    hop_tx = np.empty((L, n + 1), dtype=np.float64)
    hop_rx = np.empty((L, n + 1), dtype=np.float64)
    hop_latency = np.empty((L, n + 1), dtype=np.float64)
    for li, lk in enumerate(spec.links):
        tx = float(lk.init_energy) * live_c0w + lk.per_byte * live_bytes
        hop_tx[li] = tx
        hop_rx[li] = float(lk.rx_fraction) * tx
        hop_latency[li] = float(lk.init_s) + live_bytes * 8.0 / (
            float(lk.bandwidth_mbps) * 1e6
        )
    hop_total = hop_tx + hop_rx

    return PlacementInputs(
        graph=graph,
        spec=spec,
        cost=cost,
        node_costs=node_costs,
        energy=energy,
        q_thresh=q_thresh,
        mem=mem,
        mem_thresh=mem_thresh,
        live_bytes=live_bytes,
        live_c0w=live_c0w,
        hop_tx=hop_tx,
        hop_rx=hop_rx,
        hop_total=hop_total,
        hop_latency=hop_latency,
    )


# ---------------------------------------------------------------------------
# The numpy reference DPs
# ---------------------------------------------------------------------------


def _first_min(cand: np.ndarray, index: np.ndarray, big: int) -> np.ndarray:
    """First-min argmin along the last axis via the shared where/min idiom
    (identical in :mod:`.placement_jax`, so tie-breaks agree bitwise).
    Returns ``big`` only when ``index`` is empty; all-inf rows return the
    first index (inf == inf)."""
    mn = np.min(cand, axis=-1)
    return mn, np.min(
        np.where(cand == mn[..., None], index, big), axis=-1
    ).astype(np.int32)


def _inner_dp_numpy(
    energy_k: np.ndarray, thresh: float, n: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-node burst DP over *all* span starts at once.

    ``S[i, b]`` = minimum energy to burst-partition tasks ``i..b`` on this
    node under budget ``thresh`` (``S[i, i-1] = 0``, inf when infeasible);
    ``A[i, b]`` = start of the last burst (first-min). O(n³).
    """
    big = n + 2
    idx = np.arange(n + 2)
    S = np.full((n + 2, n + 2), np.inf, dtype=np.float64)
    S[idx[1:], idx[:-1]] = 0.0
    A = np.zeros((n + 2, n + 2), dtype=np.int32)
    ec = np.where(energy_k <= thresh, energy_k, np.inf)
    for b in range(1, n + 1):
        # cand[i, a] = S[i, a-1] + E_k⟨a,b⟩ for a = 1..b
        cand = S[:, 0:b] + ec[1 : b + 1, b][None, :]
        mn, first = _first_min(cand, np.arange(1, b + 1)[None, :], big)
        S[:, b] = np.where(idx <= b, mn, S[:, b])
        A[:, b] = np.where(idx <= b, first, 0)
    return S, A


def _outer_dp_numpy(
    S_nodes: np.ndarray,    # (N, n+2, n+2) inner DP values for one q scale
    hop: np.ndarray,        # (n+1,) hop_total for one link
    memok: np.ndarray,      # (N, n+2, n+2) bool memory feasibility
    n: int,
    N: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Chain DP over node count: ``dp[k-1, j]`` = minimum energy to run
    tasks ``1..j`` on exactly the first ``k`` nodes (each span non-empty);
    ``parent[k-1, j]`` = node ``k``'s span start (first-min)."""
    big = n + 2
    i_arr = np.arange(1, n + 1)
    j_arr = np.arange(n + 1)
    dp = np.empty((N, n + 1), dtype=np.float64)
    parent = np.empty((N, n + 1), dtype=np.int32)
    dp_prev = np.full(n + 1, np.inf)
    dp_prev[0] = 0.0
    zeros = np.zeros(n + 1)
    for k in range(1, N + 1):
        seg = np.where(memok[k - 1], S_nodes[k - 1], np.inf)
        # node 1 receives no hop; the accumulation order is ((dp + X) + S)
        base = dp_prev[0:n] + (hop[0:n] if k >= 2 else zeros[0:n])
        cand = base[None, :] + seg[1 : n + 1, 0 : n + 1].T
        cand = np.where(i_arr[None, :] <= j_arr[:, None], cand, np.inf)
        mn, first = _first_min(cand, i_arr[None, :], big)
        dp[k - 1] = mn
        parent[k - 1] = first
        dp_prev = mn
    return dp, parent


def solve_placement_numpy(
    graph: TaskGraph,
    cost: CostModel,
    spec: PlacementSpec,
    *,
    inputs: Optional[PlacementInputs] = None,
) -> "PlacementSweep":
    """The numpy reference solver: every (link, memory, Q) grid point via
    the two-level DP. The scan backend is pinned bit-identical to this
    (values *and* parent arrays) on every smoke config."""
    if inputs is None:
        inputs = placement_inputs(graph, cost, spec)
    PLACEMENT_COUNT["numpy"] += 1
    n, N = inputs.n_tasks, inputs.n_nodes
    L, M, Z = inputs.grid_shape

    inner_S = np.empty((N, Z, n + 2, n + 2), dtype=np.float64)
    inner_A = np.empty((N, Z, n + 2, n + 2), dtype=np.int32)
    for k in range(N):
        for z in range(Z):
            inner_S[k, z], inner_A[k, z] = _inner_dp_numpy(
                inputs.energy[k], inputs.q_thresh[k, z], n
            )

    memok = np.empty((N, M, n + 2, n + 2), dtype=bool)
    for k in range(N):
        for m in range(M):
            memok[k, m] = inputs.mem <= inputs.mem_thresh[k, m]

    outer_dp = np.empty((L, M, Z, N, n + 1), dtype=np.float64)
    outer_parent = np.empty((L, M, Z, N, n + 1), dtype=np.int32)
    for li in range(L):
        for m in range(M):
            for z in range(Z):
                outer_dp[li, m, z], outer_parent[li, m, z] = _outer_dp_numpy(
                    inner_S[:, z], inputs.hop_total[li], memok[:, m], n, N
                )

    e_total, k_used = _finalize(outer_dp, n, N)
    return PlacementSweep(
        inputs=inputs,
        backend="numpy",
        e_total=e_total,
        k_used=k_used,
        outer_dp=outer_dp,
        outer_parent=outer_parent,
        inner_S=inner_S,
        inner_A=inner_A,
    )


def _finalize(outer_dp: np.ndarray, n: int, N: int):
    """min over node count (first-min → fewest nodes among optima).
    ``k_used == 0`` marks infeasible cells. Shared by both backends."""
    if n == 0:
        # the empty application runs on zero nodes at zero energy
        shape = outer_dp.shape[:-2]
        return np.zeros(shape), np.zeros(shape, dtype=np.int32)
    dpn = outer_dp[..., n]                              # (L, M, Z, N)
    mn = np.min(dpn, axis=-1)
    k_arr = np.arange(1, N + 1, dtype=np.int32)
    first = np.min(
        np.where(dpn == mn[..., None], k_arr, np.int32(N + 2)), axis=-1
    )
    k_used = np.where(np.isfinite(mn), first, 0).astype(np.int32)
    return mn, k_used


# ---------------------------------------------------------------------------
# Results: the grid sweep and materialized plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementSweep:
    """Everything the grid solve produced; :meth:`plan` materializes one
    cell. ``outer_dp``/``outer_parent``/``inner_S``/``inner_A`` are the raw
    DP tables — kept so the bit-identity gates can compare backends on the
    full solver state, not just the optima."""

    inputs: PlacementInputs
    backend: str
    e_total: np.ndarray       # (L, M, Z) f64, inf where infeasible
    k_used: np.ndarray        # (L, M, Z) i32, 0 where infeasible
    outer_dp: np.ndarray      # (L, M, Z, N, n+1) f64
    outer_parent: np.ndarray  # (L, M, Z, N, n+1) i32
    inner_S: np.ndarray       # (N, Z, n+2, n+2) f64
    inner_A: np.ndarray       # (N, Z, n+2, n+2) i32

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        return tuple(self.e_total.shape)  # type: ignore[return-value]

    def feasible(
        self, link_index: int = 0, memory_index: int = 0, q_index: int = 0
    ) -> bool:
        return bool(np.isfinite(self.e_total[link_index, memory_index, q_index]))

    def plan(
        self, link_index: int = 0, memory_index: int = 0, q_index: int = 0
    ) -> "PlacementPlan":
        """Reconstruct one grid cell's placement (host-side walk of the
        parent arrays — identical plans from bit-identical arrays)."""
        li, m, z = int(link_index), int(memory_index), int(q_index)
        inp = self.inputs
        n = inp.n_tasks
        e = float(self.e_total[li, m, z])
        k = int(self.k_used[li, m, z])
        if not math.isfinite(e):
            raise PlacementError(
                f"grid cell (link={li}, memory={m}, q={z}) is infeasible: "
                f"no placement fits the node budgets"
            )
        spans: List[Tuple[int, int]] = []
        j = n
        for kk in range(k, 0, -1):
            i = int(self.outer_parent[li, m, z, kk - 1, j])
            spans.append((i, j))
            j = i - 1
        spans.reverse()
        node_bursts: List[Tuple[Tuple[int, int], ...]] = []
        node_energy: List[float] = []
        node_memory: List[float] = []
        for kk, (i, j) in enumerate(spans, start=1):
            bursts: List[Tuple[int, int]] = []
            b = j
            while b >= i:
                a = int(self.inner_A[kk - 1, z, i, b])
                bursts.append((a, b))
                b = a - 1
            bursts.reverse()
            node_bursts.append(tuple(bursts))
            node_energy.append(float(self.inner_S[kk - 1, z, i, j]))
            node_memory.append(float(inp.mem[i, j]))
        bounds = [i - 1 for (i, _) in spans[1:]]
        link = inp.spec.links[li]
        return PlacementPlan(
            link_index=li,
            memory_index=m,
            q_index=z,
            link=link,
            q_scale=float(inp.spec.q_scales[z]),
            memory_scale=float(inp.spec.memory_scales[m]),
            spans=tuple(spans),
            node_bursts=tuple(node_bursts),
            node_energy=tuple(node_energy),
            node_memory_bytes=tuple(node_memory),
            node_costs=inp.node_costs[:k],
            node_specs=inp.spec.nodes[:k],
            hop_boundaries=tuple(bounds),
            hop_bytes=tuple(float(inp.live_bytes[b]) for b in bounds),
            hop_tx=tuple(float(inp.hop_tx[li, b]) for b in bounds),
            hop_rx=tuple(float(inp.hop_rx[li, b]) for b in bounds),
            hop_latency_s=tuple(float(inp.hop_latency[li, b]) for b in bounds),
            e_total=e,
            graph=inp.graph,
        )

    def plans(self) -> List[Optional["PlacementPlan"]]:
        """Every grid cell's plan in (link, memory, q) C-order; ``None``
        where infeasible."""
        L, M, Z = self.grid_shape
        return [
            self.plan(li, m, z) if self.feasible(li, m, z) else None
            for li in range(L)
            for m in range(M)
            for z in range(Z)
        ]

    def summary(self) -> str:
        L, M, Z = self.grid_shape
        feas = int(np.isfinite(self.e_total).sum())
        return (
            f"PlacementSweep[{self.backend}] {self.inputs.n_nodes} nodes × "
            f"grid {L}×{M}×{Z} ({feas}/{L * M * Z} feasible)"
        )


@dataclasses.dataclass(frozen=True, eq=False)
class PlacementPlan:
    """One materialized placement: spans, per-node burst schedules and
    energy/memory accounting, plus per-hop transfer costs."""

    link_index: int
    memory_index: int
    q_index: int
    link: LinkModel
    q_scale: float
    memory_scale: float
    spans: Tuple[Tuple[int, int], ...]              # per used node, 1-based
    node_bursts: Tuple[Tuple[Tuple[int, int], ...], ...]
    node_energy: Tuple[float, ...]                  # span DP value per node
    node_memory_bytes: Tuple[float, ...]
    node_costs: Tuple[CostModel, ...]
    node_specs: Tuple[NodeSpec, ...]
    hop_boundaries: Tuple[int, ...]                 # cut after task b
    hop_bytes: Tuple[float, ...]
    hop_tx: Tuple[float, ...]
    hop_rx: Tuple[float, ...]
    hop_latency_s: Tuple[float, ...]
    e_total: float
    graph: TaskGraph

    @property
    def n_nodes_used(self) -> int:
        return len(self.spans)

    @property
    def n_bursts(self) -> int:
        return sum(len(bs) for bs in self.node_bursts)

    @property
    def transfer_energy(self) -> float:
        """Total inter-node transfer draw (TX + RX over every hop)."""
        return sum(self.hop_tx) + sum(self.hop_rx)

    @property
    def transfer_overhead(self) -> float:
        """Transfer energy as a fraction of the plan total (the swarm analog
        of the paper's activation-overhead figure)."""
        return self.transfer_energy / self.e_total if self.e_total else 0.0

    @property
    def transfer_bytes(self) -> float:
        return float(sum(self.hop_bytes))

    @property
    def total_hop_latency_s(self) -> float:
        return float(sum(self.hop_latency_s))

    def node_spent(self, node_index: int) -> float:
        """Node ``node_index``'s total draw: its span energy, plus TX of the
        hop it sends, plus RX of the hop it receives."""
        k = int(node_index)
        spent = self.node_energy[k]
        if k < len(self.hop_tx):
            spent += self.hop_tx[k]
        if k >= 1:
            spent += self.hop_rx[k - 1]
        return spent

    def validate(self) -> None:
        """Structural sanity: contiguous non-empty spans covering 1..n,
        bursts covering each span, hop boundaries at the span cuts."""
        expect = 1
        for (i, j), bursts in zip(self.spans, self.node_bursts):
            if i != expect or j < i:
                raise AssertionError(f"non-contiguous span ⟨{i},{j}⟩")
            b_expect = i
            for (a, b) in bursts:
                if a != b_expect or b < a:
                    raise AssertionError(
                        f"non-contiguous burst ⟨{a},{b}⟩ in span ⟨{i},{j}⟩"
                    )
                b_expect = b + 1
            if b_expect != j + 1:
                raise AssertionError(f"bursts do not cover span ⟨{i},{j}⟩")
            expect = j + 1
        if expect != self.graph.n_tasks + 1:
            raise AssertionError("placement does not cover all tasks")
        if tuple(j for (_, j) in self.spans[:-1]) != self.hop_boundaries:
            raise AssertionError("hop boundaries disagree with span cuts")

    def ledgers(self):
        """Per-node :class:`~repro.obs.ledger.EnergyLedger` attribution.

        Each committed burst charges ``restore`` (the node's E_s),
        ``compute`` (scaled task energy) and ``commit`` (the remaining NVM
        traffic); hop TX is committed by the sender and RX by the receiver.
        Node ``k``'s ledger conserves against :meth:`node_spent`\\ (k) at
        solver tolerance — the swarm CLI and tests gate on that.
        """
        from ..obs.ledger import EnergyLedger

        out = []
        for k, ((i, j), bursts) in enumerate(zip(self.spans, self.node_bursts)):
            cm = self.node_costs[k]
            scale = float(self.node_specs[k].compute_scale)
            led = EnergyLedger()
            # Re-walk the burst costs in DP accumulation order so the sum of
            # charges reproduces node_energy[k] up to reordering rounding.
            for cycle, (a, b) in enumerate(bursts):
                total = float(self._burst_energy(k, a, b))
                restore = float(cm.e_startup)
                compute = float(
                    sum(self.graph.task(t).cost for t in range(a, b + 1)) * scale
                )
                led.charge(
                    k, cycle,
                    restore=restore,
                    compute=compute,
                    commit=total - restore - compute,
                )
            hop_cycle = len(bursts)
            if k < len(self.hop_tx):            # sends to node k+1
                led.charge(k, hop_cycle, commit=self.hop_tx[k])
            if k >= 1:                          # received from node k-1
                led.charge(k, hop_cycle + 1, commit=self.hop_rx[k - 1])
            out.append(led)
        return out

    def check_conservation(self) -> None:
        """Every node's ledger must conserve against its spent total, and
        the node totals must sum to the plan energy (solver tolerance)."""
        from ..obs.ledger import LedgerImbalance

        total = 0.0
        for k, led in enumerate(self.ledgers()):
            led.check_conservation(self.node_spent(k))
            total += self.node_spent(k)
        scale = max(abs(total), abs(self.e_total))
        if abs(total - self.e_total) > scale * BUDGET_REL + BUDGET_ABS:
            raise LedgerImbalance(
                f"node energies sum to {total!r} but the plan total is "
                f"{self.e_total!r}"
            )

    def _burst_energy(self, node_index: int, a: int, b: int) -> float:
        """E_k⟨a,b⟩ from the solved inputs is not retained on the plan;
        recompute from the node's (possibly scaled) burst detail."""
        from .burst import burst_cost

        cm = self.node_costs[node_index]
        scale = float(self.node_specs[node_index].compute_scale)
        g = _scaled_graph(self.graph, scale)
        return burst_cost(g, cm, a, b)

    def summary(self) -> str:
        spans = " | ".join(
            f"n{k}⟨{i},{j}⟩×{len(bs)}"
            for k, ((i, j), bs) in enumerate(zip(self.spans, self.node_bursts))
        )
        return (
            f"nodes={self.n_nodes_used} bursts={self.n_bursts} "
            f"E_total={self.e_total:.6g} "
            f"transfer={100 * self.transfer_overhead:.2f}% "
            f"({self.transfer_bytes:.0f} B over "
            f"{self.link.bandwidth_mbps:g} mbps) [{spans}]"
        )


# ---------------------------------------------------------------------------
# Exhaustive oracle (tests): full enumeration with the DP's exact
# accumulation order and tie-break key
# ---------------------------------------------------------------------------


def exhaustive_placement(
    inputs: PlacementInputs,
    link_index: int = 0,
    memory_index: int = 0,
    q_index: int = 0,
) -> Optional[Tuple[float, Tuple[Tuple[int, int], ...], Tuple[Tuple[Tuple[int, int], ...], ...]]]:
    """Enumerate every placement of one grid cell; ``None`` if none fits.

    Returns ``(e_total, spans, node_bursts)`` for the winner under the DP's
    exact tie-break key: (energy, node count, span starts compared from the
    last span backwards, then each span's burst starts compared the same
    way). Costs accumulate in the DP's order — ``((dp + hop) + seg)`` across
    spans, left-to-right across bursts within a span — so on ties *and*
    values this matches :func:`solve_placement_numpy` bitwise. O(2^n·…):
    test-only (n ≤ 8, N ≤ 3).
    """
    n, N = inputs.n_tasks, inputs.n_nodes
    if n > 12:
        raise PlacementError("exhaustive oracle limited to n <= 12")
    li, m, z = int(link_index), int(memory_index), int(q_index)
    hop = inputs.hop_total[li]
    if n == 0:
        return 0.0, (), ()

    def span_options(k: int, i: int, j: int):
        """All burst partitions of i..j on node k: (seg_energy, bursts),
        accumulated left-to-right like the inner DP."""
        thresh = inputs.q_thresh[k, z]
        opts = []
        for cuts in itertools.product([False, True], repeat=j - i):
            bounds = []
            a = i
            for t, cut in zip(range(i, j), cuts):
                if cut:
                    bounds.append((a, t))
                    a = t + 1
            bounds.append((a, j))
            seg = 0.0
            ok = True
            for (aa, bb) in bounds:
                e = inputs.energy[k, aa, bb]
                if not (e <= thresh):
                    ok = False
                    break
                seg = seg + e
            if ok:
                opts.append((seg, tuple(bounds)))
        return opts

    def burst_key(bursts: Tuple[Tuple[int, int], ...]):
        return tuple(a for (a, _) in reversed(bursts))

    best = None  # (energy, k, rev_span_starts, rev_burst_keys, spans, bursts)
    for k in range(1, min(N, n) + 1):
        for cut_pos in itertools.combinations(range(1, n), k - 1):
            starts = (1,) + tuple(c + 1 for c in cut_pos)
            ends = tuple(c for c in cut_pos) + (n,)
            spans = tuple(zip(starts, ends))
            # memory feasibility per node
            if not all(
                inputs.mem[i, j] <= inputs.mem_thresh[kk, m]
                for kk, (i, j) in enumerate(spans)
            ):
                continue
            # pick each span's canonical burst partition: min energy, then
            # smallest reversed burst starts (the inner DP's tie-break)
            chosen = []
            feasible = True
            for kk, (i, j) in enumerate(spans):
                opts = span_options(kk, i, j)
                if not opts:
                    feasible = False
                    break
                opts.sort(key=lambda sb: (sb[0], burst_key(sb[1])))
                chosen.append(opts[0])
            if not feasible:
                continue
            total = 0.0
            for kk, (seg, _) in enumerate(chosen):
                if kk >= 1:
                    total = total + hop[spans[kk][0] - 1]
                total = total + seg
            key = (
                total,
                k,
                tuple(i for (i, _) in reversed(spans)),
                tuple(burst_key(b) for (_, b) in reversed(chosen)),
            )
            if best is None or key < best[0]:
                best = (key, spans, tuple(b for (_, b) in chosen))
    if best is None:
        return None
    return best[0][0], best[1], best[2]


# ---------------------------------------------------------------------------
# Versioned placement tables (the DSE artifact)
# ---------------------------------------------------------------------------


class PlacementTable:
    """A solved placement grid as a versioned, fingerprinted JSON artifact —
    the swarm sibling of the single-node plan table (same discipline:
    content fingerprint over hex-encoded floats, typed tamper errors)."""

    def __init__(
        self,
        sweep: Optional[PlacementSweep] = None,
        *,
        payload: Optional[Mapping[str, Any]] = None,
        meta: Optional[Mapping[str, object]] = None,
    ) -> None:
        if (sweep is None) == (payload is None):
            raise PlacementError("give exactly one of sweep= or payload=")
        if sweep is not None:
            self._payload = _table_payload(sweep, dict(meta or {}))
        else:
            self._payload = _validate_table_payload(payload)

    # -- views --------------------------------------------------------------

    @property
    def meta(self) -> Dict[str, object]:
        return dict(self._payload["meta"])

    @property
    def grid_shape(self) -> Tuple[int, int, int]:
        g = self._payload["grid"]
        return (
            len(g["bandwidth_mbps"]),
            len(g["memory_scales"]),
            len(g["q_scales"]),
        )

    @property
    def bandwidths(self) -> Tuple[float, ...]:
        return tuple(self._payload["grid"]["bandwidth_mbps"])

    @property
    def e_total(self) -> np.ndarray:
        arr = np.asarray(self._payload["e_total"], dtype=np.float64)
        return np.where(np.isnan(arr), np.inf, arr)

    def cell(self, link_index: int, memory_index: int, q_index: int) -> Dict[str, Any]:
        return dict(
            self._payload["cells"][link_index][memory_index][q_index] or {}
        )

    def fingerprint(self) -> str:
        return _table_fingerprint(self._payload)

    def summary(self) -> str:
        L, M, Z = self.grid_shape
        feas = int(np.isfinite(self.e_total).sum())
        return (
            f"PlacementTable v{self._payload['version']} grid {L}×{M}×{Z} "
            f"({feas} feasible) fingerprint={self.fingerprint()[:12]}…"
        )

    # -- persistence --------------------------------------------------------

    def to_payload(self) -> Dict[str, Any]:
        out = dict(self._payload)
        out["fingerprint"] = self.fingerprint()
        return out

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_payload(), f, indent=2)
            f.write("\n")

    @classmethod
    def from_payload(cls, payload: Mapping[str, Any]) -> "PlacementTable":
        return cls(payload=payload)

    @classmethod
    def from_json(cls, path: str) -> "PlacementTable":
        with open(path) as f:
            return cls.from_payload(json.load(f))


def _table_payload(sweep: PlacementSweep, meta: Dict[str, object]) -> Dict[str, Any]:
    inp = sweep.inputs
    spec = inp.spec
    L, M, Z = sweep.grid_shape
    cells: List[List[List[Optional[Dict[str, Any]]]]] = []
    for li in range(L):
        mrow = []
        for m in range(M):
            zrow: List[Optional[Dict[str, Any]]] = []
            for z in range(Z):
                if not sweep.feasible(li, m, z):
                    zrow.append(None)
                    continue
                plan = sweep.plan(li, m, z)
                zrow.append(
                    {
                        "spans": [list(s) for s in plan.spans],
                        "bursts": [
                            [list(b) for b in bs] for bs in plan.node_bursts
                        ],
                        "node_energy": list(plan.node_energy),
                        "transfer_overhead": plan.transfer_overhead,
                        "transfer_bytes": plan.transfer_bytes,
                        "hop_latency_s": list(plan.hop_latency_s),
                    }
                )
            mrow.append(zrow)
        cells.append(mrow)
    e = np.where(np.isfinite(sweep.e_total), sweep.e_total, np.nan)
    return {
        "version": PLACEMENT_TABLE_VERSION,
        "backend": sweep.backend,
        "grid": {
            "bandwidth_mbps": [float(lk.bandwidth_mbps) for lk in spec.links],
            "memory_scales": list(spec.memory_scales),
            "q_scales": list(spec.q_scales),
        },
        "nodes": [
            {
                "q_max": nd.q_max,
                "memory_bytes": nd.memory_bytes,
                "compute_scale": nd.compute_scale,
                "cost": cm.name,
                "name": nd.name,
            }
            for nd, cm in zip(spec.nodes, inp.node_costs)
        ],
        "cost": {
            "name": inp.cost.name,
            "scalars": [float(x) for x in cost_scalars(inp.cost)],
        },
        "n_tasks": inp.n_tasks,
        "e_total": e.tolist(),
        "k_used": sweep.k_used.tolist(),
        "cells": cells,
        "meta": meta,
    }


def _table_fingerprint(payload: Mapping[str, Any]) -> str:
    """sha256 over the solved content — grid axes and energies hex-encoded
    so two tables agree iff their solved numbers agree bitwise."""
    h = hashlib.sha256()
    h.update(f"placement-v{payload['version']}\x00".encode())
    g = payload["grid"]
    for axis in ("bandwidth_mbps", "memory_scales", "q_scales"):
        h.update(" ".join(float(x).hex() for x in g[axis]).encode() + b"\x00")
    h.update(json.dumps(payload["nodes"], sort_keys=True).encode())
    h.update(" ".join(float(x).hex() for x in payload["cost"]["scalars"]).encode())
    flat: List[float] = []
    for mrow in payload["e_total"]:
        for zrow in mrow:
            flat.extend(zrow)
    h.update(
        " ".join("nan" if x is None or (isinstance(x, float) and math.isnan(x))
                 else float(x).hex() for x in flat).encode()
    )
    h.update(json.dumps(payload["cells"], sort_keys=True).encode())
    return h.hexdigest()


def _validate_table_payload(payload: Mapping[str, Any]) -> Dict[str, Any]:
    try:
        version = payload["version"]
    except (KeyError, TypeError) as exc:
        raise PlacementError("not a placement-table payload (no version)") from exc
    if version != PLACEMENT_TABLE_VERSION:
        raise PlacementError(
            f"placement-table version {version!r} != supported "
            f"{PLACEMENT_TABLE_VERSION}"
        )
    out = dict(payload)
    recorded = out.pop("fingerprint", None)
    if recorded is not None and recorded != _table_fingerprint(out):
        raise PlacementError(
            "placement-table fingerprint mismatch: file was edited or "
            "written by an incompatible build"
        )
    return out
