"""Memory-bounded remat segmentation via Julienning (DESIGN.md §2, item 1).

Same activation graph, third cost interpretation: crossing a segment
boundary *saves* the boundary activation (HBM bytes, cheap) and the
backward pass *recomputes* the segment interior (FLOPs). Julienning under
the memory model bounds the per-segment working set; the chosen boundaries
are then priced as recompute seconds. For homogeneous stacks this recovers
the √L-style uniform segmentation; for heterogeneous stacks (MoE vs dense,
Mamba vs shared-attention in zamba2) the boundaries land after *cheap*
layers — the dependency-aware placement the paper argues for.

Like :mod:`.offload`, solve and pricing are split: :func:`plan_remat`
sweeps Q and keeps the cheapest feasible segmentation, while
:func:`remat_from_bounds` prices *given* boundaries (e.g. the cut points
stored in a plan table) with no DP solve. Budget feasibility uses the
global solver tolerance from :mod:`.partition` — no local epsilons.

``segments_for_scan`` converts a plan into the (n_segments, seg_len) shape
needed for the double-scan lowering of a homogeneous layer stack.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from ..configs.base import ModelConfig
from .cost import PEAK_FLOPS
from .graph import TaskGraph
from .layer_profile import (
    LayerProfile,
    build_activation_graph,
    memory_cost_model,
    profile_model,
)
from .partition import Infeasible, Partition, within_budget

__all__ = ["RematPlan", "plan_remat", "remat_from_bounds", "segments_for_scan"]


@dataclasses.dataclass
class RematPlan:
    cfg_name: str
    hbm_budget_bytes: float
    bounds: List[Tuple[int, int]]
    saved_bytes: int                 # boundary activations kept in HBM
    recompute_seconds: float         # extra forward time paid in backward
    compute_seconds: float           # one clean forward

    @property
    def n_segments(self) -> int:
        return len(self.bounds)

    @property
    def recompute_fraction(self) -> float:
        return self.recompute_seconds / max(self.compute_seconds, 1e-30)

    def summary(self) -> str:
        return (f"{self.cfg_name}: {self.n_segments} remat segments under "
                f"{self.hbm_budget_bytes / 1e9:.2f} GB, saved "
                f"{self.saved_bytes / 1e9:.2f} GB, recompute overhead "
                f"{100 * self.recompute_fraction:.1f}%")


def _saved_and_recompute(
    profiles: List[LayerProfile],
    mem_graph: TaskGraph,
    part: Partition,
) -> Tuple[int, float]:
    """(boundary bytes kept in HBM, recompute FLOPs) for a segmentation.

    The backward pass recomputes each segment's interior; layers whose
    outputs are saved boundaries need no recompute — so more (smaller)
    segments trade HBM for less recompute, the knob the Q_max sweep turns.
    """
    saved = sum(
        mem_graph.packets[n].nbytes for b in part.bursts for n in b.stores
    )
    boundary_layers = {j for (_, j) in part.bounds}
    recompute = sum(
        p.flops for idx, p in enumerate(profiles, start=1)
        if idx not in boundary_layers
    )
    return int(saved), recompute


def remat_from_bounds(
    cfg_name: str,
    profiles: List[LayerProfile],
    mem_graph: TaskGraph,
    bounds: Sequence[Tuple[int, int]],
    hbm_budget_bytes: float,
) -> RematPlan:
    """Price a given remat segmentation — no DP solve (plan-table path).

    Feasibility (saved boundaries + largest transient working set ≤ budget)
    uses the shared solver tolerance, matching :func:`plan_remat`'s sweep.
    """
    from .partition import _partition_from_bounds

    mem = memory_cost_model()
    part = _partition_from_bounds(mem_graph, mem, list(bounds), None)
    saved, rec_flops = _saved_and_recompute(profiles, mem_graph, part)
    if not within_budget(saved + part.max_burst, hbm_budget_bytes):
        raise Infeasible(
            f"{cfg_name}: saved boundaries ({saved / 1e9:.2f} GB) + transient "
            f"peak ({part.max_burst / 1e9:.2f} GB) exceed the "
            f"{hbm_budget_bytes / 1e9:.2f} GB budget"
        )
    compute = sum(p.flops for p in profiles) / PEAK_FLOPS
    return RematPlan(
        cfg_name=cfg_name,
        hbm_budget_bytes=hbm_budget_bytes,
        bounds=list(bounds),
        saved_bytes=saved,
        recompute_seconds=rec_flops / PEAK_FLOPS,
        compute_seconds=compute,
    )


def plan_remat(cfg: ModelConfig, batch: int, seq: int,
               hbm_budget_bytes: float) -> RematPlan:
    """Minimize recompute subject to (saved boundaries + transient working
    set) ≤ budget.

    Saved boundary activations occupy HBM *persistently* until backward, so
    the budget binds the sum of saves plus the largest segment's transient
    working set. We sweep the per-segment bound Q (the paper's design-space
    exploration) and keep the feasible partition with the least recompute —
    smaller Q ⇒ more boundaries ⇒ less recompute but more saved bytes.
    """
    import numpy as np

    from .engine import PartitionSpec, default_engine
    from .partition import q_min as _q_min

    profiles, long_lived = profile_model(cfg, batch, seq)
    mem_graph = build_activation_graph(profiles, long_lived, kind="memory")
    mem = memory_cost_model()
    qmn = _q_min(mem_graph, mem)
    qs = list(np.geomspace(qmn, max(hbm_budget_bytes, qmn * 1.0001), 24))
    part: Optional[Partition] = None
    best_recompute = None
    cands = default_engine().solve(PartitionSpec(
        graph=mem_graph, cost=mem, q_grid=tuple(qs), backend="numpy",
    )).partitions()
    for cand in cands:
        if cand is None:
            continue
        saved_c, rec = _saved_and_recompute(profiles, mem_graph, cand)
        if not within_budget(saved_c + cand.max_burst, hbm_budget_bytes):
            continue
        if best_recompute is None or rec < best_recompute:
            best_recompute, part = rec, cand
    if part is None:
        raise Infeasible(
            f"no remat segmentation fits {hbm_budget_bytes / 1e9:.2f} GB "
            f"(transient Q_min alone is {qmn / 1e9:.2f} GB)")
    return remat_from_bounds(
        cfg.name, profiles, mem_graph, part.bounds, hbm_budget_bytes
    )


def segments_for_scan(n_layers: int, plan: RematPlan) -> Tuple[int, int]:
    """(n_segments, seg_len) for a double-scan lowering: the closest uniform
    shape to the julienne boundaries that divides ``n_layers``."""
    want = max(plan.n_segments, 1)
    best = min(
        (s for s in range(1, n_layers + 1) if n_layers % s == 0),
        key=lambda s: abs(s - want),
    )
    return best, n_layers // best
