"""Memory-bounded remat segmentation via Julienning (DESIGN.md §2, item 1).

Same activation graph, third cost interpretation: crossing a segment
boundary *saves* the boundary activation (HBM bytes, cheap) and the
backward pass *recomputes* the segment interior (FLOPs). Julienning under
the memory model bounds the per-segment working set; the chosen boundaries
are then priced as recompute seconds. For homogeneous stacks this recovers
the √L-style uniform segmentation; for heterogeneous stacks (MoE vs dense,
Mamba vs shared-attention in zamba2) the boundaries land after *cheap*
layers — the dependency-aware placement the paper argues for.

``segments_for_scan`` converts a plan into the (n_segments, seg_len) shape
needed for the double-scan lowering of a homogeneous layer stack.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from ..configs.base import ModelConfig
from .cost import PEAK_FLOPS
from .layer_profile import build_activation_graph, memory_cost_model, profile_model
from .partition import Partition, optimal_partition

__all__ = ["RematPlan", "plan_remat", "segments_for_scan"]


@dataclasses.dataclass
class RematPlan:
    cfg_name: str
    hbm_budget_bytes: float
    bounds: List[Tuple[int, int]]
    saved_bytes: int                 # boundary activations kept in HBM
    recompute_seconds: float         # extra forward time paid in backward
    compute_seconds: float           # one clean forward

    @property
    def n_segments(self) -> int:
        return len(self.bounds)

    @property
    def recompute_fraction(self) -> float:
        return self.recompute_seconds / max(self.compute_seconds, 1e-30)

    def summary(self) -> str:
        return (f"{self.cfg_name}: {self.n_segments} remat segments under "
                f"{self.hbm_budget_bytes / 1e9:.2f} GB, saved "
                f"{self.saved_bytes / 1e9:.2f} GB, recompute overhead "
                f"{100 * self.recompute_fraction:.1f}%")


def plan_remat(cfg: ModelConfig, batch: int, seq: int,
               hbm_budget_bytes: float) -> RematPlan:
    """Minimize recompute subject to (saved boundaries + transient working
    set) ≤ budget.

    Saved boundary activations occupy HBM *persistently* until backward, so
    the budget binds the sum of saves plus the largest segment's transient
    working set. We sweep the per-segment bound Q (the paper's design-space
    exploration) and keep the feasible partition with the least recompute —
    smaller Q ⇒ more boundaries ⇒ less recompute but more saved bytes.
    """
    import numpy as np

    from .partition import Infeasible, q_min as _q_min, sweep as _sweep

    profiles, long_lived = profile_model(cfg, batch, seq)
    mem_graph = build_activation_graph(profiles, long_lived, kind="memory")
    mem = memory_cost_model()
    qmn = _q_min(mem_graph, mem)
    qs = list(np.geomspace(qmn, max(hbm_budget_bytes, qmn * 1.0001), 24))
    part: Optional[Partition] = None
    best_recompute = None
    for cand in _sweep(mem_graph, mem, qs):
        if cand is None:
            continue
        saved_c = sum(mem_graph.packets[n].nbytes
                      for b in cand.bursts for n in b.stores)
        if saved_c + cand.max_burst > hbm_budget_bytes:
            continue
        boundary = {j for (_, j) in cand.bounds}
        rec = sum(p.flops for i, p in enumerate(profiles, 1) if i not in boundary)
        if best_recompute is None or rec < best_recompute:
            best_recompute, part = rec, cand
    if part is None:
        raise Infeasible(
            f"no remat segmentation fits {hbm_budget_bytes / 1e9:.2f} GB "
            f"(transient Q_min alone is {qmn / 1e9:.2f} GB)")
    saved = sum(
        mem_graph.packets[n].nbytes for b in part.bursts for n in b.stores)
    # backward recomputes each segment's interior; the layers whose outputs
    # are saved boundaries need no recompute — so more (smaller) segments
    # trade HBM for less recompute, the knob the Q_max sweep turns.
    boundary_layers = {j for (_, j) in part.bounds}
    recompute = sum(
        p.flops for idx, p in enumerate(profiles, start=1)
        if idx not in boundary_layers) / PEAK_FLOPS
    compute = sum(p.flops for p in profiles) / PEAK_FLOPS
    return RematPlan(
        cfg_name=cfg.name,
        hbm_budget_bytes=hbm_budget_bytes,
        bounds=part.bounds,
        saved_bytes=int(saved),
        recompute_seconds=recompute,
        compute_seconds=compute,
    )


def segments_for_scan(n_layers: int, plan: RematPlan) -> Tuple[int, int]:
    """(n_segments, seg_len) for a double-scan lowering: the closest uniform
    shape to the julienne boundaries that divides ``n_layers``."""
    want = max(plan.n_segments, 1)
    best = min(
        (s for s in range(1, n_layers + 1) if n_layers % s == 0),
        key=lambda s: abs(s - want),
    )
    return best, n_layers // best
