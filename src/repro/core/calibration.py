"""Measured cost calibration: close the loop from captured energy ledgers
back into the solver's cost model.

The analytical :class:`~repro.core.cost.CostModel` numbers in ``core/cost.py``
are datasheet values. The telemetry layer (PR 7) captures what actually
happened: :class:`repro.obs.ledger.EnergyLedger` attributes every committed
cycle's draw into ``restore`` / ``compute`` / ``commit`` categories (and
crashed attempts into ``replay`` overhead). This module ingests those rows
into a versioned, fingerprinted :class:`MeasuredCostTable` — per-category
energy mean + variance with sample counts — and materializes it back into a
plain ``CostModel`` that slots in wherever one is accepted (the façade's
``PartitionSpec.cost``, ``layer_profile.default_cost_model`` via
:func:`install_measured_default`, plan-table builds and probes).

Uncertainty propagation ("price each cut at mean + z·sigma"):

- ``restore`` samples re-estimate the activation cost E_s:
  ``e_startup' = mean + z·std``.
- ``commit`` samples re-scale the NVM transfer curves: the coefficient of
  variation ``cv = std/mean`` multiplies both ``read`` and ``write`` as
  ``c' = c · (1 + z·cv)`` — measured commit noise inflates every
  byte-proportional term the DP prices at a cut.
- ``compute`` and ``replay`` stats are tracked (they feed the summary and
  staleness checks) but are not folded into the CostModel: task energies
  live on the graph nodes, not on the transfer model.

``z`` comes from the configured confidence level via the stdlib normal
quantile (``statistics.NormalDist().inv_cdf``); ``confidence=None`` (or
exactly 0.5, the median) prices at the plain mean with ``z = 0``.

Bit-identity contract (pinned by tests/test_calibration.py): the accumulator
is Welford's algorithm, whose mean stays *bitwise* equal to ``x`` over any
number of identical samples ``x`` (each update adds ``delta/n`` with
``delta == 0.0``) and whose m2 stays exactly ``0.0``. A ledger captured from
a run that matched the analytical model therefore rebuilds the analytical
scalars exactly, and :meth:`MeasuredCostTable.cost_model` returns the *base
CostModel object itself* whenever the materialized scalars are unchanged —
so a sigma=0 measured-table solve is the analytical solve, on every backend,
by construction.

Stdlib + numpy only (``cost_scalars`` needs numpy); no jax import.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from contextlib import contextmanager
from statistics import NormalDist
from typing import Dict, Iterable, Mapping, Optional

from .cost import CostModel, LinearTransfer, cost_scalars

__all__ = [
    "CALIBRATION_VERSION",
    "CalibrationError",
    "KernelStats",
    "MeasuredCostTable",
    "clear_measured_defaults",
    "install_measured_default",
    "measured_default",
    "use_measured",
    "z_score",
]

CALIBRATION_VERSION = 1

# Mirrors repro.obs.ledger.CATEGORIES without importing obs (keeps core
# importable on its own); checked for agreement in tests/test_calibration.py.
CATEGORIES = ("restore", "compute", "commit", "replay")


class CalibrationError(ValueError):
    """Malformed ledger rows, calibration files, or confidence levels."""


def z_score(confidence: Optional[float]) -> float:
    """Normal quantile for a one-sided confidence level in (0, 1).

    ``None`` and exactly ``0.5`` (the median) return ``0.0`` exactly — the
    sigma=0 path must not pick up an ``inv_cdf`` rounding residue.
    """
    if confidence is None:
        return 0.0
    c = float(confidence)
    if not 0.0 < c < 1.0 or math.isnan(c):
        raise CalibrationError(
            f"confidence must lie strictly in (0, 1), got {confidence!r}"
        )
    if c == 0.5:
        return 0.0
    return NormalDist().inv_cdf(c)


@dataclasses.dataclass
class KernelStats:
    """Welford running (count, mean, m2) for one energy category.

    Population variance (``m2 / count``): the ledger rows *are* the
    population of observed draws being replayed, not a sample from a larger
    experiment we never ran.
    """

    count: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def add(self, x: float) -> None:
        x = float(x)
        if math.isnan(x) or math.isinf(x):
            raise CalibrationError(f"non-finite energy sample {x!r}")
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self.m2 += delta * (x - self.mean)

    @property
    def variance(self) -> float:
        return self.m2 / self.count if self.count else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self.variance)

    @property
    def cv(self) -> float:
        """Coefficient of variation; 0.0 when unsampled or mean-free."""
        return self.std / abs(self.mean) if self.count and self.mean else 0.0

    def merge(self, other: "KernelStats") -> "KernelStats":
        """Chan's parallel Welford combine of two (count, mean, m2) triples.

        Exact contract: counts add exactly; an empty side returns the other
        side's moments bitwise; and merging accumulators whose means agree
        bitwise keeps that mean bitwise (``delta == 0.0``) — so fleets of
        devices that measured identical draws merge to the identical table,
        fingerprint included. For differing means the result equals
        sequential ingestion of the concatenated samples mathematically
        (pinned to ~ulp by the differential test), not bitwise — summation
        order is part of Welford's rounding.
        """
        if not isinstance(other, KernelStats):
            raise CalibrationError(
                f"merge takes a KernelStats, got {type(other).__name__}"
            )
        na, nb = self.count, other.count
        if nb == 0:
            return KernelStats(count=na, mean=self.mean, m2=self.m2)
        if na == 0:
            return KernelStats(count=nb, mean=other.mean, m2=other.m2)
        n = na + nb
        delta = other.mean - self.mean
        mean = self.mean + delta * (nb / n)
        m2 = self.m2 + other.m2 + delta * delta * (na * (nb / n))
        return KernelStats(count=n, mean=mean, m2=m2)

    def to_dict(self) -> Dict[str, object]:
        # float64 repr round-trips bitwise through json in Python 3
        return {"count": self.count, "mean": self.mean, "m2": self.m2}

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "KernelStats":
        """Load one stats entry, validating every field.

        The Welford invariants are enforced here — not left to the
        fingerprint check, which is skipped for legitimately fingerprint-free
        payloads and recomputable by anyone editing the file — so a NaN mean
        or negative count can never survive into confidence pricing. Raises
        the typed tamper error (:class:`CalibrationError`).
        """
        try:
            count = int(d["count"])
            mean = float(d["mean"])
            m2 = float(d["m2"])
        except (KeyError, TypeError, ValueError) as exc:
            raise CalibrationError(f"malformed stats entry {d!r}") from exc
        if count < 0:
            raise CalibrationError(
                f"stats entry has negative count {count}: the file was "
                f"edited or produced by an incompatible build"
            )
        if not math.isfinite(mean) or not math.isfinite(m2):
            raise CalibrationError(
                f"stats entry has non-finite mean/m2 ({mean!r}, {m2!r}): "
                f"the file was edited or produced by an incompatible build"
            )
        if m2 < 0.0:
            raise CalibrationError(
                f"stats entry has negative m2 {m2!r} (variance cannot be "
                f"negative): the file was edited or produced by an "
                f"incompatible build"
            )
        if count == 0 and (mean != 0.0 or m2 != 0.0):
            raise CalibrationError(
                f"stats entry claims zero samples but non-zero moments "
                f"(mean={mean!r}, m2={m2!r}): the file was edited or "
                f"produced by an incompatible build"
            )
        return cls(count=count, mean=mean, m2=m2)


class MeasuredCostTable:
    """Versioned, fingerprinted per-category measured energy statistics.

    Built from :class:`~repro.obs.ledger.EnergyLedger` rows (or a
    ``dump_json`` payload), carries the analytical ``base`` CostModel it
    calibrates, and materializes confidence-priced CostModels via
    :meth:`cost_model` — see the module docstring for the pricing rules and
    the bit-identity contract.
    """

    def __init__(
        self,
        base: CostModel,
        kind: str = "time",
        *,
        meta: Optional[Mapping[str, object]] = None,
    ) -> None:
        if not isinstance(base, CostModel):
            raise CalibrationError(
                f"base must be a CostModel, got {type(base).__name__}"
            )
        self.base = base
        self.kind = str(kind)
        self.stats: Dict[str, KernelStats] = {c: KernelStats() for c in CATEGORIES}
        self.meta: Dict[str, object] = dict(meta or {})

    # -- ingestion ---------------------------------------------------------

    def add(self, category: str, energy: float) -> None:
        if category not in self.stats:
            raise CalibrationError(
                f"unknown ledger category {category!r}; expected one of "
                f"{CATEGORIES}"
            )
        self.stats[category].add(energy)

    def ingest_rows(self, rows: Iterable[Mapping[str, object]]) -> int:
        """Ingest ``EnergyLedger.to_rows()``-shaped dicts; returns the count."""
        n = 0
        for row in rows:
            try:
                category, energy = row["category"], row["energy"]
            except (KeyError, TypeError) as exc:
                raise CalibrationError(
                    f"ledger row needs 'category' and 'energy' fields: {row!r}"
                ) from exc
            self.add(str(category), float(energy))
            n += 1
        return n

    def ingest_ledger(self, ledger) -> int:
        return self.ingest_rows(ledger.to_rows())

    @classmethod
    def from_ledger(
        cls, ledger, *, base: Optional[CostModel] = None, kind: str = "time"
    ) -> "MeasuredCostTable":
        table = cls(base if base is not None else _analytical_default(kind), kind)
        table.ingest_ledger(ledger)
        return table

    @classmethod
    def from_ledger_json(
        cls,
        path: str,
        *,
        base: Optional[CostModel] = None,
        kind: Optional[str] = None,
    ) -> "MeasuredCostTable":
        """Ingest an ``EnergyLedger.dump_json`` file (e.g. the traffic
        harness's ``--ledger-out``). Ledger meta keys (minus the bulky
        ``entries``/``summary``) carry over as provenance."""
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict) or "entries" not in payload:
            raise CalibrationError(
                f"{path}: not an EnergyLedger dump_json payload "
                "(no 'entries' list)"
            )
        k = str(kind if kind is not None else payload.get("kind", "time"))
        meta = {
            key: val
            for key, val in payload.items()
            if key not in ("entries", "summary")
        }
        table = cls(
            base if base is not None else _analytical_default(k), k, meta=meta
        )
        table.ingest_rows(payload["entries"])
        return table

    # -- multi-host aggregation --------------------------------------------

    @classmethod
    def merge(
        cls,
        *tables: "MeasuredCostTable",
        meta: Optional[Mapping[str, object]] = None,
    ) -> "MeasuredCostTable":
        """Merge per-device tables into one fleet table (ROADMAP multi-host
        profile aggregation): weighted Welford combine of every category's
        (count, mean, m2) via :meth:`KernelStats.merge`, left to right in
        argument order.

        All tables must share the ``kind`` and the exact base CostModel
        scalars — merging profiles calibrated against different analytical
        models is a typed error, not an average. Per-device provenance is
        recorded in the result's meta under ``"merged_from"`` (each source's
        fingerprint, sample count, and meta — device identity rides in the
        meta each ledger dump carried) and therefore lands in
        :meth:`to_payload`. A single-table merge reproduces that table's
        statistics bitwise.
        """
        if not tables:
            raise CalibrationError("merge needs at least one table")
        for t in tables:
            if not isinstance(t, MeasuredCostTable):
                raise CalibrationError(
                    f"merge takes MeasuredCostTable arguments, got "
                    f"{type(t).__name__}"
                )
        head = tables[0]
        ref = [float(x) for x in cost_scalars(head.base)]
        for t in tables[1:]:
            if t.kind != head.kind:
                raise CalibrationError(
                    f"cannot merge kind={t.kind!r} into kind={head.kind!r}: "
                    f"profiles of different graph kinds measure different "
                    f"quantities"
                )
            if (
                [float(x) for x in cost_scalars(t.base)] != ref
                or t.base.name != head.base.name
            ):
                raise CalibrationError(
                    f"cannot merge tables calibrated against different base "
                    f"models ({t.base.name!r} vs {head.base.name!r}): the "
                    f"merged statistics would price against neither"
                )
        out = cls(head.base, head.kind, meta=meta)
        for category in CATEGORIES:
            s = KernelStats()
            for t in tables:
                s = s.merge(t.stats[category])
            out.stats[category] = s
        out.meta.setdefault(
            "merged_from",
            [
                {
                    "fingerprint": t.fingerprint(),
                    "n_samples": t.n_samples,
                    "meta": dict(t.meta),
                }
                for t in tables
            ],
        )
        return out

    # -- identity ----------------------------------------------------------

    @property
    def n_samples(self) -> int:
        return sum(s.count for s in self.stats.values())

    def fingerprint(self) -> str:
        """sha256 over version, kind, base scalars, and the exact (count,
        mean, m2) per category — hex float encoding, so two tables agree iff
        their statistics agree bitwise."""
        h = hashlib.sha256()
        h.update(f"calibration-v{CALIBRATION_VERSION}\x00{self.kind}\x00".encode())
        h.update(self.base.name.encode() + b"\x00")
        h.update(" ".join(x.hex() for x in map(float, cost_scalars(self.base))).encode())
        for category in CATEGORIES:
            s = self.stats[category]
            h.update(
                f"\x00{category}:{s.count}:{float(s.mean).hex()}:"
                f"{float(s.m2).hex()}".encode()
            )
        return h.hexdigest()

    # -- pricing -----------------------------------------------------------

    def e_startup(self, confidence: Optional[float] = None) -> float:
        """Measured activation cost at the given confidence (base value when
        no restore samples were captured)."""
        r = self.stats["restore"]
        if not r.count:
            return float(self.base.e_startup)
        z = z_score(confidence)
        return r.mean + z * r.std if z else r.mean

    def transfer_scale(self, confidence: Optional[float] = None) -> float:
        """Multiplier applied to both transfer curves: ``1 + z·cv(commit)``."""
        z = z_score(confidence)
        cv = self.stats["commit"].cv
        return 1.0 + z * cv if z and cv else 1.0

    def cost_model(self, confidence: Optional[float] = None) -> CostModel:
        """Materialize the measured statistics as a plain CostModel.

        Returns ``self.base`` — the very same object — whenever the
        materialized scalars equal the base scalars bitwise, so a clean
        calibration loop (measurements match the model) keeps names,
        fingerprints, and solver outputs identical by construction.
        """
        e_s = self.e_startup(confidence)
        s = self.transfer_scale(confidence)
        base = self.base
        if e_s == float(base.e_startup) and s == 1.0:
            return base
        suffix = "+measured"
        z = z_score(confidence)
        if z:
            suffix += f"@{float(confidence):g}"
        return CostModel(
            e_startup=e_s,
            read=LinearTransfer(base.read.c0 * s, base.read.c1 * s),
            write=LinearTransfer(base.write.c0 * s, base.write.c1 * s),
            name=base.name + suffix,
        )

    # -- persistence -------------------------------------------------------

    def to_payload(self, **meta) -> Dict[str, object]:
        return {
            "version": CALIBRATION_VERSION,
            "kind": self.kind,
            "fingerprint": self.fingerprint(),
            "base": {
                "name": self.base.name,
                "e_startup": float(self.base.e_startup),
                "read": [float(self.base.read.c0), float(self.base.read.c1)],
                "write": [float(self.base.write.c0), float(self.base.write.c1)],
            },
            "stats": {c: self.stats[c].to_dict() for c in CATEGORIES},
            "meta": {**self.meta, **meta},
        }

    def to_json(self, path: str, **meta) -> None:
        with open(path, "w") as f:
            json.dump(self.to_payload(**meta), f, indent=2)
            f.write("\n")

    @classmethod
    def from_payload(cls, payload: Mapping[str, object]) -> "MeasuredCostTable":
        try:
            version = payload["version"]
        except (KeyError, TypeError) as exc:
            raise CalibrationError("not a calibration payload (no version)") from exc
        if version != CALIBRATION_VERSION:
            raise CalibrationError(
                f"calibration version {version!r} != supported "
                f"{CALIBRATION_VERSION}"
            )
        b = payload["base"]
        base = CostModel(
            e_startup=float(b["e_startup"]),
            read=LinearTransfer(*map(float, b["read"])),
            write=LinearTransfer(*map(float, b["write"])),
            name=str(b["name"]),
        )
        table = cls(base, str(payload["kind"]), meta=payload.get("meta"))
        for category, d in dict(payload["stats"]).items():
            if category not in table.stats:
                raise CalibrationError(f"unknown stats category {category!r}")
            table.stats[category] = KernelStats.from_dict(d)
        recorded = payload.get("fingerprint")
        if recorded is not None and recorded != table.fingerprint():
            raise CalibrationError(
                "calibration fingerprint mismatch: file was edited or "
                "written by an incompatible build"
            )
        return table

    @classmethod
    def from_json(cls, path: str) -> "MeasuredCostTable":
        with open(path) as f:
            return cls.from_payload(json.load(f))

    # -- reporting ---------------------------------------------------------

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "kind": self.kind,
            "base": self.base.name,
            "n_samples": self.n_samples,
            "fingerprint": self.fingerprint(),
        }
        for category in CATEGORIES:
            s = self.stats[category]
            out[category] = {"count": s.count, "mean": s.mean, "std": s.std}
        return out

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"MeasuredCostTable(kind={self.kind!r}, base={self.base.name!r}, "
            f"n_samples={self.n_samples}, "
            f"fingerprint={self.fingerprint()[:12]}…)"
        )


# ---------------------------------------------------------------------------
# Measured-default registry: slot a calibration in wherever the analytical
# default_cost_model would be consulted (plan builds, config-lowered specs).
# ---------------------------------------------------------------------------

_MEASURED_DEFAULTS: Dict[str, MeasuredCostTable] = {}


def _analytical_default(kind: str) -> CostModel:
    """The pre-calibration default — bypasses the measured registry so a
    table's ``base`` never recursively points at another calibration."""
    from .layer_profile import analytical_cost_model

    return analytical_cost_model(kind)


def install_measured_default(
    table: MeasuredCostTable, kind: Optional[str] = None
) -> None:
    """Register ``table`` as the default cost source for its graph kind:
    subsequent ``default_cost_model(kind)`` calls return
    ``table.cost_model()`` instead of the analytical model."""
    if not isinstance(table, MeasuredCostTable):
        raise CalibrationError(
            f"expected a MeasuredCostTable, got {type(table).__name__}"
        )
    _MEASURED_DEFAULTS[str(kind if kind is not None else table.kind)] = table


def measured_default(kind: str) -> Optional[MeasuredCostTable]:
    return _MEASURED_DEFAULTS.get(kind)


def clear_measured_defaults(kind: Optional[str] = None) -> None:
    if kind is None:
        _MEASURED_DEFAULTS.clear()
    else:
        _MEASURED_DEFAULTS.pop(str(kind), None)


@contextmanager
def use_measured(table: MeasuredCostTable, kind: Optional[str] = None):
    """Scoped :func:`install_measured_default` (restores the previous
    registration on exit) — what the traffic harness's ``--replan`` and the
    tests use."""
    key = str(kind if kind is not None else table.kind)
    previous = _MEASURED_DEFAULTS.get(key)
    install_measured_default(table, key)
    try:
        yield table
    finally:
        if previous is None:
            _MEASURED_DEFAULTS.pop(key, None)
        else:
            _MEASURED_DEFAULTS[key] = previous
