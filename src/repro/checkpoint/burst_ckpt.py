"""Burst-checkpointed training state (paper Algorithm 1 at pod scale).

The training loop executes in *bursts* of k steps. After each burst the full
state (params, optimizer, data cursor) is written to a new checkpoint and the
**burst index is committed atomically last** (write-temp → fsync → rename) —
the exact NVM protocol of the paper's runtime. A crash at any point loses at
most one uncommitted burst; on restart the loop resumes from the last
committed index and the deterministic data pipeline regenerates the same
batches (tests/test_checkpoint.py proves bit-exact resume).

``plan_burst_schedule`` chooses the checkpoint cadence with the Julienning
optimizer itself: tasks = steps, E_s = restart cost, E_w = state-write time,
Q_max = the maximum tolerated work-loss per failure (seconds). The sweep over
Q_max is the paper's design-space exploration applied to MTBF budgets.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..api import PartitionSpec, solve
from ..core import CostModel, GraphBuilder, LinearTransfer, Partition

__all__ = ["BurstCheckpointer", "plan_burst_schedule"]


class BurstCheckpointer:
    """Atomic, resumable checkpoint directory."""

    def __init__(self, path: str, keep: int = 2):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)

    def _index_file(self) -> str:
        return os.path.join(self.path, "burst_index")

    def committed_burst(self) -> int:
        f = self._index_file()
        if not os.path.exists(f):
            return 0
        with open(f) as fh:
            return int(fh.read().strip())

    def save(self, burst: int, state: Dict[str, Any]) -> None:
        """Write checkpoint ``burst``, then commit the index atomically."""
        ck = os.path.join(self.path, f"ckpt_{burst:08d}.pkl")
        fd, tmp = tempfile.mkstemp(dir=self.path)
        host_state = jax.tree.map(np.asarray, state)
        with os.fdopen(fd, "wb") as fh:
            pickle.dump(host_state, fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, ck)
        # linearization point — everything before this is invisible on crash
        fd, tmp = tempfile.mkstemp(dir=self.path)
        with os.fdopen(fd, "w") as fh:
            fh.write(str(burst))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self._index_file())
        self._gc(burst)

    def restore(self) -> Optional[Tuple[int, Dict[str, Any]]]:
        b = self.committed_burst()
        if b == 0:
            return None
        ck = os.path.join(self.path, f"ckpt_{b:08d}.pkl")
        with open(ck, "rb") as fh:
            return b, pickle.load(fh)

    def _gc(self, newest: int) -> None:
        for f in sorted(os.listdir(self.path)):
            if f.startswith("ckpt_"):
                idx = int(f.split("_")[1].split(".")[0])
                if idx <= newest - self.keep:
                    os.remove(os.path.join(self.path, f))


def plan_burst_schedule(
    n_steps: int,
    step_seconds: float,
    state_bytes: int,
    max_loss_seconds: float,
    restart_seconds: float = 30.0,
    disk_bw: float = 1e9,
) -> Partition:
    """Julienne the training run into checkpoint bursts.

    Returns the partition of steps into bursts minimizing total time
    (steps + checkpoint writes + per-burst restart exposure) such that no
    burst's work exceeds ``max_loss_seconds`` (the failure-loss budget).
    """
    b = GraphBuilder()
    prev = None
    for i in range(n_steps):
        pkt = b.packet(f"state{i}", state_bytes, keep=(i == n_steps - 1))
        reads = (prev,) if prev else ()
        b.task(f"step{i}", reads=reads, writes=(pkt,), cost=step_seconds)
        prev = pkt
    graph = b.build()
    cm = CostModel(
        e_startup=restart_seconds,
        read=LinearTransfer(c0=1.0, c1=1.0 / disk_bw),
        write=LinearTransfer(c0=1.0, c1=1.0 / disk_bw),
        name="ckpt-disk",
    )
    return solve(PartitionSpec(
        graph=graph, cost=cm, q_max=max_loss_seconds, backend="numpy",
    )).partition()
