"""The public Julienning API: declarative specs in, solutions out.

Everything the repo can solve — the paper's energy-bounded partition DP, the
§4.4 storage minimax, the exact-K pipeline DP, single graphs, zoo batches,
Q-grid device sharding, numpy/scan/Pallas backends — goes through one call::

    from repro.api import PartitionSpec, solve

    sol = solve(PartitionSpec(graph=g, cost=cm, q_max=132e-3))
    part = sol.partition()                 # a repro.core.Partition

    # the whole design space, batched and sharded
    sol = solve(PartitionSpec(
        config="qwen3-4b", shapes=((2, 24), (2, 48)), smoke=True,
        q_grid=(1e-3, 5e-3, None), sharding=QGridSharding(n_shards=8),
    ))
    sol.sweeps[0].e_total                  # per-Q optima, first bucket

    # §4.4 / pipeline objectives are just another axis of the spec
    solve(PartitionSpec(graph=g, cost=cm, objective="minimax")).q_min()

    # swarm placement: cut the chain across N harvesting nodes, sweeping
    # link bandwidth × node memory × node budget in one batched call
    sol = solve(PartitionSpec(graph=g, cost=cm, placement=PlacementSpec(
        nodes=3, links=tuple(LinkModel(bandwidth_mbps=b)
                             for b in range(900, 3400, 100)))))
    sol.placement_plan(link_index=0).summary()
    solve(PartitionSpec(graph=g, cost=cm, objective="exact_k",
                        n_bursts=4, k_objective="max")).partition()

Results reproduce the legacy entry points (``optimal_partition``,
``sweep_jax_batched``, …) **bit-identically** — the façade routes to the same
private implementations; see tests/test_api.py for the per-function
differential pins and the README "Public API" section for the migration
table. The legacy functions still work but emit :class:`DeprecationWarning`.

Backends self-register with capability flags; third-party code can add one::

    from repro.api import register_backend

    @register_backend("mine", objectives=("sum",), supports_dense=True)
    class MyBackend:
        def solve(self, req): ...

and address it with ``PartitionSpec(backend="mine")``.
"""

from __future__ import annotations

from .core._deprecation import JulienningDeprecationWarning
from .core.calibration import (
    CalibrationError,
    MeasuredCostTable,
    clear_measured_defaults,
    install_measured_default,
    use_measured,
)
from .core.engine import (
    OBJECTIVES,
    BackendInfo,
    Engine,
    EngineError,
    ExportMismatch,
    PartitionSpec,
    QGridSharding,
    Solution,
    SpecError,
    UnsupportedObjective,
    backend_info,
    backend_names,
    default_engine,
    export_kind,
    register_backend,
)
from .core.partition import Infeasible
from .core.placement import (
    LinkModel,
    NodeSpec,
    PlacementError,
    PlacementPlan,
    PlacementSpec,
    PlacementSweep,
    PlacementTable,
)

__all__ = [
    "OBJECTIVES",
    "BackendInfo",
    "CalibrationError",
    "Engine",
    "EngineError",
    "ExportMismatch",
    "Infeasible",
    "JulienningDeprecationWarning",
    "LinkModel",
    "MeasuredCostTable",
    "NodeSpec",
    "PartitionSpec",
    "PlacementError",
    "PlacementPlan",
    "PlacementSpec",
    "PlacementSweep",
    "PlacementTable",
    "QGridSharding",
    "Solution",
    "SpecError",
    "UnsupportedObjective",
    "backend_info",
    "backend_names",
    "clear_measured_defaults",
    "default_engine",
    "export_kind",
    "install_measured_default",
    "register_backend",
    "solve",
    "use_measured",
]


def solve(spec: PartitionSpec = None, **kwargs) -> Solution:
    """Solve a :class:`PartitionSpec` on the default engine.

    Accepts a prebuilt spec (positionally or as ``spec=``) or the spec's
    keyword arguments directly (``solve(graph=g, cost=cm, q_max=0.1)`` ≡
    ``solve(PartitionSpec(graph=g, cost=cm, q_max=0.1))``).
    """
    if spec is None:
        spec = PartitionSpec(**kwargs)
    elif kwargs:
        raise SpecError("pass a PartitionSpec or keywords, not both")
    return default_engine().solve(spec)
