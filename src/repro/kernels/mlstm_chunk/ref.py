"""Step-by-step sequential oracle for the chunked mLSTM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, i_pre, f_pre):
    """q/k/v: [BH, S, hd]; gates [BH, S] → [BH, S, hd]. Exact recurrence."""
    BH, S, hd = q.shape
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    i_pre = i_pre.astype(jnp.float32)
    f_pre = f_pre.astype(jnp.float32)

    def step(carry, t):
        C, n, m = carry
        logf = jax.nn.log_sigmoid(f_pre[:, t])
        m_new = jnp.maximum(logf + m, i_pre[:, t])
        gdec = jnp.exp(logf + m - m_new)[:, None, None]
        gsrc = jnp.exp(i_pre[:, t] - m_new)[:, None, None]
        C = C * gdec + gsrc * (kf[:, t, :, None] * vf[:, t, None, :])
        n = n * gdec[..., 0] + gsrc[..., 0] * kf[:, t]
        num = jnp.einsum("bd,bde->be", qf[:, t], C)
        den = jnp.einsum("bd,bd->b", qf[:, t], n)
        y = num / jnp.maximum(jnp.abs(den), 1.0)[:, None]
        return (C, n, m_new), y

    C0 = jnp.zeros((BH, hd, hd), jnp.float32)
    n0 = jnp.zeros((BH, hd), jnp.float32)
    m0 = jnp.full((BH,), -1e30, jnp.float32)
    _, ys = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype)
