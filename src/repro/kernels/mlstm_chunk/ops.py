"""Jit'd wrapper: model-layout chunked mLSTM cell."""

from __future__ import annotations

import jax

from .kernel import mlstm_chunk_bh
from .ref import mlstm_ref  # noqa: F401


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def mlstm_cell(q, k, v, i_pre, f_pre, *, chunk: int = 128,
               interpret: bool | None = None):
    """q/k/v: [B, S, H, hd]; gates [B, S, H] → [B, S, H, hd]."""
    if interpret is None:
        interpret = _is_cpu()
    B, S, H, hd = q.shape

    def fold(a):
        return a.transpose(0, 2, 1, *range(3, a.ndim)).reshape(B * H, S, *a.shape[3:])

    y = mlstm_chunk_bh(fold(q), fold(k), fold(v), fold(i_pre), fold(f_pre),
                       chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
