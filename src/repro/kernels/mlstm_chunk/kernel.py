"""Chunked mLSTM cell — Pallas TPU kernel (the xlstm-1.3b hot spot).

Grid: (B·H, n_chunks) with the chunk index innermost (sequential on TPU).
The matrix memory C [hd, hd], normalizer n [hd] and stabilizer m (scalar)
live in VMEM scratch across chunk iterations — the kernel computes, per
chunk: the intra-chunk masked linear attention (two MXU GEMMs on [L, hd]
tiles), the inter-chunk contribution from the carried state, and the state
update — the exact computation of ``repro.models.xlstm.mlstm_chunked``,
against which it is verified (tests/test_kernels.py).

Block shapes: q/k/v [L, hd] per (b·h, chunk); gate pre-activations [L]
arrive padded to [L, 1]. Default L=128 aligns the GEMMs with the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  C_scr, n_scr, m_scr, *, L: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        C_scr[...] = jnp.zeros_like(C_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(jnp.float32)        # [L, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    i_pre = i_ref[0][:, 0].astype(jnp.float32)   # [L]
    f_pre = f_ref[0][:, 0].astype(jnp.float32)

    logf = jax.nn.log_sigmoid(f_pre)
    cumf = jnp.cumsum(logf)                 # [L]
    # D[a, b] = cumf_a − cumf_b + i_b for b ≤ a
    D = cumf[:, None] - cumf[None, :] + i_pre[None, :]
    ar = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    ac = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    D = jnp.where(ar >= ac, D, NEG_INF)

    m_prev = m_scr[0]
    m_intra = D.max(axis=1)                             # [L]
    m_inter = cumf + m_prev
    m_i = jnp.maximum(m_intra, m_inter)

    sc = jnp.exp(D - m_i[:, None])
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, L]
    w = sc * qk
    y_num = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_den = w.sum(axis=1)

    g_inter = jnp.exp(m_inter - m_i)                    # [L]
    qC = jax.lax.dot_general(q, C_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, hd]
    qn = q @ n_scr[...]                                  # [L]
    y_num = y_num + g_inter[:, None] * qC
    y_den = y_den + g_inter * qn
    o_ref[0] = (y_num / jnp.maximum(jnp.abs(y_den), 1.0)[:, None]).astype(o_ref.dtype)

    # state update to end of chunk
    m_new = jnp.maximum(cumf[-1] + m_prev, (cumf[-1] - cumf + i_pre).max())
    gdec = jnp.exp(cumf[-1] + m_prev - m_new)
    gsrc = jnp.exp(cumf[-1] - cumf + i_pre - m_new)      # [L]
    kg = k * gsrc[:, None]
    C_scr[...] = C_scr[...] * gdec + jax.lax.dot_general(
        kg, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_scr[...] = n_scr[...] * gdec + kg.sum(axis=0)
    m_scr[0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_bh(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                   interpret: bool = False):
    """q/k/v: [BH, S, hd]; i_pre/f_pre: [BH, S] → y [BH, S, hd].

    Zero initial state (the kernel targets train/prefill from scratch; the
    carried-state variant threads (C, n, m) through HBM between calls).
    """
    BH, S, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0
    n_chunks = S // L
    ip = i_pre[..., None]
    fp = f_pre[..., None]

    return pl.pallas_call(
        functools.partial(_mlstm_kernel, L=L),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, 1), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),   # C
            pltpu.VMEM((hd,), jnp.float32),      # n
            pltpu.VMEM((1,), jnp.float32),       # m
        ],
        interpret=interpret,
    )(q, k, v, ip, fp)
