"""Pure-jnp oracle: the head-count window CNN via lax.conv (the same math as
``repro.core.apps.headcount._jax_kernels``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_window_scores_ref(windows, w1, b1, w2, b2, fc, fc_b):
    """windows: [N, 12, 12] → scores [N]."""
    x = windows.astype(jnp.float32)[..., None]
    x = jax.lax.conv_general_dilated(
        x, w1.astype(jnp.float32), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b1
    x = jax.nn.relu(x)
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                              (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = jax.lax.conv_general_dilated(
        x, w2.astype(jnp.float32), (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b2
    x = jax.nn.relu(x)
    feat = x.mean(axis=(1, 2))
    return feat @ fc.astype(jnp.float32) + fc_b
