"""Jit'd wrapper: score image windows with the head-count CNN weights dict."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import conv_window_scores
from .ref import conv_window_scores_ref  # noqa: F401


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def score_windows(windows, weights, *, interpret: bool | None = None):
    """windows: [N, 12, 12]; weights: the ``cnn_weights()`` dict → [N]."""
    if interpret is None:
        interpret = _is_cpu()
    return conv_window_scores(
        jnp.asarray(windows), weights["conv1"], weights["b1"],
        weights["conv2"], weights["b2"], weights["fc"], weights["fc_b"],
        interpret=interpret)
