"""Head-count CNN window scorer — Pallas TPU kernel (the paper's §5 hot spot).

Scores a batch of 12×12 image windows with the same small CNN the paper runs
per window (conv 3×3×8 → relu → 2×2 maxpool → conv 3×3×8×16 → relu → global
mean pool → fc): ~50 k MACs per window (Table 2's CNN kernels). The
MCU executes one window per task; the TPU adaptation batches ``blk`` windows
per grid step and rewrites both convolutions as im2col GEMMs so they run on
the MXU — the VMEM working set is the window block plus the (tiny) weights.

This is the "kernels of the paper as Pallas kernels" demonstrator; the
batteryless energy story lives in repro.core, this shows the same compute
expressed TPU-natively (DESIGN.md §2, hardware-adaptation record).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WIN = 12
C1, C2 = 8, 16


def _im2col(x, h, w, kh, kw):
    """x: [N, h, w, c] → [N, (h-kh+1)·(w-kw+1), kh·kw·c] via unrolled shifts."""
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(x[:, dy : dy + h - kh + 1, dx : dx + w - kw + 1, :])
    patch = jnp.concatenate(cols, axis=-1)  # [N, h', w', kh·kw·c]
    return patch.reshape(x.shape[0], -1, kh * kw * x.shape[-1])


def _conv_window_kernel(win_ref, w1_ref, b1_ref, w2_ref, b2_ref, fc_ref,
                        fcb_ref, o_ref):
    x = win_ref[...].astype(jnp.float32)            # [blk, 12, 12]
    N = x.shape[0]
    x = x[..., None]                                 # [blk, 12, 12, 1]

    # conv1 3×3×1×8 as im2col GEMM → [blk, 10·10, 8]
    p1 = _im2col(x, WIN, WIN, 3, 3)                  # [blk, 100, 9]
    w1 = w1_ref[...].astype(jnp.float32).reshape(9, C1)
    h1 = jax.lax.dot_general(p1, w1, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h1 = jax.nn.relu(h1 + b1_ref[...].astype(jnp.float32))
    h1 = h1.reshape(N, 10, 10, C1)

    # 2×2 max pool → [blk, 5, 5, 8]
    h1 = jnp.maximum(jnp.maximum(h1[:, 0::2, 0::2], h1[:, 1::2, 0::2]),
                     jnp.maximum(h1[:, 0::2, 1::2], h1[:, 1::2, 1::2]))

    # conv2 3×3×8×16 as im2col GEMM → [blk, 3·3, 16]
    p2 = _im2col(h1, 5, 5, 3, 3)                     # [blk, 9, 72]
    w2 = w2_ref[...].astype(jnp.float32).reshape(9 * C1, C2)
    h2 = jax.lax.dot_general(p2, w2, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    h2 = jax.nn.relu(h2 + b2_ref[...].astype(jnp.float32))

    feat = h2.mean(axis=1)                           # [blk, 16]
    score = feat @ fc_ref[...].astype(jnp.float32) + fcb_ref[0]
    o_ref[...] = score.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def conv_window_scores(windows, w1, b1, w2, b2, fc, fc_b, *, blk: int = 128,
                       interpret: bool = False):
    """windows: [N, 12, 12] float32 → scores [N]."""
    N = windows.shape[0]
    blk = min(blk, N)
    if N % blk:
        blk = next(b for b in range(blk, 0, -1) if N % b == 0)
    return pl.pallas_call(
        _conv_window_kernel,
        grid=(N // blk,),
        in_specs=[
            pl.BlockSpec((blk, WIN, WIN), lambda i: (i, 0, 0)),
            pl.BlockSpec((3, 3, 1, C1), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((C1,), lambda i: (0,)),
            pl.BlockSpec((3, 3, C1, C2), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((C2,), lambda i: (0,)),
            pl.BlockSpec((C2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(windows, w1, b1, w2, b2, fc, fc_b.reshape(1))
