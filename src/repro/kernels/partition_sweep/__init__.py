from .ops import *  # noqa: F401,F403
