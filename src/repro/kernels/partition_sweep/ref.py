"""Numpy oracles for the CSR column-sweep/DP kernel — all three objectives.

Replays :class:`repro.core.burst.ColumnSweep` and the fused DPs of
:func:`repro.core.partition.optimal_partition_multi` (sum),
:func:`repro.core.partition.q_min` (minimax), and
:func:`repro.core.partition.optimal_partition_k` (exact-K) directly from a
:class:`repro.core.graph.GraphCSRArrays` export — same slot order, same
left-to-right accumulation, same first-minimum argmin and budget tolerance —
so the (mns, bests) column tables are **bit-identical** to the numpy DP
tables on every graph, and the Pallas kernel (which replays the identical
order per i-tile, in the matching mode) is asserted bit-equal against them
in tests/test_partition_sweep.py. All three share one live-column iterator,
so the column bit patterns cannot drift between objectives.

Outputs follow the engine's column convention (see
:func:`repro.core.partition_jax.sweep_from_columns`): ``mns[j-1, q]`` is
``dp[q, j]`` — the optimal cost of tasks 1..j under budget ``q`` — and
``bests[j-1, q]`` is the start task of the last burst achieving it
(smallest such start on ties). Infeasibility is carried by ``mns`` alone
(``inf`` there → ``feasible`` False downstream); on an all-infeasible
column ``bests`` degenerates to 1 — numpy's argmin over an all-inf row —
exactly like the scan engine, and those parents are never walked.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from ...core.cost import CostModel, cost_scalars
from ...core.graph import GraphCSRArrays
from ...core.partition import BUDGET_ABS as _ABS, BUDGET_REL as _REL

__all__ = [
    "slot_costs",
    "store_add_ref",
    "sweep_columns_ref",
    "sweep_columns_minimax_ref",
    "sweep_columns_exactk_ref",
]


def slot_costs(
    csr: GraphCSRArrays, cost: CostModel
) -> Tuple[np.ndarray, np.ndarray]:
    """Per read slot: (E_r of the packet, E_w of the packet).

    ``E_w`` of a *read* packet is the store that gets charged back when one
    burst absorbs both the writer and the last reader (the recurrence's
    freed-store term).
    """
    _, r_c0, r_c1, w_c0, w_c1 = cost_scalars(cost)
    slot_cost = r_c0 * csr.read_c0w + r_c1 * csr.read_bytes
    slot_free = w_c0 * csr.read_c0w + w_c1 * csr.read_bytes
    return slot_cost, slot_free


def store_add_ref(csr: GraphCSRArrays, cost: CostModel) -> np.ndarray:
    """S(j) = Σ_{p ∈ writes(j), l_∞(p) > j} E_w(p), slot-by-slot.

    Computed host-side in write-slot declaration order — the exact float64
    rounding sequence of ``ColumnSweep``'s Python sum — and fed to both the
    Pallas kernel and this oracle so S(j) is one bit pattern everywhere.
    """
    _, _, _, w_c0, w_c1 = cost_scalars(cost)
    n = csr.n_pad
    out = np.zeros(n, dtype=np.float64)
    ptr = csr.write_ptr
    for j in range(1, n + 1):
        s = 0.0
        for k in range(int(ptr[j - 1]), int(ptr[j])):
            if int(csr.write_linf[k]) > j:
                s += w_c0 * float(csr.write_c0w[k]) + w_c1 * float(csr.write_bytes[k])
        out[j - 1] = s
    return out


def _iter_columns(csr: GraphCSRArrays, cost: CostModel):
    """Yield ``(j, col)`` for j = 1..n_pad with ``col[i] = E⟨i,j⟩``.

    The live-column update — extension, loads, freed stores, diagonal — in
    ColumnSweep's exact accumulation order, shared by all three DP oracles
    below so the column bit patterns are one sequence everywhere. ``col`` is
    updated in place; callers must not hold references across iterations.
    """
    n = csr.n_pad
    e_s = float(cost.e_startup)
    slot_cost, slot_free = slot_costs(csr, cost)
    store_add = store_add_ref(csr, cost)
    ptr = csr.read_ptr
    col = np.full(n + 2, np.nan, dtype=np.float64)

    for j in range(1, n + 1):
        e_j = float(csr.e_task[j - 1])
        s_j = float(store_add[j - 1])
        # 1) extend all existing bursts ⟨i, j-1⟩ with task j
        if j > 1:
            col[1:j] += e_j + s_j
        sum_er = 0.0
        for k in range(int(ptr[j - 1]), int(ptr[j])):
            er = float(slot_cost[k])
            sum_er += er
            lt = int(csr.read_lt[k])
            if j > 1 and lt + 1 < j:  # loads for bursts starting after last touch
                col[lt + 1 : j] += er
            if j > 1 and int(csr.read_linf[k]) == j:
                w = int(csr.read_writer[k])
                if w >= 1:  # store freed when the burst absorbs the writer
                    col[1 : w + 1] -= float(slot_free[k])
        # 2) the new single-task burst ⟨j,j⟩
        col[j] = e_s + sum_er + e_j + s_j
        yield j, col


def sweep_columns_ref(
    csr: GraphCSRArrays,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
) -> Tuple[np.ndarray, np.ndarray]:
    """CSR column sweep + multi-Q DP: (mns, bests), each ``(N, nq)``.

    N is the padded task count (padded tasks have zero cost and no slots, so
    their columns just extend bursts with E_s bookkeeping — identical to the
    dense engine's padding behavior).
    """
    n = csr.n_pad
    qs = np.array(
        [np.inf if q is None else float(q) for q in q_values], dtype=np.float64
    )
    nq = qs.shape[0]
    budget = qs * (1.0 + _REL) + _ABS

    mns = np.full((n, nq), np.inf, dtype=np.float64)
    bests = np.zeros((n, nq), dtype=np.int32)  # every column overwritten below
    dp = np.full((nq, n + 1), np.inf, dtype=np.float64)
    dp[:, 0] = 0.0

    for j, col in _iter_columns(csr, cost):
        # DP relaxation over the whole Q grid (first-minimum argmin)
        c = col[1 : j + 1]
        cand = dp[:, 0:j] + c[None, :]
        cand[c[None, :] > budget[:, None]] = np.inf
        best = np.argmin(cand, axis=1)
        dp[:, j] = cand[np.arange(nq), best]
        mns[j - 1] = dp[:, j]
        bests[j - 1] = best + 1

    return mns, bests


def sweep_columns_minimax_ref(
    csr: GraphCSRArrays, cost: CostModel
) -> Tuple[np.ndarray, np.ndarray]:
    """CSR column sweep + §4.4 minimax DP: (mns, bests), each ``(N, 1)``.

    ``mns[j-1, 0] = mm[j] = min_i max(mm[i-1], E⟨i,j⟩)`` — the max/min
    combine is exact in float64, so this matches
    :func:`repro.core.partition.q_min` bit-for-bit at ``mns[n_tasks-1, 0]``
    and the kernel's minimax mode matches it at *every* entry, argmin
    tie-breaks included.
    """
    n = csr.n_pad
    mns = np.full((n, 1), np.inf, dtype=np.float64)
    bests = np.zeros((n, 1), dtype=np.int32)
    mm = np.full(n + 1, np.inf, dtype=np.float64)
    mm[0] = 0.0

    for j, col in _iter_columns(csr, cost):
        cand = np.maximum(mm[0:j], col[1 : j + 1])
        best = int(np.argmin(cand))
        mm[j] = cand[best]
        mns[j - 1, 0] = mm[j]
        bests[j - 1, 0] = best + 1

    return mns, bests


def sweep_columns_exactk_ref(
    csr: GraphCSRArrays,
    cost: CostModel,
    q_max: Optional[float],
    n_bursts: int,
    k_objective: str = "sum",
) -> Tuple[np.ndarray, np.ndarray]:
    """CSR column sweep + exact-K DP: (vals, bsts), each ``(N, K+1)``.

    Lane b of column j holds ``dp[b, j]`` / its parent burst start — the
    same layout the kernel's ``exact_k`` mode emits on its lane axis (and
    :func:`repro.core.partition_jax._exactk_sweep` on its K axis), so the
    host parent walk is shared. Lane b = 0 is the degenerate zero-burst
    row: every candidate is infeasible, so ``vals[:, 0]`` is inf and
    ``bsts[:, 0]`` pins the all-inf argmin at burst start 1, exactly like
    the kernel — those parents are never walked.
    """
    n = csr.n_pad
    K = int(n_bursts)
    q = np.inf if q_max is None else float(q_max)
    budget = q * (1.0 + _REL) + _ABS
    combine = np.maximum if k_objective == "max" else (lambda prev, c: prev + c)

    vals = np.full((n, K + 1), np.inf, dtype=np.float64)
    bsts = np.ones((n, K + 1), dtype=np.int32)
    dp = np.full((K + 1, n + 1), np.inf, dtype=np.float64)
    dp[0, 0] = 0.0

    for j, col in _iter_columns(csr, cost):
        c = col[1 : j + 1].copy()
        c[c > budget] = np.inf
        for b in range(1, K + 1):
            cand = combine(dp[b - 1, 0:j], c)
            best = int(np.argmin(cand))
            dp[b, j] = cand[best]
            vals[j - 1, b] = dp[b, j]
            bsts[j - 1, b] = best + 1

    return vals, bsts
