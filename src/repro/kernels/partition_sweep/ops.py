"""Host wrapper for the CSR sweep kernel: numpy in, numpy column tables out.

``sweep_columns`` is the kernel package's public entry point: it takes a
:class:`repro.core.graph.GraphCSRArrays` export plus a cost model and an
objective — a Q_max grid for ``"sum"``, nothing extra for ``"minimax"``,
``(Q_max, n_bursts, k_objective)`` for ``"exact_k"`` — prices the slots
(the export itself is cost-model-independent), and launches
:func:`.kernel.sweep_columns_call` in the matching static mode. The engine
(:mod:`repro.core.partition_jax`, ``backend="pallas"``) assembles the
returned (mns, bests) into a :class:`~repro.core.partition_jax.JaxSweep`
(sum), a Q_min scalar (minimax), or an exact-K parent walk; tests compare
them bit-for-bit against the :mod:`.ref` oracles.

Serving-path notes (ROADMAP "hoist dtype handling"):

* float64 numerics need ``jax.experimental.enable_x64``; the scope is
  entered here, around conversion + dispatch only, and it is a cheap
  thread-local flag — the jit cache is keyed per config state, so repeated
  calls reuse one trace (asserted by tests/test_partition_sweep.py).
* the priced slot arrays are device-cached per ``(export, cost model,
  dtype)``, so a serving loop re-solving one application across request
  shapes uploads the graph once, not per request.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ...core._cache import weak_id_cache
from ...core.cost import CostModel
from ...core.graph import GraphCSRArrays
from .kernel import sweep_columns_call
from .ref import (  # noqa: F401  (re-exported oracles)
    _ABS,
    _REL,
    slot_costs,
    store_add_ref,
    sweep_columns_exactk_ref,
    sweep_columns_minimax_ref,
    sweep_columns_ref,
)

__all__ = [
    "sweep_columns",
    "sweep_columns_ref",
    "sweep_columns_minimax_ref",
    "sweep_columns_exactk_ref",
    "slot_costs",
    "store_add_ref",
]


def _needs_interpret() -> bool:
    # Compiled mode is TPU-only (pltpu memory spaces); everything else —
    # CPU and GPU backends alike — takes the interpret path.
    return jax.default_backend() != "tpu"


# (id(csr), cost, dtype name) -> priced + uploaded slot arrays (see
# core/_cache.py for the id+weakref idiom).
_DEVICE_CACHE: dict = {}


def _device_slots(csr: GraphCSRArrays, cost: CostModel, dtype) -> tuple:
    def upload():
        slot_cost, slot_free = slot_costs(csr, cost)
        store_add = store_add_ref(csr, cost)
        return (
            jnp.asarray(csr.read_ptr),
            jnp.asarray(csr.e_task, dtype=dtype),
            jnp.asarray(store_add, dtype=dtype),
            jnp.asarray(np.array([cost.e_startup]), dtype=dtype),
            jnp.asarray(slot_cost, dtype=dtype),
            jnp.asarray(slot_free, dtype=dtype),
            jnp.asarray(csr.read_lt),
            jnp.asarray(csr.read_writer),
            jnp.asarray(csr.read_linf),
        )

    return weak_id_cache(
        _DEVICE_CACHE, csr, (cost, np.dtype(dtype).name), upload
    )


def sweep_columns(
    csr: GraphCSRArrays,
    cost: CostModel,
    q_values: Sequence[Optional[float]],
    *,
    objective: str = "sum",
    n_bursts: Optional[int] = None,
    k_objective: str = "sum",
    tile: int = 512,
    slot_chunk: int = 1,
    interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Solve one CSR export in one kernel mode: → (mns, bests) tables.

    ``objective="sum"`` (default) sweeps the Q grid: ``mns[j-1, q]`` is
    dp[q, j] (optimal cost of tasks 1..j under Q[q]), ``bests[j-1, q]`` the
    start of the last burst achieving it; tables are ``(N, nq)``.

    ``objective="minimax"`` takes no Q grid (pass ``q_values=()``): tables
    are ``(N, 1)`` with ``mns[j-1, 0] = mm[j]`` — Q_min is
    ``mns[n_tasks-1, 0]``.

    ``objective="exact_k"`` takes exactly one Q value (the single Q_max,
    ``None`` for unbounded) plus ``n_bursts=K`` and ``k_objective``
    ("sum" | "max"); tables are ``(N, K+1)`` with lane b = dp[b, j] /
    parent — the layout of :func:`.ref.sweep_columns_exactk_ref`.

    Infeasible entries carry ``inf`` in mns; bests are only meaningful
    where finite. ``interpret=None`` auto-selects interpret mode on every
    non-TPU backend (float64, differential-exact); compiled TPU mode runs
    float32.
    """
    if interpret is None:
        interpret = _needs_interpret()
    dtype = np.float64 if interpret else np.float32

    combine_max = False
    if objective == "sum":
        qs = np.array(
            [np.inf if q is None else float(q) for q in q_values],
            dtype=np.float64,
        )
        nq = qs.shape[0]
        nq_pad = max(8, -(-nq // 8) * 8)
        budget = np.full(nq_pad, -np.inf, dtype=np.float64)
        budget[:nq] = qs * (1.0 + _REL) + _ABS
    elif objective == "minimax":
        if len(tuple(q_values)) != 0:
            raise ValueError("objective='minimax' takes no Q grid")
        combine_max = True
        nq, nq_pad = 1, 8
        # Lane 0 is the single unconstrained minimax lane; padding -inf.
        budget = np.full(nq_pad, -np.inf, dtype=np.float64)
        budget[0] = np.inf
    elif objective == "exact_k":
        qv = tuple(q_values)
        if len(qv) != 1:
            raise ValueError("objective='exact_k' takes exactly one Q_max")
        if n_bursts is None or int(n_bursts) < 1:
            raise ValueError("objective='exact_k' needs n_bursts >= 1")
        if k_objective not in ("sum", "max"):
            raise ValueError(f"unknown k_objective {k_objective!r}")
        combine_max = k_objective == "max"
        K = int(n_bursts)
        q = np.inf if qv[0] is None else float(qv[0])
        nq = K + 1  # lane axis is the burst count b = 0..K
        nq_pad = max(8, -(-nq // 8) * 8)
        budget = np.full(nq_pad, -np.inf, dtype=np.float64)
        budget[:nq] = q * (1.0 + _REL) + _ABS
    else:
        raise ValueError(f"unknown kernel objective {objective!r}")

    with enable_x64(bool(interpret)):
        args = _device_slots(csr, cost, dtype)
        mns, bests = sweep_columns_call(
            *args,
            jnp.asarray(budget, dtype=dtype),
            tile=tile,
            slot_chunk=slot_chunk,
            interpret=bool(interpret),
            mode=objective,
            combine_max=combine_max,
        )
        return np.asarray(mns)[:, :nq], np.asarray(bests)[:, :nq]
