"""Fused CSR column-sweep + multi-Q DP — Pallas TPU kernel (paper §4.2–§4.3).

One kernel juliennes a whole application: it walks tasks j = 1..N carrying
the live burst column E⟨·,j⟩ and the DP table, with a grid of
``(N, n_tiles)`` — the minor grid axis is **one program per column tile of
i-indices**, so each program owns a ``(tile, 1)`` slice of the column and a
``(tile, nq)`` slice of the DP candidates, all resident in VMEM scratch
across the sequential grid.

Read-slot contributions come from the CSR-style compressed slot layout of
:class:`repro.core.graph.GraphCSRArrays` (flat ``slot_task_ptr`` /
``slot_cost`` / ``slot_lt`` / ``slot_writer`` / ``slot_linf`` arrays instead
of the dense ``(N, R)`` rectangle): each program loops over task j's slot
range and applies the three piecewise-constant updates in-register:

    E⟨i,j⟩ = E⟨i,j-1⟩ + E_task(j) + S(j)
           + Σ_{p ∈ reads(j)}  E_r(p) · [i > l_j(p)]             (new loads)
           - Σ_{p ∈ reads(j)}  E_w(p) · [l_∞(p) = j] · [1 ≤ writer(p)]
                                       · [i ≤ writer(p)]          (store freed)
    E⟨j,j⟩ = E_s + Σ E_r(p) + E_task(j) + S(j)

then runs one of three DP combines over the same live column, selected by
the **static** ``mode`` argument (each mode jit-caches its own lowered
kernel — the paper's §4.3 sum DP and both §4.4 variants are all kernel
modes now):

* ``mode="sum"`` — ``dp[q, j] = min_{i ≤ j, E⟨i,j⟩ ≤ Q[q]} dp[q, i-1] +
  E⟨i,j⟩`` for every Q at once (the lane axis is the Q grid);
* ``mode="minimax"`` — the §4.4 storage minimization ``mm[j] = min_i
  max(mm[i-1], E⟨i,j⟩)`` (one real lane, budget +inf — Q_min is
  ``mns[n-1, 0]``);
* ``mode="exact_k"`` — the fixed-burst-count DP ``dp[b, j] = min_{i,
  E⟨i,j⟩ ≤ Q} combine(dp[b-1, i-1], E⟨i,j⟩)``: the lane axis carries the
  burst count b = 0..K, so the K-indexed table tiles through the identical
  slot-chunked column scan; the predecessor table is the previous column's
  lanes shifted one lane right (lane 0 refills +inf). ``combine`` is ``+``
  or ``max`` per the static ``combine_max`` flag (the pipeline-bottleneck
  variant).

Every mode tie-breaks its argmin to the smallest burst start. With
``slot_chunk=1`` (default) the slot loop replays numpy's exact
accumulation order, so the emitted column tables are bit-identical to
:mod:`.ref` — and hence to the numpy DP oracles — including argmin
tie-breaks; ``slot_chunk>1`` processes slots in vectorized chunks (one
masked 2-D reduction per chunk, ~ulp drift, for TPU throughput; on exact
dyadic-cost graphs the chunked reductions are still exact, which the tie
audit pins across all three modes).

Compiled-mode TPU use is float32 (f64 is interpret-only); the engine's
differential guarantees are stated for the f64 interpret path, which is
also the CPU production path (the whole grid lowers to one XLA while-loop).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...obs.metrics import METRICS

# Trace-count regression hook: incremented at trace time only, so tests can
# assert that serving-style loops re-dispatch the cached kernel instead of
# re-tracing (see the enable_x64-hoist note in repro/core/partition_jax.py).
# Registry-backed (repro.obs.metrics) but still a plain dict to consumers.
TRACE_COUNT = METRICS.counter_dict(
    "kernel.partition_sweep.trace_count",
    ("sweep_columns", "sweep_columns_minimax", "sweep_columns_exact_k"),
)


def _sweep_kernel(
    ptr_ref,          # (N+1,)       i32  SMEM  read-slot row pointers
    etask_ref,        # (N,)         f    SMEM  E_task(j)
    store_ref,        # (N,)         f    SMEM  S(j)
    es_ref,           # (1,)         f    SMEM  E_s
    cost_ref,         # (1, nnz_pad) f    VMEM  E_r per read slot
    free_ref,         # (1, nnz_pad) f    VMEM  E_w of the read packet
    lt_ref,           # (1, nnz_pad) i32  VMEM  l_j(p)
    writer_ref,       # (1, nnz_pad) i32  VMEM  writer(p)
    linf_ref,         # (1, nnz_pad) i32  VMEM  l_∞(p)
    budget_ref,       # (1, nq_pad)  f    VMEM  Q·(1+rel)+abs, -inf padding
    mns_ref,          # (N, nq_pad)  f    out   dp[q, j] per column
    best_ref,         # (N, nq_pad)  i32  out   argmin burst start per column
    colbuf,           # (Npad, 1)    f    VMEM scratch: live column E⟨·,j⟩
    dpbuf,            # (Npad, nq)   f    VMEM scratch: dp[q, i-1] table
    accmin,           # (1, nq_pad)  f    VMEM scratch: cross-tile running min
    accarg,           # (1, nq_pad)  i32  VMEM scratch: cross-tile argmin
    *,
    n_tiles: int,
    tile: int,
    slot_chunk: int,
    dtype,
    mode: str,
    combine_max: bool,
):
    B, C = tile, slot_chunk
    j = pl.program_id(0) + np.int32(1)   # task / column index, 1..N
    t = pl.program_id(1)                 # i-tile index, 0..n_tiles-1
    base = t * np.int32(B)

    # Shared scratch is initialized by the very first program in the grid.
    @pl.when((j == 1) & (t == 0))
    def _():
        dpbuf[...] = jnp.full(dpbuf.shape, jnp.inf, dtype)
        if mode == "exact_k":
            # dp[b, 0]: the empty prefix is reachable with zero bursts only.
            lane = lax.broadcasted_iota(jnp.int32, (dpbuf.shape[1],), 0)
            dpbuf[0, :] = jnp.where(lane == 0, jnp.asarray(0.0, dtype), jnp.inf)
        else:
            dpbuf[0, :] = jnp.zeros((dpbuf.shape[1],), dtype)  # dp[q, 0] = 0
        colbuf[...] = jnp.zeros(colbuf.shape, dtype)

    i_vec = base + np.int32(1) + lax.broadcasted_iota(jnp.int32, (B, 1), 0)
    prev = i_vec < j                      # bursts ⟨i, j-1⟩ being extended
    e_j = etask_ref[j - 1]
    s_j = store_ref[j - 1]
    colt = colbuf[pl.ds(base, B), :]
    colt = jnp.where(prev, colt + (e_j + s_j), colt)

    p0 = ptr_ref[j - 1]
    p1 = ptr_ref[j]

    if C == 1:
        # Slot-at-a-time: numpy's exact accumulation order (bit parity).
        def slot(s, carry):
            colt, sum_er = carry
            idx = p0 + s
            sc = cost_ref[0, idx]
            colt = jnp.where(prev & (i_vec > lt_ref[0, idx]), colt + sc, colt)
            w = writer_ref[0, idx]
            freed = (linf_ref[0, idx] == j) & (w >= np.int32(1))
            colt = jnp.where(
                prev & freed & (i_vec <= w), colt - free_ref[0, idx], colt
            )
            return colt, sum_er + sc

        colt, sum_er = lax.fori_loop(
            0, p1 - p0, slot, (colt, jnp.asarray(0.0, dtype))
        )
    else:
        # Chunked: one masked 2-D reduction per C slots (~ulp drift).
        def chunk(s, carry):
            colt, sum_er = carry
            idx0 = p0 + s * np.int32(C)
            lanes = idx0 + lax.broadcasted_iota(jnp.int32, (1, C), 1)
            valid = lanes < p1
            sc = jnp.where(valid, cost_ref[0, pl.ds(idx0, C)], 0.0)
            sf = jnp.where(valid, free_ref[0, pl.ds(idx0, C)], 0.0)
            slt = lt_ref[0, pl.ds(idx0, C)]
            swr = writer_ref[0, pl.ds(idx0, C)]
            sli = linf_ref[0, pl.ds(idx0, C)]
            loads = jnp.sum(
                jnp.where(i_vec > slt, sc, 0.0), axis=1, keepdims=True
            )
            freed = jnp.sum(
                jnp.where(
                    ((sli == j) & (swr >= np.int32(1))) & (i_vec <= swr),
                    sf,
                    0.0,
                ),
                axis=1,
                keepdims=True,
            )
            colt = jnp.where(prev, colt + loads - freed, colt)
            return colt, sum_er + jnp.sum(sc)

        nchunks = lax.div(p1 - p0 + np.int32(C - 1), np.int32(C))
        colt, sum_er = lax.fori_loop(
            0, nchunks, chunk, (colt, jnp.asarray(0.0, dtype))
        )

    # The new single-task burst ⟨j,j⟩ (left-to-right, ColumnSweep's order).
    diag = es_ref[0] + sum_er + e_j + s_j
    colt = jnp.where(i_vec == j, diag, colt)
    colbuf[pl.ds(base, B), :] = colt

    # DP relaxation over this tile. dpbuf rows [base, base+B) hold
    # dp[q, i-1] for the tile's i values; rows ≥ j are still inf, so
    # beyond-diagonal candidates drop out automatically.
    dpt = dpbuf[pl.ds(base, B), :]
    if mode == "exact_k":
        # Lane b needs dp[b-1, i-1]: shift the burst-count axis one lane
        # right; lane 0 (zero bursts covering a non-empty prefix) refills
        # +inf, so the b=0 output row degenerates to an all-infeasible
        # column (val inf, argmin 1) that callers never walk.
        dpt = jnp.concatenate(
            [jnp.full((B, 1), jnp.inf, dtype), dpt[:, :-1]], axis=1
        )
    masked = jnp.where(colt <= budget_ref[...], colt, jnp.inf)
    cand = jnp.maximum(dpt, masked) if combine_max else dpt + masked
    tmin = jnp.min(cand, axis=0)                                  # (nq_pad,)
    # First i achieving the min (the sentinel never survives: inf == inf on
    # an all-infeasible column still selects i = 1, like numpy's argmin —
    # infeasibility is carried by mns, bests are only walked where finite).
    targ = jnp.min(
        jnp.where(cand == tmin[None, :], i_vec, np.int32(n_tiles * B + 1)),
        axis=0,
    )

    # Cross-tile combine: strict < keeps the earliest tile on exact ties,
    # matching numpy's first-minimum argmin.
    @pl.when(t == 0)
    def _():
        accmin[0, :] = tmin
        accarg[0, :] = targ

    @pl.when(t > 0)
    def _():
        better = tmin < accmin[0, :]
        accarg[0, :] = jnp.where(better, targ, accarg[0, :])
        accmin[0, :] = jnp.minimum(accmin[0, :], tmin)

    @pl.when(t == n_tiles - 1)
    def _():
        mns_ref[pl.ds(j - 1, 1), :] = accmin[0, :][None, :]
        best_ref[pl.ds(j - 1, 1), :] = accarg[0, :][None, :]

        @pl.when(j < dpbuf.shape[0])
        def _():
            dpbuf[pl.ds(j, 1), :] = accmin[0, :][None, :]


@functools.partial(
    jax.jit,
    static_argnames=("tile", "slot_chunk", "interpret", "mode", "combine_max"),
)
def sweep_columns_call(
    read_ptr,      # (N+1,)  i32
    e_task,        # (N,)    f
    store_add,     # (N,)    f
    e_startup,     # (1,)    f
    slot_cost,     # (nnz,)  f
    slot_free,     # (nnz,)  f
    slot_lt,       # (nnz,)  i32
    slot_writer,   # (nnz,)  i32
    slot_linf,     # (nnz,)  i32
    budget,        # (nq_pad,) f   already tolerance-scaled; -inf padding
    *,
    tile: int = 512,
    slot_chunk: int = 1,
    interpret: bool = True,
    mode: str = "sum",
    combine_max: bool = False,
):
    """Launch the sweep kernel: → (mns, bests), each ``(N, nq_pad)``.

    Shapes are static per (N, nnz, nq_pad, tile, slot_chunk); the static
    ``mode`` / ``combine_max`` pair selects the DP combine (see module
    docstring) and keys the jit cache alongside them, so each objective
    caches its own lowered kernel and serving loops re-dispatch without
    re-tracing. The lane axis is the Q grid for ``mode="sum"``, a single
    real lane for ``"minimax"`` (budget lane 0 = +inf), and the burst
    count b = 0..K for ``"exact_k"`` (budget lanes 0..K = the single
    scaled Q_max, -inf beyond). Inputs are taken in whatever float dtype
    ``e_task`` carries (float64 under interpret mode — the
    differential-exact path — float32 for compiled TPU).
    """
    TRACE_COUNT[
        "sweep_columns" if mode == "sum" else f"sweep_columns_{mode}"
    ] += 1
    N = e_task.shape[0]
    nq_pad = budget.shape[0]
    dtype = e_task.dtype
    B = min(tile, max(8, N))
    T = -(-N // B)
    C = slot_chunk
    nnz = slot_cost.shape[0]
    # Slot pool padded so every C-wide dynamic load stays in bounds without
    # clamping (clamped loads would misalign the validity mask).
    nnz_pad = (-(-max(nnz, 1) // C) + 1) * C

    def pad1(a):
        return jnp.pad(a, (0, nnz_pad - nnz))[None, :]

    vspec = lambda shape: pl.BlockSpec(shape, lambda j, t: (0,) * len(shape))
    sspec = pl.BlockSpec(memory_space=pltpu.SMEM)
    kern = functools.partial(
        _sweep_kernel, n_tiles=T, tile=B, slot_chunk=C, dtype=dtype,
        mode=mode, combine_max=combine_max,
    )
    return pl.pallas_call(
        kern,
        grid=(N, T),
        in_specs=[
            sspec, sspec, sspec, sspec,
            vspec((1, nnz_pad)), vspec((1, nnz_pad)), vspec((1, nnz_pad)),
            vspec((1, nnz_pad)), vspec((1, nnz_pad)), vspec((1, nq_pad)),
        ],
        out_specs=[vspec((N, nq_pad)), vspec((N, nq_pad))],
        out_shape=[
            jax.ShapeDtypeStruct((N, nq_pad), dtype),
            jax.ShapeDtypeStruct((N, nq_pad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((T * B, 1), dtype),
            pltpu.VMEM((T * B, nq_pad), dtype),
            pltpu.VMEM((1, nq_pad), dtype),
            pltpu.VMEM((1, nq_pad), jnp.int32),
        ],
        interpret=interpret,
    )(
        read_ptr, e_task, store_add, e_startup,
        pad1(slot_cost), pad1(slot_free), pad1(slot_lt),
        pad1(slot_writer), pad1(slot_linf), budget[None, :],
    )
