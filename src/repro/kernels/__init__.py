"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three modules: ``kernel.py`` (pl.pallas_call + explicit
BlockSpec VMEM tiling), ``ops.py`` (jit'd model-layout wrapper, interpret=True
on CPU), ``ref.py`` (pure-jnp oracle used by tests/test_kernels.py).
"""
