"""Jit'd public wrapper: model-layout flash attention.

Accepts the model's [B, S, H, hd] / [B, S, KV, hd] layout (the signature of
``repro.models.attention.blockwise_attention``), regroups GQA heads, and
dispatches to the Pallas kernel — ``interpret=True`` on CPU (validation),
compiled on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bkv
from .ref import attention_ref


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, q_positions=None,
                    kv_positions=None, block_k: int = 128,
                    interpret: bool | None = None):
    """q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd] → [B, Sq, H, hd].

    Drop-in for ``blockwise_attention`` (positions args accepted for
    signature compatibility; the kernel assumes contiguous positions from 0,
    which is what train/prefill use).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    if interpret is None:
        interpret = _is_cpu()

    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, Sq, G, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    o = flash_attention_bkv(qg, kg, vg, causal=causal, blk_k=block_k,
                            interpret=interpret)
    o = o.reshape(B, KV, Sq, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd)
    return o


def flash_attention_reference(q, k, v, *, causal: bool = True, **_):
    """Oracle in model layout (tests)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 1, 3, 4).reshape(B * KV, Sq, G, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    o = attention_ref(qg, kg, vg, causal=causal)
    return o.reshape(B, KV, Sq, G, hd).transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd)
