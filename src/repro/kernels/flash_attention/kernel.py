"""Causal GQA flash attention — Pallas TPU kernel.

Grid: (B·KV, Sq/blk_q, Sk/blk_k), k-block innermost (sequential on TPU), with
the online-softmax running statistics (m, l) and the output accumulator held
in VMEM scratch across the k iterations — the HBM-resident [Sq, Sk] score
matrix of the naive form never exists, which is the whole point (see
EXPERIMENTS.md §Perf: the jnp fallback's f32 score blocks dominate the
memory roofline term).

Block shapes are explicit BlockSpecs; defaults (blk_q = blk_k = 128,
hd ∈ {64, 128}) keep the VMEM working set
  q (blk_q·G·hd) + k/v (2·blk_k·hd) + acc (blk_q·G·hd·4B) + scores
well under 1 MiB for G ≤ 8 and align the MXU contractions to 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, blk_q: int, blk_k: int, n_k: int, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # [blk_q, G, hd]
    k = k_ref[0]                       # [blk_k, hd]
    v = v_ref[0]                       # [blk_k, hd]
    G = q.shape[1]
    hd = q.shape[2]

    qf = q.reshape(blk_q * G, hd)
    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(blk_q, G, blk_k) * scale

    if causal:
        q_pos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, 1, blk_k), 0)
        k_pos = ik * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, 1, blk_k), 2)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]                # [blk_q, G]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])  # [blk_q, G, blk_k]
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p.reshape(blk_q * G, blk_k).astype(v.dtype), v,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv.reshape(blk_q, G, hd)
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "blk_q", "blk_k", "interpret"),
)
def flash_attention_bkv(q, k, v, *, causal: bool = True, blk_q: int = 128,
                        blk_k: int = 128, interpret: bool = False):
    """q: [BKV, Sq, G, hd]; k, v: [BKV, Sk, hd] → o like q."""
    BKV, Sq, G, hd = q.shape
    Sk = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    n_q, n_k = Sq // blk_q, Sk // blk_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k, n_k=n_k,
        causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BKV, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, G, hd), lambda b, iq, ik: (b, iq, 0, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, G, hd), lambda b, iq, ik: (b, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, Sq, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, G), jnp.float32),       # running max m
            pltpu.VMEM((blk_q, G), jnp.float32),       # running sum l
            pltpu.VMEM((blk_q, G, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
