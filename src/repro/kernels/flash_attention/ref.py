"""Pure-jnp oracle for the flash attention kernel (exact softmax)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True):
    """q: [BKV, Sq, G, hd]; k, v: [BKV, Sk, hd] → [BKV, Sq, G, hd] (fp32 math)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqgh,bkh->bqgk", qf, kf) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bqgk,bkh->bqgh", p, vf)
    return o.astype(q.dtype)
