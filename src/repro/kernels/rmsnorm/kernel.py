"""Fused RMSNorm — Pallas TPU kernel.

One pass over rows: mean-of-squares in fp32, rsqrt, scale by the weight —
fused so the normalized intermediate never round-trips through HBM.
Grid over row blocks; the weight vector is resident in VMEM for every block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # [blk, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "blk_rows", "interpret"))
def rmsnorm_rows(x, w, *, eps: float = 1e-5, blk_rows: int = 256,
                 interpret: bool = False):
    """x: [N, d]; w: [d] → [N, d] (same dtype as x)."""
    N, d = x.shape
    blk = min(blk_rows, N)
    if N % blk:
        blk = next(b for b in range(blk, 0, -1) if N % b == 0)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, w)
