"""Pure-jnp oracle for the fused RMSNorm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
