"""Jit'd wrapper: model-layout RMSNorm (any leading dims)."""

from __future__ import annotations

import jax

from .kernel import rmsnorm_rows
from .ref import rmsnorm_ref  # noqa: F401  (re-exported oracle)


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def rmsnorm(x, w, *, eps: float = 1e-5, interpret: bool | None = None):
    """x: [..., d]; w: [d] → [..., d]."""
    if interpret is None:
        interpret = _is_cpu()
    shape = x.shape
    y = rmsnorm_rows(x.reshape(-1, shape[-1]), w, eps=eps, interpret=interpret)
    return y.reshape(shape)
