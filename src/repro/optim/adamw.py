"""AdamW with global-norm clipping.

Optimizer state is a pytree congruent to the parameters, so under pjit it
inherits the parameter sharding (FSDP × TP) — ZeRO-1 for free: each device
holds only its parameter shard's moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
