"""NS Optimizer profile ingestion: ``prof.csv`` / ``dep.csv`` → TaskGraph.

The NS Optimizer exemplar (SNIPPETS.md) describes a network as two CSVs:

* ``prof.csv`` — one row per layer, measured on a particular device:
  ``Layer name, time (s), output size (mb), memory (mb), MACs`` (the MACs
  column is legacy, always zero; headers optional).
* ``dep.csv`` — ``Source, Destination`` edges between layer names.

:func:`load_ns_model` turns that into the repo's native shapes: a
:class:`~repro.core.graph.TaskGraph` whose tasks are the layers in a
*deterministic* topological order (Kahn's algorithm, ties broken by
``prof.csv`` row order — re-loading the same files always yields the same
task sequence, which the placement/burst DPs depend on), each layer writing
one output packet sized from the ``output size`` column (mb × 10⁶ bytes) and
reading its dependencies' outputs; sink outputs are ``keep`` packets. Layer
times load as task costs (the ``kind="time"`` convention: seconds as the
energy proxy) and double as calibration rows
(:meth:`NSModel.calibration_rows` feeds
``MeasuredCostTable.ingest_rows`` — the ROADMAP's "external profile
formats" item), so one profile drives both the solver and the measured cost
path.

Malformed inputs raise the typed :class:`NSOptimizerError`: missing/short
columns, non-numeric fields, duplicate layers, edges naming unknown layers,
self-edges, and dependency cycles (reported with the offending layer set).

Stdlib-only (csv + the core graph builder); no jax, no numpy.
"""

from __future__ import annotations

import csv
import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from ..core.graph import GraphBuilder, TaskGraph

__all__ = ["NSOptimizerError", "NSLayer", "NSModel", "load_ns_model"]

#: bytes per "mb" in NS Optimizer profiles (decimal megabytes)
MB = 1_000_000


class NSOptimizerError(ValueError):
    """Malformed NS Optimizer ``prof.csv`` / ``dep.csv`` inputs."""


@dataclasses.dataclass(frozen=True)
class NSLayer:
    """One ``prof.csv`` row."""

    name: str
    time_s: float
    output_mb: float
    memory_mb: float
    macs: float = 0.0

    @property
    def output_bytes(self) -> int:
        return int(round(self.output_mb * MB))

    @property
    def memory_bytes(self) -> int:
        return int(round(self.memory_mb * MB))


@dataclasses.dataclass(frozen=True, eq=False)
class NSModel:
    """A loaded NS Optimizer profile: the graph plus the raw layer rows
    (in the deterministic topological order the graph's tasks follow)."""

    graph: TaskGraph
    layers: Tuple[NSLayer, ...]
    edges: Tuple[Tuple[str, str], ...]

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def total_time_s(self) -> float:
        return sum(l.time_s for l in self.layers)

    def calibration_rows(self) -> List[Dict[str, object]]:
        """Layer timings as ``MeasuredCostTable.ingest_rows`` rows — one
        ``compute`` sample per layer (seconds, the ``kind="time"`` energy
        proxy), tagged with the layer name for provenance."""
        return [
            {"category": "compute", "energy": l.time_s, "kernel": l.name}
            for l in self.layers
        ]

    def summary(self) -> str:
        out_mb = sum(l.output_mb for l in self.layers)
        return (
            f"NSModel: {self.n_layers} layers, {len(self.edges)} edges, "
            f"{self.total_time_s:.4g} s total, {out_mb:.4g} mb activations"
        )


def _parse_prof(path: str) -> List[NSLayer]:
    layers: List[NSLayer] = []
    seen: Dict[str, int] = {}
    with open(path, newline="") as f:
        for lineno, row in enumerate(csv.reader(f), start=1):
            cells = [c.strip() for c in row]
            if not any(cells):
                continue
            if lineno == 1 and cells and not _is_float(cells[1] if len(cells) > 1 else ""):
                continue  # header row ("Layer name, time, ...")
            if len(cells) < 4:
                raise NSOptimizerError(
                    f"{path}:{lineno}: prof.csv rows need at least 4 columns "
                    f"(layer, time, output mb, memory mb), got {len(cells)}: "
                    f"{row!r}"
                )
            name = cells[0]
            if not name:
                raise NSOptimizerError(f"{path}:{lineno}: empty layer name")
            if name in seen:
                raise NSOptimizerError(
                    f"{path}:{lineno}: duplicate layer {name!r} "
                    f"(first at row {seen[name]})"
                )
            seen[name] = lineno
            try:
                time_s = float(cells[1])
                output_mb = float(cells[2])
                memory_mb = float(cells[3])
                macs = float(cells[4]) if len(cells) > 4 and cells[4] else 0.0
            except ValueError as exc:
                raise NSOptimizerError(
                    f"{path}:{lineno}: non-numeric profile field in {row!r}"
                ) from exc
            if time_s < 0 or output_mb < 0 or memory_mb < 0:
                raise NSOptimizerError(
                    f"{path}:{lineno}: negative profile value in {row!r}"
                )
            layers.append(NSLayer(name, time_s, output_mb, memory_mb, macs))
    if not layers:
        raise NSOptimizerError(f"{path}: no layers (empty prof.csv)")
    return layers


def _parse_dep(path: str, known: Mapping[str, int]) -> List[Tuple[str, str]]:
    edges: List[Tuple[str, str]] = []
    seen = set()
    with open(path, newline="") as f:
        for lineno, row in enumerate(csv.reader(f), start=1):
            cells = [c.strip() for c in row]
            if not any(cells):
                continue
            if lineno == 1 and [c.lower() for c in cells[:2]] == ["source", "destination"]:
                continue
            if len(cells) < 2 or not cells[0] or not cells[1]:
                raise NSOptimizerError(
                    f"{path}:{lineno}: dep.csv rows are 'Source,Destination' "
                    f"pairs, got {row!r}"
                )
            src, dst = cells[0], cells[1]
            for name in (src, dst):
                if name not in known:
                    raise NSOptimizerError(
                        f"{path}:{lineno}: edge names unknown layer {name!r} "
                        f"(not in prof.csv)"
                    )
            if src == dst:
                raise NSOptimizerError(
                    f"{path}:{lineno}: self-edge on layer {src!r}"
                )
            if (src, dst) not in seen:
                seen.add((src, dst))
                edges.append((src, dst))
    return edges


def _is_float(s: str) -> bool:
    try:
        float(s)
    except ValueError:
        return False
    return True


def load_ns_model(prof_path: str, dep_path: str) -> NSModel:
    """Load one NS Optimizer testcase (``prof.csv`` + ``dep.csv``).

    See the module docstring for the mapping. Raises
    :class:`NSOptimizerError` on malformed rows, unknown layer references,
    or cyclic dependencies.
    """
    rows = _parse_prof(prof_path)
    order = {l.name: i for i, l in enumerate(rows)}
    edges = _parse_dep(dep_path, order)

    # Deterministic Kahn topological sort: among ready layers, the one
    # earliest in prof.csv runs next (stable across loads and platforms).
    preds: Dict[str, List[str]] = {l.name: [] for l in rows}
    indeg: Dict[str, int] = {l.name: 0 for l in rows}
    for src, dst in edges:
        preds[dst].append(src)
        indeg[dst] += 1
    ready = sorted((name for name, d in indeg.items() if d == 0),
                   key=order.__getitem__)
    succs: Dict[str, List[str]] = {l.name: [] for l in rows}
    for src, dst in edges:
        succs[src].append(dst)
    topo: List[str] = []
    while ready:
        name = min(ready, key=order.__getitem__)
        ready.remove(name)
        topo.append(name)
        for nxt in succs[name]:
            indeg[nxt] -= 1
            if indeg[nxt] == 0:
                ready.append(nxt)
    if len(topo) != len(rows):
        cyclic = sorted(
            (n for n, d in indeg.items() if d > 0), key=order.__getitem__
        )
        raise NSOptimizerError(
            f"{dep_path}: dependency cycle through layers {cyclic}"
        )

    by_name = {l.name: l for l in rows}
    sinks = {l.name for l in rows} - {src for src, _ in edges}
    b = GraphBuilder()
    for name in topo:
        layer = by_name[name]
        pkt = f"out:{name}"
        b.packet(pkt, layer.output_bytes, keep=(name in sinks),
                 meta={"layer": name, "memory_bytes": layer.memory_bytes})
        b.task(
            name,
            reads=tuple(f"out:{p}" for p in sorted(preds[name],
                                                   key=order.__getitem__)),
            writes=(pkt,),
            cost=layer.time_s,
        )
    return NSModel(
        graph=b.build(),
        layers=tuple(by_name[name] for name in topo),
        edges=tuple(edges),
    )
