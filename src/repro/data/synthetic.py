"""Deterministic synthetic token pipeline.

A seeded, stateless stream: batch ``i`` is a pure function of (seed, i), so
any worker can regenerate any batch — which is exactly what the burst
checkpointing protocol needs for exact resume (re-reading a batch after a
crash yields identical data; see checkpoint/burst_ckpt.py).

The "task" is learnable structure, not noise: a periodic Markov-ish sequence
with an arch-sized vocabulary, so a ~100M model visibly reduces loss within a
few hundred steps (examples/train_tiny_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

__all__ = ["SyntheticConfig", "SyntheticData"]


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticData:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        # fixed random transition table: next ≈ f(prev) + small noise
        self._next = rng.randint(0, cfg.vocab, size=cfg.vocab).astype(np.int32)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Batch ``index`` — pure function of (seed, index)."""
        c = self.cfg
        rng = np.random.RandomState((c.seed * 1_000_003 + index) % (2**31 - 1))
        start = rng.randint(0, c.vocab, size=(c.global_batch, 1)).astype(np.int32)
        toks = np.empty((c.global_batch, c.seq_len + 1), np.int32)
        toks[:, 0] = start[:, 0]
        noise = rng.rand(c.global_batch, c.seq_len) < 0.05
        rand_tok = rng.randint(0, c.vocab, size=(c.global_batch, c.seq_len))
        for t in range(c.seq_len):
            nxt = self._next[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
