"""Mixture-of-Experts: GShard/Switch-style top-k dispatch with capacity.

Expert parallelism: expert-stacked weights [E, d, ff] are sharded on E over
the "model" mesh axis; the dispatch/combine einsums move tokens between the
token layout (batch-sharded) and the expert layout (expert-sharded), which
GSPMD lowers to all-to-alls — the canonical TPU MoE pattern.

Tokens are processed in fixed groups (``group_size``) so the dispatch one-hot
stays small: [groups, group, E, C] with C = ceil(top_k · group · cf / E).
Overflowing tokens are dropped (contribute zero), standard for
capacity-factor routing; the router's softmax weights are renormalized over
the selected experts (Phi/Mixtral convention).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import COMPUTE_DTYPE, dense_init

__all__ = ["init_moe", "moe_block", "moe_capacity"]


def init_moe(cfg, kg):
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.n_experts
    p = {
        "router": dense_init(kg(), (d, E)),
        "w1": dense_init(kg(), (E, d, ff)),
        "w3": dense_init(kg(), (E, d, ff)),
        "w2": dense_init(kg(), (E, ff, d)),
    }
    logical = {
        "router": ("d_in", "none"),
        # EP: experts take the "model" axis; the expert-internal dims keep
        # only FSDP ("data") — sharding ff over "model" too would double-map
        # the axis.
        "w1": ("experts", "d_in", None),
        "w3": ("experts", "d_in", None),
        "w2": ("experts", None, "d_in"),
    }
    return p, logical


def moe_capacity(m, group: int) -> int:
    c = int(np.ceil(m.top_k * group * m.capacity_factor / m.n_experts))
    return max(c, 4)


def moe_block(cfg, p, x, group_size: int = 1024):
    """x: [B, S, d] → [B, S, d]."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    group = min(group_size, S)
    assert (B * S) % group == 0
    G = B * S // group
    C = moe_capacity(m, group)

    xg = x.reshape(G, group, d)
    logits = (xg @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)  # [G,t,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, k)                       # [G,t,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    sel_oh = jax.nn.one_hot(sel, E, dtype=jnp.float32)        # [G,t,k,E]
    # position of each (token, choice) within its expert queue, k-major then t
    flat = sel_oh.reshape(G, group * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # [G,t*k,E]
    pos = pos.reshape(G, group, k, E)
    in_cap = (pos < C).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # [G,t,k,E,C]

    dispatch = jnp.einsum("gtke,gtkec->gtec", sel_oh * in_cap, pos_oh)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec", gate, sel_oh * in_cap, pos_oh)

    # token layout → expert layout (all-to-all under EP)
    xe = jnp.einsum("gtec,gtd->egcd", dispatch.astype(COMPUTE_DTYPE), xg)
    h1 = jnp.einsum("egcd,edf->egcf", xe, p["w1"].astype(COMPUTE_DTYPE))
    h3 = jnp.einsum("egcd,edf->egcf", xe, p["w3"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(COMPUTE_DTYPE) * h3
    ye = jnp.einsum("egcf,efd->egcd", h, p["w2"].astype(COMPUTE_DTYPE))
    # expert layout → token layout
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(COMPUTE_DTYPE), ye)
    return y.reshape(B, S, d), _load_balance_loss(probs, sel_oh)


def _load_balance_loss(probs, sel_oh):
    """Switch-style auxiliary loss (mean prob · mean assignment per expert)."""
    me = probs.mean(axis=(0, 1))            # [E]
    ce = sel_oh.sum(axis=2).mean(axis=(0, 1))  # [E]
    return probs.shape[-1] * jnp.sum(me * ce)
