"""Whisper-style encoder-decoder backbone.

The conv frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings [B, n_audio_frames, d_model] from
``input_specs()``. LayerNorm + GELU + learned positions (no RoPE), causal
decoder self-attention, cross-attention to the encoder output.

The paper-technique tie-in (DESIGN.md §5): the encoder output is a packet
whose last use is the *final* decoder layer — julienne keeps it resident
across decoder bursts exactly like the head-count image across CNN windows.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention, init_attention
from .common import COMPUTE_DTYPE, KeyGen, dense_init, layernorm, ones_init, zeros_init
from .mlp import gelu_mlp, init_gelu_mlp
from .transformer import _probe, stack_init

__all__ = ["init_encdec", "encdec_forward", "encdec_loss", "encdec_prefill",
           "encdec_decode_step", "encdec_cache_shape"]


def _init_ln(kg, d):
    return {"w": ones_init(kg(), (d,)), "b": zeros_init(kg(), (d,))}, \
        {"w": ("none",), "b": ("none",)}


def _init_enc_layer(cfg, kg):
    attn_p, attn_l = init_attention(cfg, kg)
    mlp_p, mlp_l = init_gelu_mlp(cfg, kg)
    ln1, ln1_l = _init_ln(kg, cfg.d_model)
    ln2, ln2_l = _init_ln(kg, cfg.d_model)
    return ({"attn": attn_p, "mlp": mlp_p, "ln1": ln1, "ln2": ln2},
            {"attn": attn_l, "mlp": mlp_l, "ln1": ln1_l, "ln2": ln2_l})


def _init_dec_layer(cfg, kg):
    self_p, self_l = init_attention(cfg, kg)
    cross_p, cross_l = init_attention(cfg, kg, cross=True)
    mlp_p, mlp_l = init_gelu_mlp(cfg, kg)
    ln1, ln1_l = _init_ln(kg, cfg.d_model)
    lnc, lnc_l = _init_ln(kg, cfg.d_model)
    ln2, ln2_l = _init_ln(kg, cfg.d_model)
    return ({"self": self_p, "cross": cross_p, "mlp": mlp_p,
             "ln1": ln1, "lnc": lnc, "ln2": ln2},
            {"self": self_l, "cross": cross_l, "mlp": mlp_l,
             "ln1": ln1_l, "lnc": lnc_l, "ln2": ln2_l})


def init_encdec(cfg, key=None, max_seq: int = 4096):
    kg = KeyGen(key) if key is not None else _probe()
    p: Dict[str, Any] = {
        "embed": dense_init(kg() if key is not None else None, (cfg.vocab, cfg.d_model)),
        "pos_enc": dense_init(kg() if key is not None else None,
                              (cfg.n_audio_frames, cfg.d_model)),
        "pos_dec": dense_init(kg() if key is not None else None,
                              (max_seq, cfg.d_model)),
        "head": dense_init(kg() if key is not None else None,
                           (cfg.d_model, cfg.vocab)),
    }
    l: Dict[str, Any] = {
        "embed": ("vocab", "d_in"), "pos_enc": ("none", "d_in"),
        "pos_dec": ("none", "d_in"), "head": ("d_in", "vocab"),
    }
    lkey = None if key is None else kg()
    p["enc"], l["enc"] = stack_init(cfg.n_encoder_layers,
                                    lambda kg2: _init_enc_layer(cfg, kg2), lkey)
    lkey2 = None if key is None else kg()
    p["dec"], l["dec"] = stack_init(cfg.n_layers,
                                    lambda kg2: _init_dec_layer(cfg, kg2), lkey2)
    enc_ln, enc_ln_l = _init_ln(kg if key is not None else _probe(), cfg.d_model)
    dec_ln, dec_ln_l = _init_ln(kg if key is not None else _probe(), cfg.d_model)
    p["enc_ln"], l["enc_ln"] = enc_ln, enc_ln_l
    p["dec_ln"], l["dec_ln"] = dec_ln, dec_ln_l
    return p, l


def _ln(x, lnp, eps):
    return layernorm(x, lnp["w"], lnp["b"], eps)


def encode(cfg, params, audio_embed, constrain=lambda x: x, remat=True):
    """audio_embed [B, F, d] → encoder output [B, F, d]."""
    F = audio_embed.shape[1]
    x = constrain(audio_embed.astype(COMPUTE_DTYPE)
                  + params["pos_enc"][:F].astype(COMPUTE_DTYPE))
    pos = jnp.arange(F)[None, :]

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        a, _ = attention(cfg, lp["attn"], h, positions=pos, causal=False,
                         rope=False, constrain=constrain)
        x = constrain(x + a)
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = constrain(x + gelu_mlp(lp["mlp"], h))
        return x, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(x, params["enc_ln"], cfg.norm_eps)


def encdec_forward(cfg, params, tokens, audio_embed, constrain=lambda x: x,
                   remat: bool = True, collect_cache: bool = False):
    enc_out = encode(cfg, params, audio_embed, constrain, remat)
    B, S = tokens.shape
    pos = jnp.arange(S)[None, :]
    fpos = jnp.arange(enc_out.shape[1])[None, :]
    x = jnp.take(params["embed"].astype(COMPUTE_DTYPE), tokens, axis=0)
    x = constrain(x + params["pos_dec"][:S].astype(COMPUTE_DTYPE))

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        a, skv = attention(cfg, lp["self"], h, positions=pos, constrain=constrain)
        x = constrain(x + a)
        h = _ln(x, lp["lnc"], cfg.norm_eps)
        a, ckv = attention(cfg, lp["cross"], h, positions=pos, causal=False,
                           kv_x=enc_out, kv_positions=fpos, rope=False,
                           constrain=constrain)
        x = constrain(x + a)
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = constrain(x + gelu_mlp(lp["mlp"], h))
        return x, ((skv, ckv) if collect_cache else None)

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat and not collect_cache else body
    x, caches = jax.lax.scan(body_fn, x, params["dec"])
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    logits = x @ params["head"].astype(COMPUTE_DTYPE)
    return logits, caches


def encdec_loss(cfg, params, tokens, labels, audio_embed, constrain=lambda x: x,
                remat: bool = True):
    from .common import softmax_cross_entropy

    logits, _ = encdec_forward(cfg, params, tokens, audio_embed, constrain, remat)
    ce = softmax_cross_entropy(logits, labels)
    return ce, ce


def encdec_cache_shape(cfg, batch: int, max_seq: int):
    hd, KV = cfg.hd, cfg.n_kv_heads
    self_kv = jax.ShapeDtypeStruct((cfg.n_layers, batch, max_seq, KV, hd),
                                   COMPUTE_DTYPE)
    cross_kv = jax.ShapeDtypeStruct((cfg.n_layers, batch, cfg.n_audio_frames, KV, hd),
                                    COMPUTE_DTYPE)
    tree = {"k": self_kv, "v": self_kv, "cross_k": cross_kv, "cross_v": cross_kv}
    logical = {"k": ("layers", "batch", "kv_seq", "none", "none"),
               "v": ("layers", "batch", "kv_seq", "none", "none"),
               "cross_k": ("layers", "batch", "none", "none", "none"),
               "cross_v": ("layers", "batch", "none", "none", "none")}
    return tree, logical


def encdec_prefill(cfg, params, tokens, audio_embed, max_seq: int,
                   constrain=lambda x: x):
    logits, caches = encdec_forward(cfg, params, tokens, audio_embed, constrain,
                                    remat=False, collect_cache=True)
    (sk, sv), (ck, cv) = caches

    def pad(kv):
        w = [(0, 0)] * kv.ndim
        w[2] = (0, max_seq - kv.shape[2])
        return jnp.pad(kv, w)

    cache = {"k": pad(sk), "v": pad(sv), "cross_k": ck, "cross_v": cv}
    return logits[:, -1:, :], cache


def encdec_decode_step(cfg, params, cache, token, pos, constrain=lambda x: x):
    x = jnp.take(params["embed"].astype(COMPUTE_DTYPE), token, axis=0)
    x = constrain(x + jnp.take(params["pos_dec"], pos, axis=0).astype(COMPUTE_DTYPE))

    def body(x, lin):
        lp, k_, v_, ck_, cv_ = lin
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        a, k_, v_ = decode_attention(cfg, lp["self"], h, k_, v_, pos)
        x = constrain(x + a)
        h = _ln(x, lp["lnc"], cfg.norm_eps)
        a, _, _ = decode_attention(cfg, lp["cross"], h, ck_, cv_, pos, cross=True)
        x = constrain(x + a)
        h = _ln(x, lp["ln2"], cfg.norm_eps)
        x = constrain(x + gelu_mlp(lp["mlp"], h))
        return x, (k_, v_)

    x, (k2, v2) = jax.lax.scan(body, x,
                               (params["dec"], cache["k"], cache["v"],
                                cache["cross_k"], cache["cross_v"]))
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    return x @ params["head"].astype(COMPUTE_DTYPE), dict(cache, k=k2, v=v2)
