"""Shared model components: norms, RoPE, initializers, losses, dtype policy.

Numerics policy (mixed precision, MaxText-style): parameters and optimizer
state in fp32; activations and matmuls in bf16; softmax statistics, norms and
the loss in fp32.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32

__all__ = [
    "COMPUTE_DTYPE",
    "PARAM_DTYPE",
    "KeyGen",
    "dense_init",
    "rmsnorm",
    "layernorm",
    "apply_rope",
    "softmax_cross_entropy",
    "Abstract",
]


class KeyGen:
    """Split-on-demand PRNG key source (init-time only)."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k


class Abstract:
    """Stand-in KeyGen that makes init functions produce ShapeDtypeStructs.

    Used by the dry-run: ``jax.eval_shape(init)`` never allocates, but we
    also want a *direct* abstract path so huge configs can be described
    without tracing the initializers at all.
    """

    def __call__(self):
        return None


def dense_init(key, shape: Tuple[int, ...], scale: float = 0.02, dtype=PARAM_DTYPE):
    if key is None:  # abstract init (dry-run)
        return jax.ShapeDtypeStruct(shape, dtype)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def zeros_init(key, shape: Tuple[int, ...], dtype=PARAM_DTYPE):
    if key is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def ones_init(key, shape: Tuple[int, ...], dtype=PARAM_DTYPE):
    if key is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.ones(shape, dtype)


def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(COMPUTE_DTYPE)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(COMPUTE_DTYPE)


def _rope_angles(positions, head_dim: int, theta: float):
    # positions: [...]; returns sin/cos [..., head_dim/2]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    sin, cos = _rope_angles(positions, hd, theta)  # [..., seq, hd/2]
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softmax_cross_entropy(logits, labels):
    """Mean token cross-entropy; logits [..., V] any dtype, stats in fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
