"""Unified model API: one entry point per (init / loss / prefill / decode),
dispatched on ``cfg.family``, plus ``input_specs`` for the dry-run.

All functions are pure and jit-friendly; ``key=None`` gives abstract
(ShapeDtypeStruct) parameters for allocation-free lowering.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec, recurrent, transformer
from .common import COMPUTE_DTYPE

__all__ = ["init_params", "loss", "prefill", "decode_step", "cache_shape",
           "input_specs", "extra_inputs"]


def init_params(cfg: ModelConfig, key=None, max_seq: int = 4096):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_lm(cfg, key)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key, max_seq=max_seq)
    if cfg.family == "ssm":
        return recurrent.init_xlstm_lm(cfg, key)
    if cfg.family == "hybrid":
        return recurrent.init_zamba_lm(cfg, key)
    raise ValueError(cfg.family)


def loss(cfg: ModelConfig, params, batch: Dict[str, Any],
         constrain=lambda x: x, remat: bool = True):
    """batch: {tokens, labels, [vision|audio]} → (loss, ce)."""
    if cfg.family in ("dense", "moe"):
        return transformer.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                                   constrain, remat=remat)
    if cfg.family == "vlm":
        return transformer.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                                   constrain, vision=batch["vision"], remat=remat)
    if cfg.family == "encdec":
        return encdec.encdec_loss(cfg, params, batch["tokens"], batch["labels"],
                                  batch["audio"], constrain, remat=remat)
    if cfg.family == "ssm":
        return recurrent.xlstm_loss(cfg, params, batch["tokens"], batch["labels"],
                                    constrain, remat=remat)
    if cfg.family == "hybrid":
        return recurrent.zamba_loss(cfg, params, batch["tokens"], batch["labels"],
                                    constrain, remat=remat)
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params, batch, max_seq: int, constrain=lambda x: x):
    if cfg.family in ("dense", "moe"):
        return transformer.lm_prefill(cfg, params, batch["tokens"], max_seq, constrain)
    if cfg.family == "vlm":
        return transformer.lm_prefill(cfg, params, batch["tokens"], max_seq,
                                      constrain, vision=batch["vision"])
    if cfg.family == "encdec":
        return encdec.encdec_prefill(cfg, params, batch["tokens"], batch["audio"],
                                     max_seq, constrain)
    if cfg.family == "ssm":
        return recurrent.xlstm_prefill(cfg, params, batch["tokens"], max_seq, constrain)
    if cfg.family == "hybrid":
        return recurrent.zamba_prefill(cfg, params, batch["tokens"], max_seq, constrain)
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, cache, token, pos, constrain=lambda x: x):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.lm_decode_step(cfg, params, cache, token, pos, constrain)
    if cfg.family == "encdec":
        return encdec.encdec_decode_step(cfg, params, cache, token, pos, constrain)
    if cfg.family == "ssm":
        return recurrent.xlstm_decode_step(cfg, params, cache, token, pos, constrain)
    if cfg.family == "hybrid":
        return recurrent.zamba_decode_step(cfg, params, cache, token, pos, constrain)
    raise ValueError(cfg.family)


def cache_shape(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.lm_cache_shape(cfg, batch, max_seq)
    if cfg.family == "encdec":
        return encdec.encdec_cache_shape(cfg, batch, max_seq)
    if cfg.family == "ssm":
        return recurrent.xlstm_cache_shape(cfg, batch, max_seq)
    if cfg.family == "hybrid":
        return recurrent.zamba_cache_shape(cfg, batch, max_seq)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def extra_inputs(cfg: ModelConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Modality-frontend stubs: precomputed frame/patch embeddings."""
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        out["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model), COMPUTE_DTYPE)
    if cfg.family == "encdec":
        out["audio"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_audio_frames, cfg.d_model), COMPUTE_DTYPE)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for one (arch × shape) cell.

    train:   {tokens, labels, extra...}            [B, S]
    prefill: {tokens, extra...}                    [B, S]
    decode:  {token [B,1], pos scalar, cache}      (cache from cache_shape)
    """
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        return {"tokens": tok, "labels": tok, **extra_inputs(cfg, B)}
    if shape.kind == "prefill":
        return {"tokens": tok, **extra_inputs(cfg, B)}
    if shape.kind == "decode":
        cache, _ = cache_shape(cfg, B, S)
        return {
            "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": cache,
        }
    raise ValueError(shape.kind)
