"""Recurrent / hybrid LM assemblies: xLSTM (ssm family) and Zamba2 (hybrid).

xLSTM-1.3b: blocks in groups of ``slstm_every`` — (slstm_every − 1) mLSTM
blocks followed by 1 sLSTM block — scanned over groups with an inner scan
over the stacked mLSTM blocks.

Zamba2-7b: ``attn_every`` Mamba2 blocks per group followed by one application
of the SHARED attention+MLP block (one parameter set, reused every group,
concat([hidden, embedding]) input per the Zamba papers), plus remainder
Mamba2 blocks. 81 = 13·6 + 3 for the full config.

Sharding profile "ssm" (models/sharding.py): sequence local, batch over
("pod","data"), cell feature dims over "model".
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention
from .common import COMPUTE_DTYPE, KeyGen, dense_init, ones_init, rmsnorm, softmax_cross_entropy
from .mlp import init_swiglu, swiglu
from .ssm import (init_mamba, mamba_chunked, mamba_decode_step, mamba_init_state)
from .transformer import _probe, stack_init
from .xlstm import (init_mlstm, init_slstm, mlstm_chunked, mlstm_decode_step,
                    mlstm_init_state, slstm_decode_step, slstm_init_state, slstm_seq)

__all__ = [
    "init_xlstm_lm", "xlstm_forward", "xlstm_loss", "xlstm_prefill",
    "xlstm_decode_step", "xlstm_cache_shape",
    "init_zamba_lm", "zamba_forward", "zamba_loss", "zamba_prefill",
    "zamba_decode_step", "zamba_cache_shape",
]


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------


def _xlstm_groups(cfg) -> Tuple[int, int]:
    per = cfg.slstm_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per - 1  # (n_groups, mlstm per group)


def init_xlstm_lm(cfg, key=None):
    kg = KeyGen(key) if key is not None else _probe()
    p: Dict[str, Any] = {
        "embed": dense_init(kg() if key is not None else None, (cfg.vocab, cfg.d_model)),
        "final_norm": ones_init(kg() if key is not None else None, (cfg.d_model,)),
        "head": dense_init(kg() if key is not None else None, (cfg.d_model, cfg.vocab)),
    }
    l: Dict[str, Any] = {"embed": ("vocab", "d_in"), "final_norm": ("none",),
                         "head": ("d_in", "vocab")}
    n_groups, n_m = _xlstm_groups(cfg)

    def init_group(kg2):
        def init_mblock(kg3):
            mp, ml = init_mlstm(cfg, kg3)
            return ({"cell": mp, "ln": ones_init(kg3(), (cfg.d_model,))},
                    {"cell": ml, "ln": ("none",)})

        mp, ml = stack_init(n_m, init_mblock,
                            kg2() if not isinstance(kg2, _probe) else None)
        sp, sl = init_slstm(cfg, kg2)
        return ({"m": mp, "s": sp, "s_ln": ones_init(kg2(), (cfg.d_model,))},
                {"m": ml, "s": sl, "s_ln": ("none",)})

    lkey = None if key is None else kg()
    p["groups"], l["groups"] = stack_init(n_groups, init_group, lkey)
    return p, l


def _xlstm_stack(cfg, params, x, constrain, remat, states=None, collect=False,
                 single_step=False):
    """Shared group-scan driver. states: optional cache pytree to thread."""
    n_groups, n_m = _xlstm_groups(cfg)
    mstep = mlstm_decode_step if single_step else mlstm_chunked
    sstep = slstm_decode_step if single_step else slstm_seq

    def mblock(x, mp, st):
        y, st2 = mstep(cfg, mp["cell"], rmsnorm(x, mp["ln"], cfg.norm_eps), st)
        return constrain(x + y), st2

    def group_body(carry, gin):
        x = carry
        gp, gst = gin

        def inner(x, lin):
            mp, st = lin
            x, st2 = mblock(x, mp, st)
            return x, st2

        inner_fn = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable) \
            if remat else inner
        x, mstates = jax.lax.scan(inner_fn, x, (gp["m"], gst["m"]))
        y, sstate = sstep(cfg, gp["s"], rmsnorm(x, gp["s_ln"], cfg.norm_eps),
                          gst["s"])
        x = constrain(x + y)
        return x, {"m": mstates, "s": sstate}

    if states is None:
        B = x.shape[0]
        m0 = mlstm_init_state(cfg, B)
        s0 = slstm_init_state(cfg, B)
        states = {
            "m": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_groups, n_m, *a.shape)), m0),
            "s": jax.tree.map(lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), s0),
        }
    x, new_states = jax.lax.scan(group_body, x, (params["groups"], states))
    return x, new_states


def xlstm_forward(cfg, params, tokens, constrain=lambda x: x, remat=True,
                  states=None, single_step=False):
    x = constrain(jnp.take(params["embed"].astype(COMPUTE_DTYPE), tokens, axis=0))
    x, new_states = _xlstm_stack(cfg, params, x, constrain, remat, states,
                                 single_step=single_step)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["head"].astype(COMPUTE_DTYPE), new_states


def xlstm_loss(cfg, params, tokens, labels, constrain=lambda x: x, remat=True):
    logits, _ = xlstm_forward(cfg, params, tokens, constrain, remat)
    ce = softmax_cross_entropy(logits, labels)
    return ce, ce


def xlstm_cache_shape(cfg, batch: int, max_seq: int):
    """Recurrent state 'cache' — O(1) in sequence length (the 500k story)."""
    n_groups, n_m = _xlstm_groups(cfg)
    m0 = mlstm_init_state(cfg, batch)
    s0 = slstm_init_state(cfg, batch)
    tree = {
        "m": jax.tree.map(lambda a: jax.ShapeDtypeStruct((n_groups, n_m, *a.shape),
                                                         a.dtype), m0),
        "s": jax.tree.map(lambda a: jax.ShapeDtypeStruct((n_groups, *a.shape),
                                                         a.dtype), s0),
    }
    mlog = {"C": ("layers", "none", "batch", "none", "feat", "none"),
            "n": ("layers", "none", "batch", "none", "feat"),
            "m": ("layers", "none", "batch", "none")}
    slog = {k: ("layers", "batch", "none", "none") for k in ("c", "n", "h", "m")}
    return tree, {"m": mlog, "s": slog}


def xlstm_prefill(cfg, params, tokens, max_seq: int, constrain=lambda x: x):
    logits, states = xlstm_forward(cfg, params, tokens, constrain, remat=False)
    return logits[:, -1:, :], states


def xlstm_decode_step(cfg, params, cache, token, pos, constrain=lambda x: x):
    del pos  # recurrent state carries position implicitly
    logits, states = xlstm_forward(cfg, params, token, constrain, remat=False,
                                   states=cache, single_step=True)
    return logits, states


# ---------------------------------------------------------------------------
# Zamba2
# ---------------------------------------------------------------------------


def _zamba_groups(cfg) -> Tuple[int, int]:
    n_groups = cfg.n_layers // cfg.attn_every
    rem = cfg.n_layers - n_groups * cfg.attn_every
    return n_groups, rem


def _init_shared_attn(cfg, kg):
    """Shared attention block: input concat([h, e]) ∈ R^{2d} (Zamba)."""
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    p = {
        "wq": dense_init(kg(), (2 * d, nq)),
        "wk": dense_init(kg(), (2 * d, nkv)),
        "wv": dense_init(kg(), (2 * d, nkv)),
        "wo": dense_init(kg(), (nq, d)),
        "ln": ones_init(kg(), (2 * d,)),
        "mlp_ln": ones_init(kg(), (cfg.d_model,)),
    }
    l = {"wq": ("d_in", "feat"), "wk": ("d_in", "feat"), "wv": ("d_in", "feat"),
         "wo": ("feat", "d_in"), "ln": ("none",), "mlp_ln": ("none",)}
    mlp_p, mlp_l = init_swiglu(cfg, kg)
    p["mlp"], l["mlp"] = mlp_p, mlp_l
    return p, l


def init_zamba_lm(cfg, key=None):
    kg = KeyGen(key) if key is not None else _probe()
    p: Dict[str, Any] = {
        "embed": dense_init(kg() if key is not None else None, (cfg.vocab, cfg.d_model)),
        "final_norm": ones_init(kg() if key is not None else None, (cfg.d_model,)),
        "head": dense_init(kg() if key is not None else None, (cfg.d_model, cfg.vocab)),
    }
    l: Dict[str, Any] = {"embed": ("vocab", "d_in"), "final_norm": ("none",),
                         "head": ("d_in", "vocab")}
    n_groups, rem = _zamba_groups(cfg)

    def init_mblock(kg2):
        mp, ml = init_mamba(cfg, kg2)
        return ({"cell": mp, "ln": ones_init(kg2(), (cfg.d_model,))},
                {"cell": ml, "ln": ("none",)})

    def init_group(kg2):
        mp, ml = stack_init(cfg.attn_every, init_mblock,
                            kg2() if not isinstance(kg2, _probe) else None)
        return {"mamba": mp}, {"mamba": ml}

    lkey = None if key is None else kg()
    p["groups"], l["groups"] = stack_init(n_groups, init_group, lkey)
    if rem:
        rkey = None if key is None else kg()
        p["tail"], l["tail"] = stack_init(rem, init_mblock, rkey)
    p["shared"], l["shared"] = _init_shared_attn(cfg, kg)
    return p, l


def _shared_attn_apply(cfg, sp, x, e0, constrain, kv_cache=None, pos=None):
    """One application of the shared attention + MLP block."""
    cat = jnp.concatenate([x, e0], axis=-1)
    cat = rmsnorm(cat, sp["ln"], cfg.norm_eps)
    if kv_cache is None:
        # The hybrid profile keeps sequences device-local for the Mamba
        # recurrence, but THIS block is full attention: without sequence
        # sharding its f32 score blocks are [B_local, S, H, blk] —
        # 8.6 GB/device per KV block on prefill_32k (§Perf #3). Shard q/k/v
        # along seq over whatever mesh axes the batch left free.
        from .sharding import constrain as _constrain, rules_for as _rules_for

        _r = _rules_for("hybrid")

        def _c4(a):
            if a.ndim == 4:
                return _constrain(a, _r, "batch", "kv_seq", None, None)
            return a

        positions = jnp.arange(x.shape[1])[None, :]
        a, kv = attention(cfg, sp, cat, positions=positions, constrain=_c4)
        out_cache = kv
    else:
        ck, cv = kv_cache
        a, ck, cv = decode_attention(cfg, sp, cat, ck, cv, pos)
        out_cache = (ck, cv)
    x = constrain(x + a)
    h = rmsnorm(x, sp["mlp_ln"], cfg.norm_eps)
    x = constrain(x + swiglu(sp["mlp"], h))
    return x, out_cache


def _zamba_stack(cfg, params, x, constrain, remat, states=None, collect=False,
                 single_step=False, attn_caches=None, pos=None):
    n_groups, rem = _zamba_groups(cfg)
    mstep = mamba_decode_step if single_step else mamba_chunked
    e0 = x  # original embedding, concat-input to the shared block

    def mblock(x, mp, st):
        y, st2 = mstep(cfg, mp["cell"], rmsnorm(x, mp["ln"], cfg.norm_eps), st)
        return constrain(x + y), st2

    def inner(x, lin):
        mp, st = lin
        x, st2 = mblock(x, mp, st)
        return x, st2

    inner_fn = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else inner

    if states is None:
        B = x.shape[0]
        m0 = mamba_init_state(cfg, B)
        states = {
            "groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups, cfg.attn_every, *a.shape)), m0),
            "tail": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (rem, *a.shape)), m0) if rem else None,
        }

    def group_body(x, gin):
        gp, gst, gkv = gin
        x, mstates = jax.lax.scan(inner_fn, x, (gp["mamba"], gst))
        x, kv_out = _shared_attn_apply(cfg, params["shared"], x, e0, constrain,
                                       kv_cache=gkv, pos=pos)
        return x, (mstates, kv_out)

    gkv_in = attn_caches if attn_caches is not None else (
        None if single_step else _no_cache_marker(n_groups))
    if attn_caches is not None:
        x, (g_states, kv_outs) = jax.lax.scan(
            group_body, x, (params["groups"], states["groups"], attn_caches))
    else:
        # the shared attention block is rematerialized too — without this the
        # 13 applications' softmax intermediates dominate training memory
        # (observed 136 GB/device on zamba2-7b train_4k before the fix)
        def shared_apply(x_in, e_in):
            y, kv_out = _shared_attn_apply(cfg, params["shared"], x_in, e_in,
                                           constrain)
            return y, kv_out

        if remat:
            shared_apply = jax.checkpoint(
                shared_apply, policy=jax.checkpoint_policies.nothing_saveable)

        def group_body_nocache(x, gin):
            gp, gst = gin
            x, mstates = jax.lax.scan(inner_fn, x, (gp["mamba"], gst))
            x, kv_out = shared_apply(x, e0)
            return x, (mstates, kv_out)

        x, (g_states, kv_outs) = jax.lax.scan(
            group_body_nocache, x, (params["groups"], states["groups"]))

    tail_states = None
    if rem:
        x, tail_states = jax.lax.scan(inner_fn, x, (params["tail"], states["tail"]))
    return x, {"groups": g_states, "tail": tail_states}, kv_outs


def _no_cache_marker(n):
    return None


def zamba_forward(cfg, params, tokens, constrain=lambda x: x, remat=True):
    x = constrain(jnp.take(params["embed"].astype(COMPUTE_DTYPE), tokens, axis=0))
    x, _, _ = _zamba_stack(cfg, params, x, constrain, remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["head"].astype(COMPUTE_DTYPE)


def zamba_loss(cfg, params, tokens, labels, constrain=lambda x: x, remat=True):
    logits = zamba_forward(cfg, params, tokens, constrain, remat)
    ce = softmax_cross_entropy(logits, labels)
    return ce, ce


def zamba_cache_shape(cfg, batch: int, max_seq: int):
    n_groups, rem = _zamba_groups(cfg)
    m0 = mamba_init_state(cfg, batch)
    hd, KV = cfg.hd, cfg.n_kv_heads
    kv = jax.ShapeDtypeStruct((n_groups, batch, max_seq, KV, hd), COMPUTE_DTYPE)
    tree = {
        "groups": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((n_groups, cfg.attn_every, *a.shape),
                                           a.dtype), m0),
        "tail": (jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((rem, *a.shape), a.dtype), m0)
            if rem else None),
        "attn_k": kv, "attn_v": kv,
    }
    mlog = {"ssm": ("layers", "none", "batch", "feat", "none", "none"),
            "conv": ("layers", "none", "batch", "none", "feat")}
    tlog = {"ssm": ("layers", "batch", "feat", "none", "none"),
            "conv": ("layers", "batch", "none", "feat")} if rem else None
    logical = {"groups": mlog, "tail": tlog,
               "attn_k": ("layers", "batch", "kv_seq", "none", "none"),
               "attn_v": ("layers", "batch", "kv_seq", "none", "none")}
    return tree, logical


def zamba_prefill(cfg, params, tokens, max_seq: int, constrain=lambda x: x):
    x = constrain(jnp.take(params["embed"].astype(COMPUTE_DTYPE), tokens, axis=0))
    x, states, kv_outs = _zamba_stack(cfg, params, x, constrain, remat=False)
    k, v = kv_outs  # [n_groups, B, S, KV, hd]

    def pad(kv):
        w = [(0, 0)] * kv.ndim
        w[2] = (0, max_seq - kv.shape[2])
        return jnp.pad(kv, w)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(COMPUTE_DTYPE)
    cache = {"groups": states["groups"], "tail": states["tail"],
             "attn_k": pad(k.astype(COMPUTE_DTYPE)),
             "attn_v": pad(v.astype(COMPUTE_DTYPE))}
    return logits[:, -1:, :], cache


def zamba_decode_step(cfg, params, cache, token, pos, constrain=lambda x: x):
    x = constrain(jnp.take(params["embed"].astype(COMPUTE_DTYPE), token, axis=0))
    states = {"groups": cache["groups"], "tail": cache["tail"]}
    x, new_states, kv_outs = _zamba_stack(
        cfg, params, x, constrain, remat=False, states=states,
        single_step=True, attn_caches=(cache["attn_k"], cache["attn_v"]), pos=pos)
    k2, v2 = kv_outs
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(COMPUTE_DTYPE)
    return logits, dict(cache, groups=new_states["groups"], tail=new_states["tail"],
                        attn_k=k2, attn_v=v2)
