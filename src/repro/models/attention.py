"""Attention: GQA with RoPE, optional QKV-bias / qk-norm, cross-attention,
blockwise (flash-style) softmax, and psum-friendly decode over sequence-sharded
KV caches.

Memory discipline mirrors the Pallas kernel (kernels/flash_attention): the
softmax is computed online over KV blocks inside a ``lax.scan``, so the full
[Sq, Sk] score matrix never materializes — this is what lets prefill_32k and
train_4k compile within HBM on the dry-run meshes. The Pallas kernel is a
drop-in replacement for the inner loop on real TPUs (see kernels/ops.py);
the scan version is the oracle it is tested against.

Sharding (see models/sharding.py):
* train/prefill: activations sequence-sharded over "model" (SP); K/V are
  all-gathered per layer (blockwise, inside the scan) — q stays sharded, so
  score blocks are [B, Sq/model, H, blk] per device.
* decode: KV caches are [B, S, kv, hd] sharded along S over "model"; scores
  and the weighted sum reduce over the sharded axis, which GSPMD lowers to
  all-reduces — this works for any (n_heads, n_kv_heads), unlike head-sharded
  TP (DESIGN.md §4). Cache updates use one-hot scatter (shard-local).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, apply_rope, dense_init, ones_init, rmsnorm, zeros_init

__all__ = ["init_attention", "attention", "decode_attention", "blockwise_attention"]

NEG_INF = -1e30


def init_attention(cfg, kg, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    p: Dict[str, Any] = {
        "wq": dense_init(kg(), (d, nq)),
        "wk": dense_init(kg(), (d, nkv)),
        "wv": dense_init(kg(), (d, nkv)),
        "wo": dense_init(kg(), (nq, d)),
    }
    logical: Dict[str, Any] = {
        "wq": ("d_in", "feat"),
        "wk": ("d_in", "feat"),
        "wv": ("d_in", "feat"),
        "wo": ("feat", "d_in"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = zeros_init(kg(), (nq,))
        p["bk"] = zeros_init(kg(), (nkv,))
        p["bv"] = zeros_init(kg(), (nkv,))
        logical.update({"bq": ("feat",), "bk": ("feat",), "bv": ("feat",)})
    if cfg.qk_norm and not cross:
        p["q_norm"] = ones_init(kg(), (hd,))
        p["k_norm"] = ones_init(kg(), (hd,))
        logical.update({"q_norm": ("none",), "k_norm": ("none",)})
    return p, logical


def _project_qkv(cfg, p, x, kv_x=None, positions=None, kv_positions=None,
                 rope: bool = True):
    """Returns q [B,Sq,H,hd], k/v [B,Sk,KV,hd] (bf16)."""
    hd = cfg.hd
    xq = x
    xkv = x if kv_x is None else kv_x
    q = xq @ p["wq"].astype(COMPUTE_DTYPE)
    k = xkv @ p["wk"].astype(COMPUTE_DTYPE)
    v = xkv @ p["wv"].astype(COMPUTE_DTYPE)
    if "bq" in p:
        q = q + p["bq"].astype(COMPUTE_DTYPE)
        k = k + p["bk"].astype(COMPUTE_DTYPE)
        v = v + p["bv"].astype(COMPUTE_DTYPE)
    q = q.reshape(*q.shape[:-1], cfg.n_heads, hd)
    k = k.reshape(*k.shape[:-1], cfg.n_kv_heads, hd)
    v = v.reshape(*v.shape[:-1], cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions if kv_positions is not None else positions,
                       cfg.rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, causal: bool, q_positions=None,
                        kv_positions=None, block_k: int = 1024):
    """Online-softmax attention scanned over KV blocks (the flash pattern).

    q: [B, Sq, H, hd];  k, v: [B, Sk, KV, hd];  H % KV == 0 (GQA).
    Positions are absolute token indices used for causal masking; when None,
    iota is used (pure self-attention over a contiguous block).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    blk = min(block_k, Sk)
    if Sk % blk:
        # cross-attention KV lengths (1601 vision tokens, 1500 audio frames)
        # need not divide the default block — use the largest divisor, unless
        # it is degenerate (1601 is prime → divisor 1 → a 1601-step scan whose
        # backward stacks 107 GB of residuals): then take one whole block.
        d = next(d for d in range(blk, 0, -1) if Sk % d == 0)
        blk = d if d >= block_k // 4 else Sk
    n_blocks = Sk // blk

    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(Sk)[None, :]

    # layout [B·KV, G, Sq, hd] so both contractions are explicit batched GEMMs
    # (dot_general) — a >2-batch/free-dim einsum tempts XLA:CPU into a
    # broadcast-multiply-reduce that materializes [blk, ..., hd] outer
    # products (observed: a 107 GB f32 temp on llama-vision cross-attention).
    qg = (q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * KV, G, Sq, hd).astype(COMPUTE_DTYPE))
    kb = k.transpose(0, 2, 1, 3).reshape(B * KV, n_blocks, blk, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * KV, n_blocks, blk, hd)
    pb = kv_positions.reshape(kv_positions.shape[0], n_blocks, blk)

    def step(carry, blk_in):
        m, l, acc = carry                    # [B·KV, G, Sq], [..., hd]
        kblk, vblk, pblk = blk_in            # [B·KV, blk, hd], [B|1, blk]
        s = jax.lax.dot_general(
            qg, kblk.astype(COMPUTE_DTYPE),
            (((3,), (2,)), ((0,), (0,))),    # contract hd, batch B·KV
            preferred_element_type=jnp.float32) * scale  # [B·KV, G, Sq, blk]
        if causal:
            mask = q_positions[:, :, None] >= pblk[:, None, :]  # [B|1, Sq, blk]
            if mask.shape[0] != 1:
                mask = jnp.repeat(mask, KV, axis=0)             # [B·KV, Sq, blk]
            s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        pv = jax.lax.dot_general(
            pexp.astype(COMPUTE_DTYPE), vblk.astype(COMPUTE_DTYPE),
            (((3,), (1,)), ((0,), (0,))),    # contract blk, batch B·KV
            preferred_element_type=jnp.float32)          # [B·KV, G, Sq, hd]
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B * KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B * KV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B * KV, G, Sq, hd), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    pb_t = jnp.moveaxis(pb, 1, 0)
    # remat each KV block: the backward otherwise saves the f32 score/pexp
    # blocks for every step — ~15 GB/device on deepseek train_4k (§Perf #1)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb_t, vb_t, pb_t))
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # [B·KV, G, Sq, hd]
    out = (out.reshape(B, KV, G, Sq, hd).transpose(0, 3, 1, 2, 4)
           .reshape(B, Sq, H, hd))
    return out.astype(COMPUTE_DTYPE)


def attention(cfg, p, x, *, positions, causal: bool = True, kv_x=None,
              kv_positions=None, rope: bool = True, block_k: int = 1024,
              attn_impl=None, constrain=lambda x: x):
    """Full (train/prefill) attention. Returns (output [B,S,d], (k, v))."""
    q, k, v = _project_qkv(cfg, p, x, kv_x=kv_x, positions=positions,
                           kv_positions=kv_positions, rope=rope)
    # re-anchor the sharding after the feature-sharded projections: q stays
    # sequence-sharded; k/v likewise until the blockwise scan gathers them
    # per block (without this, SPMD may materialize full-sequence f32 score
    # tensors — observed 122 GB/device on llama-vision train_4k)
    q = constrain(q)
    if kv_x is None:
        k = constrain(k)
        v = constrain(v)
    impl = attn_impl or blockwise_attention
    o = impl(q, k, v, causal=causal, q_positions=positions,
             kv_positions=kv_positions, block_k=block_k)
    o = o.reshape(*o.shape[:-2], cfg.n_heads * cfg.hd)
    return o @ p["wo"].astype(COMPUTE_DTYPE), (k, v)


def _onehot_update(cache, new, pos):
    """cache [B, S, KV, hd] ← new [B, 1, KV, hd] at sequence index ``pos``.

    One-hot scatter: every shard updates only its local slice, no cross-shard
    gather under SPMD (a dynamic-update-slice on a sharded dim would gather).
    """
    S = cache.shape[1]
    oh = (jnp.arange(S) == pos).astype(cache.dtype)[None, :, None, None]
    return cache * (1 - oh) + oh * new.astype(cache.dtype)


def decode_attention(cfg, p, x, cache_k, cache_v, pos, *, cross: bool = False):
    """Single-token attention against a (sequence-sharded) cache.

    x: [B, 1, d]; cache_k/v: [B, S, KV, hd]; pos: scalar current position.
    Returns (out [B, 1, d], cache_k, cache_v).
    """
    positions = jnp.full((1, 1), pos, jnp.int32)
    if cross:
        # cross-attention caches are precomputed at prefill; no update, no rope
        q, _, _ = _project_qkv(cfg, p, x, kv_x=jnp.zeros_like(x), rope=False,
                               positions=positions)
        k, v = cache_k, cache_v
        mask = None
    else:
        q, k_new, v_new = _project_qkv(cfg, p, x, positions=positions,
                                       kv_positions=positions)
        cache_k = _onehot_update(cache_k, k_new, pos)
        cache_v = _onehot_update(cache_v, v_new, pos)
        k, v = cache_k, cache_v
        mask = (jnp.arange(k.shape[1]) <= pos)[None, None, None, :]  # [1,1,1,S]

    B, S, KV, hd = k.shape
    H = cfg.n_heads
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if mask is not None:
        s = jnp.where(mask[:, :, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)  # reduction over sharded S → psum via SPMD
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(COMPUTE_DTYPE),
                   v.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H * hd).astype(COMPUTE_DTYPE)
    return o @ p["wo"].astype(COMPUTE_DTYPE), cache_k, cache_v
