"""Mamba2 (SSD) block — chunked selective-state-space compute (zamba2).

Train/prefill use the chunkwise SSD form: within a chunk (length ``CHUNK``)
the recurrence is evaluated as a masked quadratic form; across chunks the
state [B, H, P, N] is carried by a ``lax.scan``. Decode is the single-step
recurrence. Both paths share the same discretization, so decode extends
prefill bit-consistently (tested against a pure sequential scan oracle in
tests/test_models_smoke.py).

TPU adaptation notes (DESIGN.md §2): heads shard over "model"
(H = expand·d/headdim is a multiple of 16 for zamba2-7b: 112), sequence stays
local to a device (the inter-chunk recurrence is sequential), batch shards
over ("pod","data").
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, dense_init, ones_init, zeros_init

__all__ = ["init_mamba", "mamba_chunked", "mamba_decode_step", "mamba_init_state",
           "CHUNK"]

CHUNK = 128
CONV_K = 4  # causal depthwise conv window


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = d_in // P
    N = cfg.ssm_state
    return d_in, H, P, N


def init_mamba(cfg, kg):
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    p = {
        "in_proj": dense_init(kg(), (d, 2 * d_in + 2 * N + H)),  # z, x, B, C, dt
        "conv_w": dense_init(kg(), (CONV_K, d_in + 2 * N), scale=0.5),
        "A_log": zeros_init(kg(), (H,)),
        "dt_bias": zeros_init(kg(), (H,)),
        "D": ones_init(kg(), (H,)),
        "out_proj": dense_init(kg(), (d_in, d)),
    }
    logical = {
        "in_proj": ("d_in", "feat"),
        "conv_w": ("none", "feat"),
        "A_log": ("none",),
        "dt_bias": ("none",),
        "D": ("none",),
        "out_proj": ("feat", "d_in"),
    }
    return p, logical


def _split_proj(cfg, p, x):
    d_in, H, P, N = _dims(cfg)
    zxbcdt = x @ p["in_proj"].astype(COMPUTE_DTYPE)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xbc, dt


def _discretize(p, dt):
    """dt [..., H] → (log decay per step [..., H], effective dt [..., H])."""
    dt_eff = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H], negative
    return A * dt_eff, dt_eff  # log-decay = A·dt  (≤ 0)


def _conv(p, xbc, conv_state=None):
    """Causal depthwise conv over seq. xbc: [B, S, d_in + 2N].

    conv_state (decode): [B, CONV_K-1, d_in+2N] trailing context.
    Returns (out, new_conv_state).
    """
    w = p["conv_w"].astype(COMPUTE_DTYPE)  # [K, F]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], CONV_K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+K-1, F]
    out = sum(xp[:, i : i + xbc.shape[1], :] * w[i] for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1) :, :]
    return jax.nn.silu(out.astype(jnp.float32)).astype(COMPUTE_DTYPE), new_state


def mamba_init_state(cfg, batch, dtype=jnp.float32):
    d_in, H, P, N = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, CONV_K - 1, d_in + 2 * N), dtype),
    }


def mamba_chunked(cfg, p, x, state=None):
    """x: [B, S, d], S % CHUNK == 0. Returns (y [B,S,d], final_state)."""
    d_in, H, P, N = _dims(cfg)
    B, S, d = x.shape
    L = min(CHUNK, S)
    nc = S // L
    assert S % L == 0

    z, xbc, dt = _split_proj(cfg, p, x)
    conv_in_state = None if state is None else state["conv"]
    xbc, conv_state = _conv(p, xbc, conv_in_state)
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xh = xs.reshape(B, S, H, P)
    logdec, dt_eff = _discretize(p, dt)  # [B,S,H]

    # chunk views
    xc = xh.reshape(B, nc, L, H, P)
    Bc = Bmat.reshape(B, nc, L, N).astype(jnp.float32)
    Cc = Cmat.reshape(B, nc, L, N).astype(jnp.float32)
    ld = logdec.reshape(B, nc, L, H)
    dtc = dt_eff.reshape(B, nc, L, H)

    cum = jnp.cumsum(ld, axis=2)                     # [B,nc,L,H] inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Li,Lj,H]
    causal = jnp.tril(jnp.ones((L, L), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay_ij = jnp.exp(seg)                          # [B,nc,Li,Lj,H]

    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # [B,nc,Li,Lj]
    scores = cb[..., None] * decay_ij * dtc[:, :, None, :, :]  # [B,nc,Li,Lj,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp",
                         scores.astype(COMPUTE_DTYPE), xc)

    # inter-chunk: state recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,L,H]
    # per-chunk state contribution: sum_j decay_to_end_j dt_j B_j ⊗ x_j
    contrib = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn",
                         decay_to_end.astype(jnp.float32),
                         dtc.astype(jnp.float32),
                         Bc, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])          # [B,nc,H]

    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if state is None
          else state["ssm"].astype(jnp.float32))

    def step(s, inp):
        dec, con = inp  # [B,H], [B,H,P,N]
        s_out = s  # state BEFORE this chunk (used by y_inter)
        s_new = s * dec[:, :, None, None] + con
        return s_new, s_out

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    con_t = jnp.moveaxis(contrib, 1, 0)
    s_final, s_before = jax.lax.scan(step, s0, (dec_t, con_t))
    s_before = jnp.moveaxis(s_before, 0, 1)          # [B,nc,H,P,N]

    decay_in = jnp.exp(cum)                          # [B,nc,L,H]
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc, decay_in.astype(jnp.float32), s_before)

    y = (y_intra.astype(jnp.float32) + y_inter
         + xh.reshape(B, nc, L, H, P).astype(jnp.float32)
         * p["D"].astype(jnp.float32)[None, None, None, :, None])
    y = y.reshape(B, S, d_in).astype(COMPUTE_DTYPE)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    out = y @ p["out_proj"].astype(COMPUTE_DTYPE)
    return out, {"ssm": s_final, "conv": conv_state}


def mamba_decode_step(cfg, p, x, state):
    """x: [B, 1, d]; single-step recurrence. Returns (y [B,1,d], state)."""
    d_in, H, P, N = _dims(cfg)
    B = x.shape[0]
    z, xbc, dt = _split_proj(cfg, p, x)
    xbc, conv_state = _conv(p, xbc, state["conv"])
    xs, Bmat, Cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xh = xs.reshape(B, H, P)
    logdec, dt_eff = _discretize(p, dt[:, 0, :])     # [B,H]
    dec = jnp.exp(logdec)
    s = state["ssm"].astype(jnp.float32)
    s = (s * dec[:, :, None, None]
         + jnp.einsum("bh,bn,bhp->bhpn", dt_eff, Bmat[:, 0].astype(jnp.float32),
                      xh.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cmat[:, 0].astype(jnp.float32), s)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_in).astype(COMPUTE_DTYPE)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), {"ssm": s, "conv": conv_state}
