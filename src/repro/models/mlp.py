"""Feed-forward blocks: gated SwiGLU (llama family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, dense_init, zeros_init

__all__ = ["init_swiglu", "swiglu", "init_gelu_mlp", "gelu_mlp"]


def init_swiglu(cfg, kg, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    p = {
        "w1": dense_init(kg(), (d, ff)),   # gate
        "w3": dense_init(kg(), (d, ff)),   # up
        "w2": dense_init(kg(), (ff, d)),   # down
    }
    logical = {"w1": ("d_in", "feat"), "w3": ("d_in", "feat"), "w2": ("feat", "d_in")}
    return p, logical


def swiglu(p, x):
    g = x @ p["w1"].astype(COMPUTE_DTYPE)
    u = x @ p["w3"].astype(COMPUTE_DTYPE)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u
    return h @ p["w2"].astype(COMPUTE_DTYPE)


def init_gelu_mlp(cfg, kg):
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "w1": dense_init(kg(), (d, ff)),
        "b1": zeros_init(kg(), (ff,)),
        "w2": dense_init(kg(), (ff, d)),
        "b2": zeros_init(kg(), (d,)),
    }
    logical = {"w1": ("d_in", "feat"), "b1": ("feat",),
               "w2": ("feat", "d_in"), "b2": ("none",)}
    return p, logical


def gelu_mlp(p, x):
    h = x @ p["w1"].astype(COMPUTE_DTYPE) + p["b1"].astype(COMPUTE_DTYPE)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    return h @ p["w2"].astype(COMPUTE_DTYPE) + p["b2"].astype(COMPUTE_DTYPE)
