"""Logical-axis sharding rules (MaxText-style), resolved against a mesh.

Every parameter and activation is annotated with *logical* axis names; a
per-family rule table maps logical names to mesh axes. Rules silently drop
mesh axes that don't exist in the current mesh (so the same annotations work
on the single-pod ``("data","model")`` and multi-pod ``("pod","data","model")``
meshes, and on the 1-device CPU mesh used by smoke tests, where everything
resolves to replicated).

Parallelism encoding:

* ``batch``    → ("pod", "data")   — DP across pods and the data axis
* ``d_in``     → ("data",)         — FSDP: weights sharded on their input dim,
                                     all-gathered per layer inside the scan
* ``feat``/``heads_flat``/``vocab`` → ("model",)  — megatron TP
* ``act_seq``  → ("model",)        — sequence parallelism at layer boundaries
                                     (dense/MoE/enc-dec/VLM profile)
* ``kv_seq``   → ("model",)        — decode KV caches sharded along sequence,
                                     attention reduces with psum (works for any
                                     GQA head count — see DESIGN.md)
* ``experts``  → ("model",)        — expert parallelism (MoE)
* SSM profile: activations stay sequence-local; cell state dims shard on model.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "rules_for", "logical_to_spec", "shardings_for_tree", "constrain"]

Rules = Dict[str, Tuple[str, ...]]

_TP_RULES: Rules = {
    "batch": ("pod", "data"),
    "act_seq": ("model",),
    "kv_seq": ("data", "model"),  # decode caches; batch claims "data" first
    "d_in": ("data",),
    "feat": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "none": (),
}

_SSM_RULES: Rules = {
    # sequence stays local (the state recurrence is sequential in seq), so the
    # batch takes every available mesh axis (pure DP); weights stay FSDP+TP
    # sharded. When the batch doesn't cover the full mesh (decode shapes),
    # the divisibility-aware resolver falls back to a prefix of the axes and
    # frees "model" for the kv_seq / cell dims.
    # order matters: preferring (data, model) keeps B=1/device on BOTH
    # meshes at global_batch=256 (the multi-pod (pod,data) prefix gave
    # B=8/device and 75 GB temps); the pod axis joins only when the batch
    # covers it (global_batch ≥ 512 — the elastic-scaling recommendation
    # for SSM/hybrid training, DESIGN.md §4).
    "batch": ("data", "model", "pod"),
    "act_seq": (),
    "kv_seq": ("data", "model"),  # long_500k batch=1 frees both axes
    "d_in": ("data",),
    "feat": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "none": (),
}


def rules_for(family: str) -> Rules:
    return _SSM_RULES if family in ("ssm", "hybrid") else _TP_RULES


def logical_to_spec(
    logical: Tuple[Optional[str], ...], rules: Rules, mesh: Mesh,
    shape: Optional[Tuple[int, ...]] = None,
) -> P:
    """Map logical axis names (None = replicated) to a PartitionSpec.

    Resolution is left-to-right, divisibility-aware and duplicate-free:
    each dimension takes the longest *prefix* of its rule's mesh axes that
    (a) exists in the mesh, (b) hasn't been claimed by an earlier dimension
    of the same tensor, and (c) divides the dimension size (when ``shape``
    is provided). This is what lets one rule table serve every mesh and every
    (train/prefill/decode/long-context) shape — e.g. a decode batch of 128
    takes ("pod","data") and leaves "model" free for the kv_seq dim.
    """
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    used: set = set()
    out = []
    for i, ax in enumerate(logical):
        if ax is None:
            out.append(None)
            continue
        if ax not in rules:
            raise KeyError(f"unknown logical axis {ax!r}")
        candidates = [a for a in rules[ax] if a in names and a not in used]
        dim = shape[i] if shape is not None and i < len(shape) else None
        chosen: list = []
        prod = 1
        for a in candidates:
            if dim is not None and dim % (prod * sizes[a]) != 0:
                continue  # skip non-dividing axes but keep trying later ones
            prod *= sizes[a]
            chosen.append(a)
        if not chosen:
            out.append(None)
            continue
        used.update(chosen)
        out.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shardings_for_tree(
    logical_tree: Any, abstract_tree: Any, rules: Rules, mesh: Mesh
) -> Any:
    """NamedSharding tree for a pytree of logical-axis annotations."""

    def one(logical, leaf):
        spec = logical_to_spec(logical, rules, mesh, shape=tuple(leaf.shape))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, logical_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def constrain(x, rules: Rules, *logical: Optional[str]):
    """with_sharding_constraint via logical names (requires a mesh context).

    No-op outside jit on a single device (smoke tests).
    """
    mesh = _current_mesh()
    if mesh is None or mesh.empty or mesh.size == 1:
        return x
    spec = logical_to_spec(tuple(logical), rules, mesh, shape=tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return m
    except Exception:
        return None
