"""xLSTM blocks: mLSTM (matrix memory, chunked linear attention) and sLSTM
(scalar memory, strictly sequential recurrence).

mLSTM train/prefill uses a chunkwise-parallel form with carried
(C, n, m) state — matrix memory C [B,H,hd,hd], normalizer n [B,H,hd], and the
log-space stabilizer m [B,H] from the xLSTM paper (exp input gate + sigmoid
forget gate, stabilized by the running max). Decode is the single-step
recurrence; the two paths agree bit-consistently up to bf16 rounding
(tested against a step-by-step oracle).

sLSTM has no parallel form (the hidden state feeds back into the gates); it
is a ``lax.scan`` over time — one of the paper's "inherently sequential"
tasks, which is why xlstm-1.3b interleaves it only every 8th block.

TPU adaptation (DESIGN.md): hd=512 matrix memory shards its first dim over
"model" (512 % 16 == 0 for the full config); sequence stays local; batch
shards over ("pod","data").
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, dense_init, ones_init, rmsnorm, zeros_init

__all__ = [
    "init_mlstm",
    "mlstm_chunked",
    "mlstm_decode_step",
    "mlstm_init_state",
    "init_slstm",
    "slstm_seq",
    "slstm_decode_step",
    "slstm_init_state",
    "MLSTM_CHUNK",
]

MLSTM_CHUNK = 128


def _mdims(cfg):
    H = cfg.n_heads
    d_in = 2 * cfg.d_model          # up-projection factor 2 (xLSTM block)
    hd = d_in // H
    return d_in, H, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg, kg):
    d = cfg.d_model
    d_in, H, hd = _mdims(cfg)
    p = {
        "wq": dense_init(kg(), (d, d_in)),
        "wk": dense_init(kg(), (d, d_in)),
        "wv": dense_init(kg(), (d, d_in)),
        "wi": dense_init(kg(), (d, H)),      # input gate (exp)
        "wf": dense_init(kg(), (d, H)),      # forget gate (sigmoid)
        "wo_gate": dense_init(kg(), (d, d_in)),
        "out_proj": dense_init(kg(), (d_in, d)),
    }
    logical = {
        "wq": ("d_in", "feat"), "wk": ("d_in", "feat"), "wv": ("d_in", "feat"),
        "wi": ("d_in", "none"), "wf": ("d_in", "none"),
        "wo_gate": ("d_in", "feat"), "out_proj": ("feat", "d_in"),
    }
    return p, logical


def mlstm_init_state(cfg, batch, dtype=jnp.float32):
    d_in, H, hd = _mdims(cfg)
    return {
        "C": jnp.zeros((batch, H, hd, hd), dtype),
        "n": jnp.zeros((batch, H, hd), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
    }


def _mlstm_qkvif(cfg, p, x):
    d_in, H, hd = _mdims(cfg)
    B, S, _ = x.shape
    # Re-anchor the batch sharding after every projection: without this,
    # SPMD resolves (batch-sharded x) × (model-sharded W) as partial matmuls
    # + an all-reduce of the full activation per einsum — 447 GB/device of
    # all-reduce on xlstm train_4k (§Perf #2). The constraint makes SPMD
    # all-gather the (much smaller) weights instead.
    from .sharding import constrain as _constrain, rules_for as _rules_for

    _r = _rules_for("ssm")

    def _c(a):
        dims = ("batch",) + (None,) * (a.ndim - 1)
        return _constrain(a, _r, *dims)

    q = _c((x @ p["wq"].astype(COMPUTE_DTYPE)).reshape(B, S, H, hd))
    k = _c((x @ p["wk"].astype(COMPUTE_DTYPE)).reshape(B, S, H, hd)) * (hd ** -0.5)
    v = _c((x @ p["wv"].astype(COMPUTE_DTYPE)).reshape(B, S, H, hd))
    i_pre = _c((x @ p["wi"].astype(COMPUTE_DTYPE))).astype(jnp.float32)  # [B,S,H]
    f_pre = _c((x @ p["wf"].astype(COMPUTE_DTYPE))).astype(jnp.float32)
    return q, k, v, i_pre, f_pre


def mlstm_chunked(cfg, p, x, state=None):
    """x: [B,S,d] → (y [B,S,d], state). S % MLSTM_CHUNK == 0 (or S < chunk)."""
    d_in, H, hd = _mdims(cfg)
    B, S, d = x.shape
    L = min(MLSTM_CHUNK, S)
    nc = S // L
    assert S % L == 0

    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, x)
    logf = jax.nn.log_sigmoid(f_pre)                  # [B,S,H]

    qc = q.reshape(B, nc, L, H, hd)
    kc = k.reshape(B, nc, L, H, hd)
    vc = v.reshape(B, nc, L, H, hd)
    ic = i_pre.reshape(B, nc, L, H)
    fc = logf.reshape(B, nc, L, H)

    cumf = jnp.cumsum(fc, axis=2)                     # inclusive per chunk
    # log gate of source j as seen at target i (within chunk):
    #   D[i,j] = cumf_i - cumf_j + i_pre_j   for j ≤ i
    Dmat = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + ic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    Dmat = jnp.where(causal[None, None, :, :, None], Dmat, -jnp.inf)

    # carried state per chunk (scan): C, n, m
    if state is None:
        state = mlstm_init_state(cfg, B)
    # inter-chunk gate: contribution of carry at position i has log-gate cumf_i
    # overall stabilizer per position: m_i = max(max_j D[i,j], cumf_i + m_prev)

    def chunk_step(carry, inp):
        C_prev, n_prev, m_prev = carry
        qb, kb, vb, Db, cumfb, ib = inp
        # qb.. [B,L,H,hd]; Db [B,Li,Lj,H]; cumfb [B,L,H]
        m_intra = Db.max(axis=2)                      # [B,Li,H]
        m_inter = cumfb + m_prev[:, None, :]          # [B,L,H]
        m_i = jnp.maximum(m_intra, m_inter)           # [B,L,H]
        # intra scores
        sc = jnp.exp(Db - m_i[:, :, None, :])         # [B,Li,Lj,H]
        qk = jnp.einsum("blhd,bjhd->bljh", qb, kb,
                        preferred_element_type=jnp.float32)
        w = sc * qk
        y_num_intra = jnp.einsum("bljh,bjhd->blhd", w.astype(COMPUTE_DTYPE), vb)
        y_den_intra = w.sum(axis=2)                   # [B,Li,H] = q_i · n_intra_i
        # inter: y += exp(cumf_i + m_prev - m_i) q·C_prev
        g_inter = jnp.exp(m_inter - m_i)              # [B,L,H]
        qC = jnp.einsum("blhd,bhde->blhe", qb, C_prev.astype(COMPUTE_DTYPE))
        qn = jnp.einsum("blhd,bhd->blh", qb, n_prev.astype(COMPUTE_DTYPE))
        y_num = y_num_intra.astype(jnp.float32) + g_inter[..., None] * qC.astype(jnp.float32)
        y_den = y_den_intra.astype(jnp.float32) + g_inter * qn.astype(jnp.float32)
        y = y_num / jnp.maximum(jnp.abs(y_den), 1.0)[..., None]
        # state update to end of chunk with new stabilizer m_new
        m_new = jnp.maximum(cumfb[:, -1, :] + m_prev, (cumfb[:, -1:, :] - cumfb + ib).max(axis=1))
        gdec = jnp.exp(cumfb[:, -1, :] + m_prev - m_new)       # [B,H]
        gsrc = jnp.exp(cumfb[:, -1:, :] - cumfb + ib - m_new[:, None, :])  # [B,L,H]
        C_new = (C_prev * gdec[:, :, None, None]
                 + jnp.einsum("blh,blhd,blhe->bhde", gsrc,
                              kb.astype(jnp.float32), vb.astype(jnp.float32)))
        n_new = (n_prev * gdec[:, :, None]
                 + jnp.einsum("blh,blhd->bhd", gsrc, kb.astype(jnp.float32)))
        return (C_new, n_new, m_new), y

    inp = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(Dmat, 1, 0), jnp.moveaxis(cumf, 1, 0), jnp.moveaxis(ic, 1, 0),
    )
    (C, n, m), y = jax.lax.scan(chunk_step, (state["C"], state["n"], state["m"]), inp)
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, d_in)
    o = jax.nn.sigmoid((x @ p["wo_gate"].astype(COMPUTE_DTYPE)).astype(jnp.float32))
    y = (y * o).astype(COMPUTE_DTYPE)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), {"C": C, "n": n, "m": m}


def mlstm_decode_step(cfg, p, x, state):
    """x: [B,1,d]; exact single-step recurrence."""
    d_in, H, hd = _mdims(cfg)
    B = x.shape[0]
    q, k, v, i_pre, f_pre = _mlstm_qkvif(cfg, p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]               # [B,H,hd]
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]           # [B,H]
    logf = jax.nn.log_sigmoid(f_pre)
    m_prev, C_prev, n_prev = state["m"], state["C"], state["n"]
    m_new = jnp.maximum(logf + m_prev, i_pre)
    gdec = jnp.exp(logf + m_prev - m_new)
    gsrc = jnp.exp(i_pre - m_new)
    C = (C_prev * gdec[:, :, None, None]
         + gsrc[:, :, None, None] * jnp.einsum("bhd,bhe->bhde",
                                               k.astype(jnp.float32),
                                               v.astype(jnp.float32)))
    n = n_prev * gdec[:, :, None] + gsrc[:, :, None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(B, 1, d_in)
    o = jax.nn.sigmoid((x @ p["wo_gate"].astype(COMPUTE_DTYPE)).astype(jnp.float32))
    y = (y * o).astype(COMPUTE_DTYPE)
    return y @ p["out_proj"].astype(COMPUTE_DTYPE), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _sdims(cfg):
    H = cfg.n_heads
    hd = cfg.d_model // H
    return H, hd


def init_slstm(cfg, kg):
    d = cfg.d_model
    H, hd = _sdims(cfg)
    p = {
        "w_in": dense_init(kg(), (d, 4 * d)),          # i,f,z,o pre-activations
        "r": dense_init(kg(), (H, hd, 4 * hd), scale=0.05),  # block-diag recurrence
        "b": zeros_init(kg(), (4 * d,)),
        "out_proj": dense_init(kg(), (d, d)),
        # gated FF (factor 4/3, GLU) — the sLSTM block's post-projection
        "ff_w1": dense_init(kg(), (d, 4 * d // 3)),
        "ff_w3": dense_init(kg(), (d, 4 * d // 3)),
        "ff_w2": dense_init(kg(), (4 * d // 3, d)),
    }
    logical = {
        # The recurrence h_t → gates contracts hd every step: sharding r (or
        # the gate dim) over "model" forces a per-timestep all-reduce inside
        # the 4096-step scan — 412 GB/device of collective traffic on
        # train_4k (§Perf #2). The recurrence is instead batch-parallel with
        # replicated recurrent weights (they are tiny: H·hd·4hd).
        "w_in": ("d_in", None), "r": ("none", "none", "none"), "b": ("none",),
        "out_proj": ("d_in", "feat"),
        "ff_w1": ("d_in", "feat"), "ff_w3": ("d_in", "feat"),
        "ff_w2": ("feat", "d_in"),
    }
    return p, logical


def slstm_init_state(cfg, batch, dtype=jnp.float32):
    H, hd = _sdims(cfg)
    return {
        "c": jnp.zeros((batch, H, hd), dtype),
        "n": jnp.zeros((batch, H, hd), dtype),
        "h": jnp.zeros((batch, H, hd), dtype),
        "m": jnp.full((batch, H, hd), -1e30, dtype),
    }


def _slstm_cell(p, H, hd, pre, st):
    """pre: [B, 4d] input pre-activation; st: state dict. Returns (h, state)."""
    rec = jnp.einsum("bhd,hdq->bhq", st["h"].astype(COMPUTE_DTYPE),
                     p["r"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    pre = pre.reshape(pre.shape[0], H, 4 * hd).astype(jnp.float32) + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)   # [B,H,hd]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + st["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(logf + st["m"] - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_g * st["c"] + i_g * z
    n = f_g * st["n"] + i_g
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return h, {"c": c, "n": n, "h": h, "m": m_new}


def slstm_seq(cfg, p, x, state=None):
    """x: [B,S,d] → (y [B,S,d], state). Strictly sequential scan over S."""
    H, hd = _sdims(cfg)
    B, S, d = x.shape
    if state is None:
        state = slstm_init_state(cfg, B)
    pre_all = x @ p["w_in"].astype(COMPUTE_DTYPE) + p["b"].astype(COMPUTE_DTYPE)

    def step(st, pre_t):
        h, st2 = _slstm_cell(p, H, hd, pre_t, st)
        return st2, h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre_all, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(COMPUTE_DTYPE)
    y = hs @ p["out_proj"].astype(COMPUTE_DTYPE)
    # gated FF
    g = y @ p["ff_w1"].astype(COMPUTE_DTYPE)
    u = y @ p["ff_w3"].astype(COMPUTE_DTYPE)
    ff = (jax.nn.gelu(g.astype(jnp.float32)).astype(COMPUTE_DTYPE) * u) @ p[
        "ff_w2"].astype(COMPUTE_DTYPE)
    return ff, state


def slstm_decode_step(cfg, p, x, state):
    y, state = slstm_seq(cfg, p, x, state)
    return y, state
