"""Decoder-only LM assembly (dense / MoE / VLM families).

Layers are stacked along a leading axis and executed with ``jax.lax.scan`` so
the HLO stays O(1) in depth (essential for 62-layer dry-runs) and FSDP
all-gathers happen per layer inside the loop (overlapping with the previous
layer's compute under XLA's latency-hiding scheduler). VLM groups
(cross_attn_every − 1 self layers + 1 cross-attention layer) scan over groups
with an inner scan over the self layers.

Remat: the scanned body is wrapped in ``jax.checkpoint`` — the scan carry
(one [B, S/SP, d] activation per layer boundary) is all that survives the
forward pass, the paper-faithful "store only what later bursts read" policy.
The remat segmentation itself is chosen by the Julienning partitioner in
``repro.core.remat_policy`` (see §Perf).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention, init_attention
from .common import COMPUTE_DTYPE, KeyGen, dense_init, ones_init, rmsnorm, softmax_cross_entropy
from .mlp import init_swiglu, swiglu
from .moe import init_moe, moe_block

__all__ = ["init_lm", "lm_forward", "lm_loss", "lm_prefill", "lm_decode_step",
           "lm_cache_shape", "stack_init"]


def stack_init(n: int, init_one, key):
    """Stack ``n`` copies of ``init_one(kg) -> (tree, logical)`` along axis 0.

    ``key=None`` → abstract (ShapeDtypeStruct) tree, no allocation.
    """
    def one(k):
        tree, _ = init_one(KeyGen(k))
        return tree

    _, logical = init_one(_probe())
    if key is None:
        tree = jax.eval_shape(one, jax.random.PRNGKey(0))
        tree = jax.tree.map(lambda l: jax.ShapeDtypeStruct((n, *l.shape), l.dtype), tree)
    else:
        tree = jax.vmap(one)(jax.random.split(key, n))
    logical = jax.tree.map(lambda ax: ("layers", *ax), logical,
                           is_leaf=lambda x: isinstance(x, tuple))
    return tree, logical


class _probe:
    """KeyGen stand-in used only to extract the logical tree."""

    def __call__(self):
        return None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg, kg):
    attn_p, attn_l = init_attention(cfg, kg)
    p = {"attn": attn_p, "ln1": ones_init(kg(), (cfg.d_model,)),
         "ln2": ones_init(kg(), (cfg.d_model,))}
    l = {"attn": attn_l, "ln1": ("none",), "ln2": ("none",)}
    if cfg.family == "moe":
        p["moe"], l["moe"] = init_moe(cfg, kg)
    else:
        p["mlp"], l["mlp"] = init_swiglu(cfg, kg)
    return p, l


def _init_cross_layer(cfg, kg):
    attn_p, attn_l = init_attention(cfg, kg, cross=True)
    gate = dense_init(kg(), (1,), scale=0.0)  # llama-vision: zero-init attn gate
    p = {"attn": attn_p, "ln": ones_init(kg(), (cfg.d_model,)), "gate": gate}
    l = {"attn": attn_l, "ln": ("none",), "gate": ("none",)}
    return p, l


def init_lm(cfg, key=None):
    """Returns (params, logical). ``key=None`` → abstract params (dry-run)."""
    kg = KeyGen(key) if key is not None else _probe()
    params: Dict[str, Any] = {
        "embed": dense_init(kg() if key is not None else None, (cfg.vocab, cfg.d_model)),
        "final_norm": ones_init(kg() if key is not None else None, (cfg.d_model,)),
    }
    logical: Dict[str, Any] = {"embed": ("vocab", "d_in"), "final_norm": ("none",)}
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kg() if key is not None else None,
                                    (cfg.d_model, cfg.vocab))
        logical["head"] = ("d_in", "vocab")

    lkey = None if key is None else kg()
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        n_groups = cfg.n_layers // per

        # groups: [n_groups, per-1, ...] self layers + [n_groups, ...] cross
        def init_pair(kg2):
            sp, sl = stack_init(per - 1, lambda kg3: _init_layer(cfg, kg3),
                                kg2() if not isinstance(kg2, _probe) else None)
            cp, cl = _init_cross_layer(cfg, kg2)
            return {"self": sp, "cross": cp}, {"self": sl, "cross": cl}
        params["groups"], logical["groups"] = stack_init(n_groups, init_pair, lkey)
    else:
        params["layers"], logical["layers"] = stack_init(
            cfg.n_layers, lambda kg2: _init_layer(cfg, kg2), lkey)
    return params, logical


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens):
    e = jnp.take(params["embed"].astype(COMPUTE_DTYPE), tokens, axis=0)
    return e


def _head(cfg, params, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return x @ w.astype(COMPUTE_DTYPE)


def _layer_apply(cfg, lp, x, positions, constrain, attn_impl=None):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    a, kv = attention(cfg, lp["attn"], h, positions=positions,
                      attn_impl=attn_impl, constrain=constrain)
    x = constrain(x + a)
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_block(cfg, lp["moe"], h)
    else:
        m, aux = swiglu(lp["mlp"], h), jnp.zeros((), jnp.float32)
    x = constrain(x + m)
    return x, aux, kv


def _cross_apply(cfg, cp, x, vision, constrain):
    h = rmsnorm(x, cp["ln"], cfg.norm_eps)
    a, kv = attention(cfg, cp["attn"], h, positions=jnp.arange(x.shape[1])[None],
                      causal=False, kv_x=vision,
                      kv_positions=jnp.arange(vision.shape[1])[None], rope=False,
                      constrain=constrain)
    gate = jnp.tanh(cp["gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE)
    x = constrain(x + gate * a)
    return x, kv


def lm_forward(cfg, params, tokens, constrain=lambda x: x, vision=None,
               remat: bool = True, attn_impl=None, collect_cache: bool = False):
    """tokens [B,S] → logits [B,S,V]. Optionally collects the KV cache."""
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = constrain(_embed(cfg, params, tokens))
    aux_total = jnp.zeros((), jnp.float32)

    def body(x, lp):
        x, aux, kv = _layer_apply(cfg, lp, x, positions, constrain, attn_impl)
        return x, (aux, kv if collect_cache else None)

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body

    caches = None
    if cfg.family == "vlm":
        assert vision is not None

        def cross_fn(x, cp):
            return _cross_apply(cfg, cp, x, vision, constrain)

        if remat:
            cross_fn = jax.checkpoint(
                cross_fn, policy=jax.checkpoint_policies.nothing_saveable)

        def group_body(x, gp):
            x, (aux, kvs) = jax.lax.scan(body_fn, x, gp["self"])
            x, ckv = cross_fn(x, gp["cross"])
            return x, (aux, (kvs, ckv) if collect_cache else None)

        x, (auxs, caches) = jax.lax.scan(group_body, x, params["groups"])
        aux_total = auxs.sum()
    else:
        x, (auxs, caches) = jax.lax.scan(body_fn, x, params["layers"])
        aux_total = auxs.sum()

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, x)
    return logits, aux_total, caches


def lm_loss(cfg, params, tokens, labels, constrain=lambda x: x, vision=None,
            remat: bool = True, attn_impl=None):
    logits, aux, _ = lm_forward(cfg, params, tokens, constrain, vision,
                                remat=remat, attn_impl=attn_impl)
    ce = softmax_cross_entropy(logits, labels)
    return ce + (0.01 * aux if cfg.family == "moe" else 0.0), ce


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def lm_cache_shape(cfg, batch: int, max_seq: int):
    """Abstract KV-cache tree + logical axes (sequence-sharded)."""
    hd, KV = cfg.hd, cfg.n_kv_heads
    kv = jax.ShapeDtypeStruct((cfg.n_layers if cfg.family != "vlm"
                               else cfg.n_layers - cfg.n_layers // cfg.cross_attn_every,
                               batch, max_seq, KV, hd), COMPUTE_DTYPE)
    tree = {"k": kv, "v": kv}
    logical = {"k": ("layers", "batch", "kv_seq", "none", "none"),
               "v": ("layers", "batch", "kv_seq", "none", "none")}
    if cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        cross = jax.ShapeDtypeStruct((n_groups, batch, cfg.n_vision_tokens, KV, hd),
                                     COMPUTE_DTYPE)
        tree.update({"cross_k": cross, "cross_v": cross})
        logical.update({"cross_k": ("layers", "batch", "none", "none", "none"),
                        "cross_v": ("layers", "batch", "none", "none", "none")})
    return tree, logical


def lm_prefill(cfg, params, tokens, max_seq: int, constrain=lambda x: x,
               vision=None, attn_impl=None):
    """Prefill: forward pass that also materializes the padded KV cache."""
    B, S = tokens.shape
    logits, _, caches = lm_forward(cfg, params, tokens, constrain, vision,
                                   remat=False, attn_impl=attn_impl,
                                   collect_cache=True)

    def pad(kv):  # [L?, B, S, KV, hd] → padded to max_seq along S
        pad_width = [(0, 0)] * kv.ndim
        pad_width[2] = (0, max_seq - kv.shape[2])
        return jnp.pad(kv, pad_width)

    if cfg.family == "vlm":
        kvs, ckv = caches
        k, v = kvs  # [n_groups, per-1, B, S, KV, hd] — merge group dims
        k = k.reshape(-1, *k.shape[2:])
        v = v.reshape(-1, *v.shape[2:])
        ck, cv = ckv
        cache = {"k": pad(_to_cache_layout(k)), "v": pad(_to_cache_layout(v)),
                 "cross_k": _to_cache_layout(ck), "cross_v": _to_cache_layout(cv)}
    else:
        k, v = caches
        cache = {"k": pad(_to_cache_layout(k)), "v": pad(_to_cache_layout(v))}
    return logits[:, -1:, :], cache


def _to_cache_layout(kv):
    # attention() returns k/v as [..., B, S, KV, hd] already
    return kv.astype(COMPUTE_DTYPE)


def lm_decode_step(cfg, params, cache, token, pos, constrain=lambda x: x):
    """token [B,1] int32, pos scalar int32 → (logits [B,1,V], cache)."""
    x = constrain(_embed(cfg, params, token))

    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        n_groups = cfg.n_layers // per
        k = cache["k"].reshape(n_groups, per - 1, *cache["k"].shape[1:])
        v = cache["v"].reshape(n_groups, per - 1, *cache["v"].shape[1:])

        def group_body(x, gin):
            gp, gk, gv, gck, gcv = gin

            def body(x, lin):
                lp, ck_, cv_ = lin
                h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
                a, ck_, cv_ = decode_attention(cfg, lp["attn"], h, ck_, cv_, pos)
                x = constrain(x + a)
                h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
                x = constrain(x + swiglu(lp["mlp"], h))
                return x, (ck_, cv_)

            x, (gk, gv) = jax.lax.scan(body, x, (gp["self"], gk, gv))
            h = rmsnorm(x, gp["cross"]["ln"], cfg.norm_eps)
            a, _, _ = decode_attention(cfg, gp["cross"]["attn"], h, gck, gcv,
                                       pos, cross=True)
            gate = jnp.tanh(gp["cross"]["gate"].astype(jnp.float32)).astype(COMPUTE_DTYPE)
            x = constrain(x + gate * a)
            return x, (gk, gv)

        x, (k2, v2) = jax.lax.scan(group_body, x,
                                   (params["groups"], k, v,
                                    cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, k=k2.reshape(-1, *k2.shape[2:]),
                     v=v2.reshape(-1, *v2.shape[2:]))
    else:
        def body(x, lin):
            lp, ck_, cv_ = lin
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, ck_, cv_ = decode_attention(cfg, lp["attn"], h, ck_, cv_, pos)
            x = constrain(x + a)
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = moe_block(cfg, lp["moe"], h)
            else:
                m = swiglu(lp["mlp"], h)
            x = constrain(x + m)
            return x, (ck_, cv_)

        x, (k2, v2) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=k2, v=v2)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _head(cfg, params, x), cache
