"""Online plan consumption + offline table building (CLI).

:class:`ServePlanner` is the request-path face of a
:class:`repro.core.plan_table.PlanTable`: every query is an O(1) lookup —
no DP solve, no graph lowering — and the planner keeps counters the serving
regression tests pin ("zero partitioner solves on the request path").

Besides the serving plan itself, the stored cut points feed the other three
julienne consumers *without re-solving*:

* :meth:`ServePlanner.offload_plan` — price the tabulated bounds as an
  activation-offload schedule (:func:`repro.core.offload.price_offload_bounds`);
* :meth:`ServePlanner.remat_plan` — price them as remat segment boundaries
  (:func:`repro.core.remat_policy.remat_from_bounds`);
* :meth:`ServePlanner.pipeline_cuts` — the interior segment ends as
  pipeline-stage cuts.

:func:`request_cycles` maps a looked-up plan onto a request's token steps:
each step (prefill or one decode) is one traversal of the activation graph
and costs the plan's ``e_total``; consecutive steps are greedily grouped so
each cycle (E_s + steps) fits the energy budget. This is O(n) bookkeeping,
not a partitioner solve — the *intra*-step segmentation already fits Q by
construction of the table, so a single step over budget still forms a legal
one-step cycle.

CLI (offline build)::

    python -m repro.launch.planner --arch qwen3-4b \
        --buckets 2x24,2x48 --q-points 16 --out plan_qwen.npz

builds the Q grid from the buckets' own Q_min .. E_total(whole-app) range
(plus an unbounded entry), solves the whole grid in one batched engine call,
and writes the versioned table. ``--shards N`` shards the solve across N
devices (byte-identical output; see :mod:`repro.launch.dse`), ``--extend``
grows an existing table in place without re-solving tabulated cells, and
``--probe K`` re-validates K random cells against the live engine after the
build (the load-time staleness check).
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..configs import resolve_config as _resolve_config
from ..configs.base import ModelConfig
from ..core.layer_profile import lower_config, profile_model, build_activation_graph
from ..core.offload import OffloadPlan, price_offload_bounds
from ..core.partition import Infeasible, whole_app_partition, within_budget
from ..core.plan_table import (
    PlanTable,
    PlanTableError,
    SegmentPlan,
    build_plan_table,
    probe_plan_table,
    _default_cost,
)
from ..core.remat_policy import RematPlan, remat_from_bounds

__all__ = [
    "ADMISSION_OUTCOMES",
    "ServePlanner",
    "as_planner",
    "request_cycles",
    "build_table_for_arch",
    "derive_q_grid",
    "lower_buckets",
]


def resolve_config(arch: str, smoke: bool = True) -> ModelConfig:
    """Smoke-first view of the shared :func:`repro.configs.resolve_config`
    (the launch CLIs default to the smoke registry; serve.py, the DSE CLI,
    the plan-table builders, and the façade all resolve through the same
    helper)."""
    return _resolve_config(arch, smoke=smoke)


#: Admission-control outcomes the traffic harness reports per request.
ADMISSION_OUTCOMES = ("admitted", "deferred", "rejected")


def _fresh_planner_stats() -> Dict[str, object]:
    return {
        "lookups": 0,
        "hits": 0,       # lookups answered from the table
        "misses": 0,     # UnknownBucketError / Infeasible budget
        "admitted": 0,   # admission-control outcomes (see record_admission)
        "deferred": 0,
        "rejected": 0,
        "by_bucket": {},  # "BATCHxSEQ" -> hit count
    }


class ServePlanner:
    """O(1) plan lookups for the serving loop, with observability counters.

    ``stats`` carries per-bucket hit/miss counters (every :meth:`plan_for`
    call) plus the fleet admission counters the continuous-traffic harness
    reports through :meth:`record_admission`. Counters are process-lifetime
    for the planner instance; consumers that compare across runs must
    snapshot-and-diff (or call :meth:`reset_stats` for a fresh baseline).
    """

    def __init__(self, table: PlanTable) -> None:
        self.table = table
        self.stats: Dict[str, object] = _fresh_planner_stats()

    def reset_stats(self) -> None:
        """Zero all counters (test isolation / per-run baselines)."""
        self.stats = _fresh_planner_stats()

    @classmethod
    def from_file(
        cls,
        path: str,
        *,
        probe: Optional[Union[ModelConfig, str]] = None,
        probe_k: Optional[int] = 4,
        probe_seed: int = 0,
        probe_cost=None,
    ) -> "ServePlanner":
        """Load a table; with ``probe`` (a ModelConfig or registry arch name),
        re-validate ``probe_k`` random cells against the live engine first —
        the load-time staleness check (raises
        :class:`repro.core.plan_table.StaleTableError` on any bit drift).
        ``probe_cost`` must name the table's cost model when it was built
        with a non-default one (defaults per table kind)."""
        table = PlanTable.load(path)
        if probe is not None:
            probe_plan_table(table, probe, k=probe_k, seed=probe_seed,
                             cost=probe_cost)
        return cls(table)

    @property
    def e_startup(self) -> float:
        return self.table.e_startup

    def plan_for(
        self, batch: int, seq: int, energy_budget: Optional[float] = None
    ) -> SegmentPlan:
        """Bucket the request shape and return the precomputed plan.

        A successful lookup counts as a *hit* (per-bucket, under the
        ``"BATCHxSEQ"`` key of the covering bucket); an untabulated shape or
        a budget below the Q grid counts as a *miss* and re-raises.
        """
        self.stats["lookups"] += 1
        try:
            plan = self.table.lookup(batch, seq, energy_budget)
        except (PlanTableError, Infeasible):
            self.stats["misses"] += 1
            raise
        self.stats["hits"] += 1
        key = f"{plan.batch}x{plan.seq_bucket}"
        by = self.stats["by_bucket"]
        by[key] = by.get(key, 0) + 1
        return plan

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups answered from the table (0.0 before any)."""
        n = self.stats["lookups"]
        return self.stats["hits"] / n if n else 0.0

    def record_admission(self, outcome: str) -> None:
        """Fleet admission observability: the traffic harness reports each
        request's outcome ('admitted' | 'deferred' | 'rejected') here so the
        admission counters live beside the lookup counters they gate on."""
        if outcome not in ADMISSION_OUTCOMES:
            raise ValueError(
                f"unknown admission outcome {outcome!r}; "
                f"expected one of {ADMISSION_OUTCOMES}"
            )
        self.stats[outcome] += 1

    # -- derived consumers (no DP solve; bounds come from the table) --------

    def _memory_plan(
        self, cfg: ModelConfig, batch: int, seq: int, hbm_budget: float
    ) -> Tuple[SegmentPlan, list, object]:
        if self.table.kind != "memory":
            raise PlanTableError(
                f"offload/remat derivation needs a kind='memory' table, "
                f"this one is kind={self.table.kind!r}"
            )
        if cfg.name != self.table.arch:
            raise PlanTableError(
                f"table was built for {self.table.arch!r}, not {cfg.name!r}"
            )
        plan = self.plan_for(batch, seq, hbm_budget)
        profiles, long_lived = profile_model(cfg, plan.batch, plan.seq_bucket)
        mem_graph = build_activation_graph(profiles, long_lived, kind="memory")
        return plan, profiles, mem_graph

    def offload_plan(
        self, cfg: ModelConfig, batch: int, seq: int, hbm_budget: float
    ) -> OffloadPlan:
        """Tabulated bounds priced as a PCIe offload schedule."""
        plan, profiles, mem_graph = self._memory_plan(cfg, batch, seq, hbm_budget)
        return price_offload_bounds(
            cfg.name, profiles, mem_graph, list(plan.bounds), hbm_budget
        )

    def remat_plan(
        self, cfg: ModelConfig, batch: int, seq: int, hbm_budget: float
    ) -> RematPlan:
        """Tabulated bounds priced as remat segment boundaries."""
        plan, profiles, mem_graph = self._memory_plan(cfg, batch, seq, hbm_budget)
        return remat_from_bounds(
            cfg.name, profiles, mem_graph, list(plan.bounds), hbm_budget
        )

    def pipeline_cuts(
        self, batch: int, seq: int, energy_budget: Optional[float] = None
    ) -> Tuple[int, ...]:
        """Interior segment ends of the looked-up plan — stage cut points."""
        return self.plan_for(batch, seq, energy_budget).cut_points


def as_planner(obj: Union[str, PlanTable, ServePlanner]) -> ServePlanner:
    """Coerce a path / table / planner into a ServePlanner."""
    if isinstance(obj, ServePlanner):
        return obj
    if isinstance(obj, PlanTable):
        return ServePlanner(obj)
    if isinstance(obj, str):
        return ServePlanner.from_file(obj)
    raise TypeError(f"cannot make a ServePlanner from {type(obj).__name__}")


def request_cycles(
    n_steps: int,
    step_energy: float,
    energy_budget: Optional[float] = None,
    e_startup: float = 0.0,
) -> List[Tuple[int, int]]:
    """Greedy grouping of token steps into energy-bounded cycles (1-based).

    Uses the shared solver tolerance (:func:`within_budget`) so a request
    whose steps exactly fill the budget is not split by float noise. With no
    budget the whole request is one cycle; a single step that alone exceeds
    the budget still forms its own cycle (its interior segmentation fits Q by
    table construction).
    """
    if n_steps <= 0:
        return []
    if energy_budget is None:
        return [(1, n_steps)]
    bounds: List[Tuple[int, int]] = []
    start = 1
    acc = e_startup + step_energy  # step `start` is always admitted
    for k in range(2, n_steps + 1):
        if within_budget(acc + step_energy, energy_budget):
            acc += step_energy
        else:
            bounds.append((start, k - 1))
            start = k
            acc = e_startup + step_energy
    bounds.append((start, n_steps))
    return bounds


def lower_buckets(
    cfg: ModelConfig, shape_buckets: List[Tuple[int, int]], kind: str = "time"
):
    """One lowered activation graph per (batch, seq) bucket."""
    return [lower_config(cfg, batch=b, seq=s, kind=kind)
            for (b, s) in shape_buckets]


def derive_q_grid(graphs, cm, n_q: int = 16) -> List[Optional[float]]:
    """The standard offline Q grid for a bucket set: geometric from
    [min over buckets of Q_min, max whole-app E_total × 1.05] plus one
    unbounded entry, so every bucket has both fully-julienned and
    single-cycle plans tabulated.

    Q_min goes through the façade's minimax objective (``backend="auto"``),
    so the build path picks the same registry backend — scan or the Pallas
    kernel's minimax mode — that the rest of the table build uses, instead
    of hardwiring the numpy DP (which would dense-walk graphs the registry
    routes to the CSR kernel).
    """
    from ..api import PartitionSpec, solve  # lazy: avoid import cycle

    lo = min(
        solve(PartitionSpec(graph=g, cost=cm, objective="minimax")).q_min()
        for g in graphs
    )
    hi = max(whole_app_partition(g, cm).e_total * 1.05 for g in graphs)
    qs: List[Optional[float]] = list(np.geomspace(lo, max(hi, lo * 1.0001), n_q))
    qs.append(None)
    return qs


def build_table_for_arch(
    arch: str,
    shape_buckets: List[Tuple[int, int]],
    n_q: int = 16,
    *,
    smoke: bool = True,
    kind: str = "time",
    cache_dir: Optional[str] = None,
    n_shards: Optional[int] = None,
) -> PlanTable:
    """Convenience offline build: derive the Q grid from the buckets
    (:func:`derive_q_grid`) and solve the whole grid in one batched façade
    call — or, with ``n_shards``, one Q-sharded multi-device call
    (``build_plan_table(..., sharding=QGridSharding(...))``; same bytes
    either way).
    """
    cfg = resolve_config(arch, smoke)
    cm = _default_cost(kind)
    graphs = lower_buckets(cfg, shape_buckets, kind)
    qs = derive_q_grid(graphs, cm, n_q)
    sharding = None
    if n_shards is not None:
        from ..api import QGridSharding
        from .mesh import shard_devices  # jax device state: keep import local

        # shard_devices is None on device-starved hosts (sequential fallback)
        sharding = QGridSharding(n_shards, shard_devices(n_shards))
    return build_plan_table(
        cfg, shape_buckets, qs, kind=kind, cost=cm, cache_dir=cache_dir,
        graphs=graphs, sharding=sharding,
    )


def _parse_buckets(text: str) -> List[Tuple[int, int]]:
    """Parse comma-separated ``BATCHxSEQ`` bucket tokens (e.g. ``2x24,4x48``).

    Each token must be two positive integers joined by an ``x`` (case
    insensitive). Malformed tokens raise a ValueError naming the offending
    entry — previously ``"2x"`` or ``"2x24,48"`` died with an opaque
    "not enough values to unpack".
    """
    out = []
    for part in text.split(","):
        token = part.strip().lower()
        batch_s, sep, seq_s = token.partition("x")
        try:
            if not sep or not batch_s or not seq_s:
                raise ValueError
            bucket = (int(batch_s), int(seq_s))
            if bucket[0] <= 0 or bucket[1] <= 0:
                raise ValueError
        except ValueError:
            raise ValueError(
                f"malformed bucket {part.strip()!r} in {text!r}: expected "
                f"BATCHxSEQ with positive integers (e.g. 2x24)"
            ) from None
        out.append(bucket)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--buckets", default="2x24,2x48",
                    help="comma-separated BATCHxSEQ buckets, e.g. 2x24,4x48")
    ap.add_argument("--q-points", type=int, default=None,
                    help="geometric Q grid size, default 16 (an unbounded "
                    "point is added; fresh builds only)")
    ap.add_argument("--kind", choices=("time", "memory"), default=None,
                    help="cost interpretation, default time (fresh builds "
                    "only — an extension keeps the base table's kind)")
    ap.add_argument("--out", required=True, help="output .npz path")
    ap.add_argument("--full", action="store_true",
                    help="use the full config instead of the smoke config")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard the solve across this many devices "
                    "(byte-identical to the single-host build)")
    ap.add_argument("--extend", action="store_true",
                    help="extend the existing table at --out with any "
                    "missing --buckets instead of rebuilding it")
    ap.add_argument("--probe", type=int, default=0,
                    help="re-validate this many random cells against the "
                    "live engine after the build")
    args = ap.parse_args(argv)

    buckets = _parse_buckets(args.buckets)
    t0 = time.time()
    if args.extend:
        if args.kind is not None or args.q_points is not None:
            ap.error("--kind/--q-points are fixed by the base table; "
                     "not valid with --extend")
        from .dse import extend_for_arch  # lazy: avoids a module cycle

        table = extend_for_arch(
            args.out, args.arch, buckets, smoke=not args.full,
            n_shards=args.shards,
        )
        verb = "extended"
    else:
        table = build_table_for_arch(
            args.arch, buckets, args.q_points or 16, smoke=not args.full,
            kind=args.kind or "time", n_shards=args.shards,
        )
        verb = "built"
    table.save(args.out)
    shard_note = "" if args.shards is None else f" ({args.shards} shards)"
    print(f"[planner] {verb} {table.summary()} in {time.time() - t0:.2f}s"
          f"{shard_note} → {args.out}")
    if args.probe:
        n = probe_plan_table(
            table, resolve_config(args.arch, smoke=not args.full), k=args.probe
        )
        print(f"[planner]   probe: {n} cells re-validated — clean")
    for b, (batch, seq) in enumerate(table.buckets()):
        plan = table.plan_at(b, table.q_index(None))
        print(f"[planner]   {plan.summary()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
