"""Production mesh construction.

Single pod: 256 chips as ("data", "model") = (16, 16).
Multi-pod:  512 chips as ("pod", "data", "model") = (2, 16, 16) — the "pod"
axis is pure data parallelism across ICI-connected pods (gradient all-reduce
crosses the pod axis once per step; everything else stays intra-pod).

Defined as functions so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_shard_mesh",
           "shard_devices"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / examples on CPU."""
    n = len(jax.devices())
    if n >= 2:
        return jax.make_mesh((1, n), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def make_shard_mesh(n_shards: int) -> Mesh:
    """1-axis ("shard",) mesh for the offline DSE sweep (launch/dse.py).

    Uses the first ``n_shards`` local devices — on CI/laptops these are the
    emulated host devices from ``--xla_force_host_platform_device_count``.
    """
    devs = jax.local_devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for a shard mesh, have {len(devs)}"
        )
    return Mesh(np.array(devs[:n_shards]), ("shard",))


def shard_devices(n_shards: int):
    """The DSE shard mesh's devices in shard order, or None when the host
    has fewer than ``n_shards`` (callers then fall back to the sequential
    same-decomposition path)."""
    if len(jax.local_devices()) < n_shards:
        return None
    return list(make_shard_mesh(n_shards).devices.ravel())
