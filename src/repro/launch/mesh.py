"""Production mesh construction.

Single pod: 256 chips as ("data", "model") = (16, 16).
Multi-pod:  512 chips as ("pod", "data", "model") = (2, 16, 16) — the "pod"
axis is pure data parallelism across ICI-connected pods (gradient all-reduce
crosses the pod axis once per step; everything else stays intra-pod).

Defined as functions so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for smoke tests / examples on CPU."""
    n = len(jax.devices())
    if n >= 2:
        return jax.make_mesh((1, n), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))
