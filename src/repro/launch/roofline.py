"""Roofline analysis from compiled artifacts (no real hardware).

Parses the optimized (post-SPMD, scheduled) HLO text into a per-computation
symbol table and derives, **with while-loop trip-count correction** (layer
scans place one set of ops inside a while body — counting them once would
undercount by n_layers):

* ``flops``            — 2 · prod(out) · K for every dot, K resolved from the
                         operand shapes + contracting dims;
* ``bytes``            — HBM-traffic proxy: operand+output bytes of dots,
                         convolutions, explicit data movement (copy, gather,
                         scatter, dynamic-(update-)slice) and collectives.
                         XLA:CPU fuses far less than XLA:TPU, so counting
                         every elementwise line would overstate TPU traffic
                         ~100×; on TPU the elementwise chains fuse into their
                         matmul producers/consumers, making matmul-boundary
                         traffic the dominant term (methodology note in
                         EXPERIMENTS.md §Roofline);
* ``collective bytes`` — operand sizes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute,
                         reconstructed from output shape × replica-group size.

``compiled.cost_analysis()`` is recorded too, but XLA:CPU does not apply trip
counts to while bodies, so the parsed numbers are the §Roofline source of
truth (methodology note in EXPERIMENTS.md).

Hardware constants (assignment): 197 TFLOP/s bf16 per chip; 819 GB/s HBM;
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 4.0  # v5e 2D torus: 4 links/chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose operands/outputs count toward the HBM-traffic proxy.
# "copy" is deliberately absent: XLA:CPU materializes while-carry copies that
# XLA:TPU elides via buffer aliasing — including them would overstate TPU
# traffic severalfold (verified on tinyllama train_4k: copies alone were ~65%
# of all bytes).
_BYTES_OPS = ("dot", "convolution", "dynamic-slice",
              "dynamic-update-slice", "gather", "scatter") + _COLLECTIVES

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1), [int(d) for d in m.group(2).split(",") if d])
            for m in _SHAPE_RE.finditer(type_str)]


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_default: int = 1) -> int:
    # explicit: replica_groups={{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # iota: replica_groups=[G,S]<=[N] (each group has S members)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+", line)
    if m:
        return int(m.group(2))
    return n_default


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count_by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())

    def add(self, other: "HloStats", mult: float = 1.0,
            include_bytes: bool = True) -> None:
        self.flops += other.flops * mult
        if include_bytes:
            self.bytes += other.bytes * mult
        for k, v in other.coll_bytes_by_kind.items():
            self.coll_bytes_by_kind[k] = self.coll_bytes_by_kind.get(k, 0.0) + v * mult
        for k, v in other.coll_count_by_kind.items():
            self.coll_count_by_kind[k] = self.coll_count_by_kind.get(k, 0) + int(v * mult)


class HloAnalyzer:
    """Symbol-table HLO text analyzer with call-graph accumulation."""

    def __init__(self, hlo: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for line in hlo.splitlines():
            if not line.startswith((" ", "\t")):
                m = _HEADER_RE.match(line)
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
                cur = None
                continue
            if cur is not None and "=" in line:
                self.comps[cur].append(line)
        if self.entry is None and self.comps:
            self.entry = list(self.comps)[-1]
        self._memo: Dict[str, HloStats] = {}

    # -- per-line helpers -----------------------------------------------------

    def _symbols(self, comp: str) -> Dict[str, str]:
        """instruction name → result type string (plus parameters)."""
        table: Dict[str, str] = {}
        for line in self.comps.get(comp, []):
            m = _INSTR_RE.match(line)
            if m:
                table[m.group(1)] = m.group(2)
        return table

    def _dot_flops(self, line: str, table: Dict[str, str], out_type: str) -> float:
        ops = _OPERAND_RE.findall(line.split("(", 1)[1])
        if not ops:
            return 0.0
        lhs_t = table.get(ops[0])
        if lhs_t is None:
            return 0.0
        lhs_shapes = _shape_list(lhs_t)
        if not lhs_shapes:
            return 0.0
        lhs_dims = lhs_shapes[0][1]
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if m and m.group(1):
            k = 1
            for d in m.group(1).split(","):
                k *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
        else:
            k = lhs_dims[-1] if lhs_dims else 1
        out = 1
        for _, dims in _shape_list(out_type):
            for d in dims:
                out *= d
            break
        return 2.0 * out * k

    def _line_stats(self, comp: str, line: str, table: Dict[str, str]) -> Tuple[
            HloStats, Optional[Tuple[str, int, bool]]]:
        st = HloStats()
        call: Optional[Tuple[str, int, bool]] = None
        m = _INSTR_RE.match(line)
        if not m:
            return st, call
        _, out_type, opcode = m.groups()
        out_b = _bytes_of(out_type)
        in_b = 0
        op_names = _OPERAND_RE.findall(line.split("(", 1)[1].split(")", 1)[0]) \
            if "(" in line else []
        for o in op_names:
            t = table.get(o)
            if t:
                in_b += _bytes_of(t)
        if opcode in _BYTES_OPS:
            if opcode in ("dynamic-slice", "gather"):
                # reads only the sliced region (≈ output), not the operand
                st.bytes += 2 * out_b
            elif opcode in ("dynamic-update-slice", "scatter"):
                # reads + writes the update region only (in-place on TPU)
                upd = table.get(op_names[1]) if len(op_names) > 1 else None
                st.bytes += 2 * (_bytes_of(upd) if upd else out_b)
            else:
                st.bytes += out_b + in_b
        if opcode == "dot":
            st.flops += self._dot_flops(line, table, out_type)
        if opcode in _COLLECTIVES:
            g = _group_size(line)
            if opcode == "all-gather":
                b = out_b / max(g, 1)
            elif opcode == "reduce-scatter":
                b = out_b * g
            else:  # all-reduce, all-to-all, collective-permute
                b = out_b
            st.coll_bytes_by_kind[opcode] = st.coll_bytes_by_kind.get(opcode, 0.0) + b
            st.coll_count_by_kind[opcode] = st.coll_count_by_kind.get(opcode, 0) + 1
        # call edges. Two kinds:
        #  - "control" (while / call / conditional): the child is real code
        #    executing from HBM-resident buffers → include its bytes.
        #  - "apply" (fusion / reduce / map / ...): the child describes the
        #    fused computation whose intermediates live in registers/VMEM →
        #    include only its FLOPs (dots inside fusions) and collectives,
        #    NOT its bytes; the call site's operand/output bytes already
        #    account for the HBM traffic.
        wm = re.search(r"\bwhile\(", line)
        if wm:
            cm = re.search(r"condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)", line)
            if cm:
                trips = self._trip_count(line, cm.group(1))
                call = (cm.group(2), trips, True)
        else:
            cm = re.search(r"\bcall\(.*?to_apply=%?([\w\.\-]+)", line)
            if cm:
                call = (cm.group(1), 1, True)
            else:
                cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
                if cm and opcode in ("fusion", "call", "custom-call", "reduce",
                                     "map", "sort", "scatter", "select-and-scatter"):
                    call = (cm.group(1), 1, opcode == "call")
        return st, call

    def _trip_count(self, line: str, cond: str) -> int:
        m = _TRIP_RE.search(line)
        if m:
            return int(m.group(1))
        consts = []
        for l in self.comps.get(cond, []):
            for mm in re.finditer(r"constant\((\d+)\)", l):
                consts.append(int(mm.group(1)))
        return max(consts) if consts else 1

    # -- accumulation ----------------------------------------------------------

    def stats_of(self, comp: str, _stack: Tuple[str, ...] = ()) -> HloStats:
        if comp in self._memo:
            return self._memo[comp]
        total = HloStats()
        if comp in _stack or comp not in self.comps:
            return total
        table = self._symbols(comp)
        for line in self.comps[comp]:
            st, call = self._line_stats(comp, line, table)
            total.add(st)
            if call is not None:
                child, mult, include_bytes = call
                total.add(self.stats_of(child, _stack + (comp,)), mult,
                          include_bytes=include_bytes)
        self._memo[comp] = total
        return total

    def entry_stats(self) -> HloStats:
        return self.stats_of(self.entry) if self.entry else HloStats()


def analyze_hlo(hlo: str) -> HloStats:
    return HloAnalyzer(hlo).entry_stats()


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float):
    """The three roofline times (seconds) for one step, per chip."""
    return {
        "t_compute": flops_per_chip / PEAK_FLOPS,
        "t_memory": bytes_per_chip / HBM_BW,
        "t_collective": coll_bytes_per_chip / (ICI_BW * ICI_LINKS),
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("t_compute", "t_memory", "t_collective"), key=lambda k: terms[k])
