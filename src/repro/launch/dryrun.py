import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

The two lines above MUST run before any jax import — jax locks the device
count at first initialization. Everything else (smoke tests, benches) sees
the real single CPU device because only this module sets the flag.

Per cell we record:
* ``compiled.memory_analysis()``  — bytes per device (proves it fits)
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
* collective bytes by kind        — parsed from the optimized HLO, with
  while-loop trip-count correction (launch/roofline.py)

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs.base import REGISTRY, SHAPES, get_config, shape_applicable
from .mesh import make_production_mesh
from .roofline import analyze_hlo, dominant_term, roofline_terms
from .steps import build_cell

# ensure all arch modules registered
from .. import configs as _configs  # noqa: F401


def _mem_stats(compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    out[k] = int(v)
    except Exception as e:  # backend may not support it
        out["error"] = repr(e)
    return out


def _cost_stats(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:
        return {"error_msg": 0.0}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: Optional[str] = None, remat: bool = True,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "family": cfg.family,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    try:
        cell = build_cell(cfg, shape, mesh, remat=remat)
        lowered = cell.lower()
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

        mem = _mem_stats(compiled)
        cost = _cost_stats(compiled)
        hlo = compiled.as_text()
        stats = analyze_hlo(hlo)
        if save_hlo:
            with open(save_hlo, "w") as fh:
                fh.write(hlo)

        # Trip-count-corrected per-device numbers from the parsed HLO
        # (XLA:CPU cost_analysis counts while bodies once — recorded for
        # reference but not used for the roofline).
        flops_dev = stats.flops
        bytes_dev = stats.bytes
        coll_dev = stats.coll_bytes

        terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
        model_flops = 6 * cfg.active_param_count() * shape.seq_len * shape.global_batch
        if shape.kind == "decode":
            model_flops = 6 * cfg.active_param_count() * shape.global_batch  # 1 token

        rec.update({
            "status": "ok",
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "n_chips": n_chips,
            "memory": mem,
            "cost_analysis": {k: v for k, v in sorted(cost.items())
                              if k in ("flops", "bytes accessed", "transcendentals")},
            "collective_bytes_by_kind": stats.coll_bytes_by_kind,
            "collective_count_by_kind": stats.coll_count_by_kind,
            "collective_bytes_total": coll_dev,
            "roofline": terms,
            "dominant": dominant_term(terms),
            "model_flops_global": model_flops,
            "useful_flops_ratio": (model_flops / (flops_dev * n_chips)
                                   if flops_dev else None),
        })
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
                  f"compile {t_compile:.1f}s  dominant={rec['dominant']}")
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis: flops={flops_dev:.3g} "
                  f"bytes={bytes_dev:.3g} coll={coll_dev:.3g}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: FAILED {rec['error']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--out", default=None, help="directory for JSON records")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(REGISTRY)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    if args.out:
        os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                rec = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                               remat=not args.no_remat)
                if rec["status"] == "error":
                    failures += 1
                if args.out:
                    fn = f"{arch}_{shape}_{rec['mesh']}.json".replace("/", "-")
                    with open(os.path.join(args.out, fn), "w") as fh:
                        json.dump(rec, fh, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
