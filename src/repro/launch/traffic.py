"""Continuous-traffic serving harness: async request queue, energy-budget
admission control, and continuation batching over the plan table.

This is the fleet-scale face of the serving path. PR 3 proved the paper's
energy-bounded execution for *one* request; this module sustains a stream:

* **Arrival processes** — deterministic fixed-interval, Poisson-like
  (seeded-PRNG exponential gaps), or replay-from-trace (JSON records) —
  produce :class:`Request` objects with virtual arrival timestamps that feed
  an ``asyncio.Queue`` (the request queue) through a virtual-clock-driven
  producer coroutine.

* **Admission control** checks each request's *tabulated* energy (looked up
  O(1) from the :class:`~repro.launch.planner.ServePlanner` plan table — no
  DP solve on the admission path) against the remaining harvest budget
  (:class:`HarvestModel`): requests that can never fit are **rejected**,
  requests that outstrip the current charge are **deferred** to a FIFO queue
  and retried as the budget replenishes, and admitted requests *reserve*
  their whole tabulated draw up front. The harvest pool models energy
  *income over time*; the per-cycle buffer Q (``cycle_budget``) that bounds
  any single burst is a separate, smaller quantity — exactly the paper's
  E_burst — used to split each request into committed cycles.

* **Continuation batching**: an admitted request opens as a
  :class:`Continuation` — a steppable :class:`~repro.core.runtime.BurstRuntime`
  whose cycles commit one at a time. The scheduler drains one shape bucket's
  continuations at a time (round-robin *within* the bucket, FIFO *across*
  buckets), so consecutive cycles — even from different requests — hit the
  same cached jitted prefill/decode executables
  (:func:`repro.launch.serve._step_fns`): zero retraces after warmup, pinned
  by the ``TRACE_COUNT`` snapshot the report carries. A mid-cycle
  :class:`~repro.core.runtime.PowerFailure` leaves the continuation queued
  with its committed index intact; the next visit replays the cycle.

Time is two-track: the *virtual* clock drives arrivals and energy
replenishment (deterministic under a fixed seed — the tests pin admission /
deferral ordering exactly), while wall-clock timestamps feed the serving
metrics (sustained requests/sec, p50/p95/p99 latency) reported by
:class:`TrafficReport` and the ``serving_traffic`` benchmark section.

CLI (smoke-checkable, used by CI)::

    python -m repro.launch.planner --arch qwen3-4b --buckets 2x16 --out plan.npz
    python -m repro.launch.traffic --arch qwen3-4b --plan-table plan.npz \\
        --arrivals poisson --rate 2.0 --n 12 --shapes 2x8x8 \\
        --capacity-requests 1.5 --rate-requests 0.4 \\
        --expect-admitted 1 --expect-deferred 1 --expect-zero-retrace
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import heapq
import json
import random
import sys
import time
from collections import deque
from typing import (
    Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple,
)

import numpy as np

from ..core.partition import BUDGET_ABS, BUDGET_REL, within_budget
from ..core.runtime import COMMIT_STATS, PowerFailure
from ..obs.ledger import EnergyLedger
from ..obs.log import enable_cli_output, get_emitter
from ..obs.metrics import METRICS
from ..obs.trace import (
    PID_RUNTIME,
    PID_SOLVER,
    PID_TRAFFIC,
    TID_HARVEST,
    TID_SCHEDULER,
    TRACER,
    request_tid,
)

# Structured progress reporting: silent under pytest / library use (no
# handler), "[traffic] ..." on stdout under the CLI (enable_cli_output).
_LOG = get_emitter("repro.traffic")

__all__ = [
    "Request",
    "Continuation",
    "HarvestModel",
    "TrafficReport",
    "TrafficHarness",
    "deterministic_arrivals",
    "poisson_arrivals",
    "trace_arrivals",
    "load_trace",
    "main",
]


# ---------------------------------------------------------------------------
# Requests and arrival processes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: a shape plus its virtual arrival time."""

    rid: int
    batch: int
    prompt_len: int
    gen: int
    time: float = 0.0
    seed: int = 0

    @property
    def max_seq(self) -> int:
        return self.prompt_len + self.gen

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.batch, self.prompt_len, self.gen)


def deterministic_arrivals(
    n: int,
    interval: float,
    shape: Tuple[int, int, int],
    *,
    start: float = 0.0,
    seed: int = 0,
) -> List[Request]:
    """``n`` identical-shape requests, one every ``interval`` virtual time
    units. All requests share ``seed`` (one model, one prompt set) so the
    whole stream reuses a single cached executable + params entry."""
    batch, prompt_len, gen = shape
    return [
        Request(rid=i, batch=batch, prompt_len=prompt_len, gen=gen,
                time=start + i * interval, seed=seed)
        for i in range(n)
    ]


def poisson_arrivals(
    n: int,
    rate: float,
    shapes: Sequence[Tuple[int, int, int]],
    *,
    seed: int = 0,
    start: float = 0.0,
    request_seed: int = 0,
) -> List[Request]:
    """Poisson-like arrivals: exponential inter-arrival gaps at ``rate``
    requests per unit virtual time from a seeded PRNG, shapes drawn
    uniformly from ``shapes``. Deterministic for a fixed ``seed``."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    rng = random.Random(seed)
    t = start
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        batch, prompt_len, gen = shapes[rng.randrange(len(shapes))]
        out.append(Request(rid=i, batch=batch, prompt_len=prompt_len,
                           gen=gen, time=t, seed=request_seed))
    return out


def trace_arrivals(records: Iterable) -> List[Request]:
    """Replay-from-trace: records are dicts with ``time``/``batch``/
    ``prompt_len``/``gen`` (optional ``rid``/``seed``), tuples
    ``(time, batch, prompt_len, gen[, seed])``, or ready Requests."""
    out: List[Request] = []
    for i, rec in enumerate(records):
        if isinstance(rec, Request):
            out.append(rec)
        elif isinstance(rec, dict):
            out.append(Request(
                rid=int(rec.get("rid", i)), batch=int(rec["batch"]),
                prompt_len=int(rec["prompt_len"]), gen=int(rec["gen"]),
                time=float(rec.get("time", i)), seed=int(rec.get("seed", 0)),
            ))
        else:
            t, batch, prompt_len, gen = rec[:4]
            seed = int(rec[4]) if len(rec) > 4 else 0
            out.append(Request(rid=i, batch=int(batch),
                               prompt_len=int(prompt_len), gen=int(gen),
                               time=float(t), seed=seed))
    return sorted(out, key=lambda r: (r.time, r.rid))


def load_trace(path: str) -> List[Request]:
    """Load a JSON arrival trace (a list of record dicts / tuples)."""
    with open(path) as fh:
        return trace_arrivals(json.load(fh))


# ---------------------------------------------------------------------------
# Continuations: the schedulable unit
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Continuation:
    """An admitted request opened as a steppable BurstRuntime.

    ``scope`` (a context-manager factory, e.g. the host mesh) wraps every
    step so the cached jitted executables hit their compile cache; the
    synthetic executors the fast tests use leave it None.
    """

    request: Request
    plan: Any  # SegmentPlan
    cycles: List[Tuple[int, int]]
    runtime: Any  # BurstRuntime
    e_startup: float
    output: str = "sequence"
    scope: Optional[Callable[[], Any]] = None

    @property
    def bucket_key(self) -> Tuple[int, int]:
        return (self.plan.batch, self.plan.seq_bucket)

    @property
    def n_cycles(self) -> int:
        return len(self.cycles)

    @property
    def cycles_done(self) -> int:
        return int(self.runtime.nvm.read_index())

    @property
    def done(self) -> bool:
        return self.cycles_done >= self.n_cycles

    def cycle_cost(self, c: int) -> float:
        """Modeled energy of cycle ``c``: E_s + its token steps."""
        i, j = self.cycles[c]
        return self.e_startup + (j - i + 1) * self.plan.e_total

    @property
    def total_cost(self) -> float:
        """The whole request's tabulated draw (what admission reserves)."""
        return sum(self.cycle_cost(c) for c in range(self.n_cycles))

    def step(self) -> bool:
        """Commit one cycle; True when the request is complete. May raise
        PowerFailure (the committed index survives — re-step to replay)."""
        if self.scope is None:
            return self.runtime.step()
        with self.scope():
            return self.runtime.step()

    def run_to_completion(self, max_activations: int = 10 ** 6):
        """Drive :meth:`step` to completion, riding through injected power
        failures (the single-request path `_serve_planned` uses)."""
        for _ in range(max_activations):
            try:
                while not self.step():
                    pass
                return self.tokens()
            except PowerFailure:
                continue
        raise RuntimeError("did not complete within max_activations")

    def tokens(self):
        return self.runtime.outputs()[self.output]


def request_energy(
    plan, gen: int, cycle_budget: Optional[float], e_startup: float
) -> Tuple[List[Tuple[int, int]], float]:
    """Tabulated cycles + total draw for a request, without opening it.

    This is the admission-path counterpart of opening a Continuation: an
    O(gen) grouping over the looked-up plan — no solver, no graph lowering —
    so rejected/deferred requests never pay params/graph setup.
    """
    from .planner import request_cycles  # lazy: avoid import cycle at load

    cycles = request_cycles(gen, plan.e_total, cycle_budget,
                            e_startup=e_startup)
    total = sum(e_startup + (j - i + 1) * plan.e_total for (i, j) in cycles)
    return cycles, total


# ---------------------------------------------------------------------------
# Harvest budget
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HarvestModel:
    """Energy-harvesting admission budget: a storage pool replenished at
    ``rate`` (energy per unit *virtual* time), capped at ``capacity``.

    Admission *reserves* a request's whole tabulated energy up front
    (``draw``); deferral waits for replenishment; rejection is for requests
    that can never fit — ``e_req > capacity``, or ``rate == 0`` with
    ``e_req`` above the current charge. ``capacity=float('inf')`` disables
    admission control (everything fits immediately).

    Distinct from the per-cycle buffer Q: the pool bounds how much total
    work is admitted per unit time (income), Q bounds any single burst.
    """

    capacity: float
    rate: float = 0.0
    charge: Optional[float] = None

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(f"capacity must be positive, got {self.capacity}")
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        if self.charge is None:
            self.charge = self.capacity
        self.charge = min(float(self.charge), float(self.capacity))
        self.harvested = 0.0
        self.spent = 0.0

    def replenish(self, dt: float) -> None:
        """Advance virtual time by ``dt``: harvest ``rate * dt``, capped."""
        if dt <= 0 or self.rate == 0 or not np.isfinite(self.capacity):
            return
        add = min(self.rate * dt, self.capacity - self.charge)
        if add > 0:
            self.charge += add
            self.harvested += add

    def fits(self, energy: float) -> bool:
        """Does ``energy`` fit the *current* charge (solver tolerance)?"""
        return within_budget(energy, self.charge)

    def can_ever_fit(self, energy: float) -> bool:
        """Could ``energy`` ever fit, given replenishment?"""
        if not within_budget(energy, self.capacity):
            return False
        return self.rate > 0 or self.fits(energy)

    def draw(self, energy: float) -> None:
        """Reserve an admitted request's tabulated draw."""
        self.charge -= energy
        self.spent += energy

    def time_until(self, energy: float) -> float:
        """Virtual time until ``energy`` fits (0 if it already does)."""
        if self.fits(energy):
            return 0.0
        if self.rate <= 0 or not within_budget(energy, self.capacity):
            return float("inf")
        return (energy - self.charge) / self.rate



# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrafficReport:
    """What one harness run observed (all counters are per-run deltas)."""

    arrived: int = 0
    admitted: int = 0
    deferred: int = 0    # requests deferred at least once
    rejected: int = 0
    completed: int = 0
    cycles_run: int = 0
    power_failures: int = 0
    executable_switches: int = 0  # bucket-key changes between cycles
    reject_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)
    events: List[Tuple[float, str, int]] = dataclasses.field(
        default_factory=list)  # (virtual time, event, rid)
    latency_wall_s: List[float] = dataclasses.field(default_factory=list)
    latency_virtual: List[float] = dataclasses.field(default_factory=list)
    wall_seconds: float = 0.0
    virtual_makespan: float = 0.0
    trace_delta: Dict[str, int] = dataclasses.field(default_factory=dict)
    commit_delta: Dict[str, int] = dataclasses.field(default_factory=dict)
    planner_delta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    hit_rate: float = 0.0
    energy_spent: float = 0.0
    energy_harvested: float = 0.0
    final_charge: float = 0.0
    tokens: Dict[int, Any] = dataclasses.field(default_factory=dict)
    # Energy-ledger attribution (repro.obs.ledger): restore/compute/commit
    # charged against the admission reservation, replay as overhead on top.
    energy_ledger: Dict[str, float] = dataclasses.field(default_factory=dict)
    ledger_conserved: Optional[bool] = None
    ledger_conservation_error: float = 0.0
    ledger_overhead_fraction: float = 0.0
    ledger: Optional[Any] = dataclasses.field(default=None, repr=False)

    @property
    def requests_per_s(self) -> float:
        return self.completed / self.wall_seconds if self.wall_seconds else 0.0

    def latency_percentiles_ms(self) -> Dict[str, float]:
        if not self.latency_wall_s:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        lat = np.asarray(self.latency_wall_s) * 1e3
        return {
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
        }

    @property
    def retraces(self) -> int:
        return sum(self.trace_delta.values())

    def summary(self) -> str:
        pct = self.latency_percentiles_ms()
        return (
            f"{self.completed}/{self.arrived} completed "
            f"({self.admitted} admitted, {self.deferred} deferred, "
            f"{self.rejected} rejected) | "
            f"{self.requests_per_s:.1f} req/s, "
            f"p50/p95/p99 {pct['p50']:.1f}/{pct['p95']:.1f}/"
            f"{pct['p99']:.1f} ms | "
            f"{self.cycles_run} cycles ({self.power_failures} power "
            f"failures, {self.commit_delta.get('replays', 0)} replays) | "
            f"plan-cache hit rate {self.hit_rate:.3f} | "
            f"retraces {self.retraces} | "
            f"energy {self.energy_spent:.4g} spent "
            f"(replay overhead {self.ledger_overhead_fraction:.2%}, "
            f"ledger {'conserved' if self.ledger_conserved else 'IMBALANCED'})"
        )


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------


class _VirtualClock:
    """Deterministic virtual time shared by the producer (arrivals) and the
    scheduler: coroutines ``wait_until`` a timestamp, the scheduler
    ``advance_to`` the next event and yields so due waiters run."""

    def __init__(self) -> None:
        self.now = 0.0
        self._waiters: List[Tuple[float, int, asyncio.Future]] = []
        self._n = 0

    async def wait_until(self, t: float) -> None:
        if t <= self.now:
            return
        fut = asyncio.get_running_loop().create_future()
        self._n += 1
        heapq.heappush(self._waiters, (t, self._n, fut))
        await fut

    def next_wakeup(self) -> Optional[float]:
        return self._waiters[0][0] if self._waiters else None

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)
        while self._waiters and self._waiters[0][0] <= self.now:
            _, _, fut = heapq.heappop(self._waiters)
            if not fut.done():
                fut.set_result(None)


@dataclasses.dataclass
class _Pending:
    """A request between lookup and admission (possibly deferred)."""

    request: Request
    plan: Any
    cycles: List[Tuple[int, int]]
    energy: float
    arrive_wall: float


class TrafficHarness:
    """Drives an executor (``repro.launch.serve.PlannedExecutor`` in
    production, synthetic ones in the fast tests) under continuous traffic.

    The executor contract: ``.planner`` (a ServePlanner), and
    ``.open(batch, prompt_len, gen, *, seed, cycle_budget, plan, nvm,
    crash_hook) -> Continuation``. Optionally ``.warmup(shapes)`` to
    pre-compile executables outside the measured run.
    """

    def __init__(
        self,
        executor,
        *,
        harvest: Optional[HarvestModel] = None,
        cycle_budget: Optional[float] = None,
        service_time: float = 1.0,
        max_wait: Optional[float] = None,
        keep_tokens: bool = False,
        crash_hook_factory: Optional[Callable[[Request], Any]] = None,
        nvm_factory: Optional[Callable[[Request], Any]] = None,
    ) -> None:
        self.executor = executor
        self.planner = executor.planner
        self.harvest = harvest if harvest is not None else HarvestModel(
            capacity=float("inf"))
        self.cycle_budget = cycle_budget
        if service_time <= 0:
            raise ValueError("service_time must be positive")
        self.service_time = service_time
        self.max_wait = max_wait
        self.keep_tokens = keep_tokens
        self.crash_hook_factory = crash_hook_factory
        self.nvm_factory = nvm_factory

    # -- warmup ------------------------------------------------------------

    def warmup(self, requests: Sequence[Request]) -> int:
        """Run one throwaway request per distinct shape so compiles happen
        outside the measured window; returns the number of shapes warmed.
        Uses each shape's first-seen seed so the warmed params entry is the
        one the run will reuse."""
        warm = getattr(self.executor, "warmup", None)
        shapes: Dict[Tuple[int, int, int], int] = {}
        for r in sorted(requests, key=lambda r: (r.time, r.rid)):
            shapes.setdefault(r.shape, r.seed)
        if warm is None:
            return 0
        warm([(b, p, g, s) for (b, p, g), s in shapes.items()],
             cycle_budget=self.cycle_budget)
        return len(shapes)

    # -- the run -----------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> TrafficReport:
        """Serve an arrival schedule to drain; returns the run's report."""
        return asyncio.run(self._run_async(list(requests)))

    async def _feed(self, requests: List[Request], clock: _VirtualClock,
                    queue: "asyncio.Queue[Request]") -> None:
        # The producer side of the async request queue: park until the
        # virtual clock reaches each arrival, then enqueue.
        for r in sorted(requests, key=lambda r: (r.time, r.rid)):
            await clock.wait_until(r.time)
            queue.put_nowait(r)
        self._feed_done = True

    async def _run_async(self, requests: List[Request]) -> TrafficReport:
        report = TrafficReport()
        ledger = EnergyLedger()
        report.ledger = ledger
        self._feed_done = not requests
        clock = _VirtualClock()
        if TRACER.enabled:
            TRACER.set_process(PID_TRAFFIC, "traffic")
            TRACER.set_thread(PID_TRAFFIC, TID_SCHEDULER, "scheduler")
            TRACER.set_thread(PID_TRAFFIC, TID_HARVEST, "harvest")
            TRACER.set_process(PID_SOLVER, "solver/plan-table")
            TRACER.set_process(PID_RUNTIME, "burst runtime")
        queue: "asyncio.Queue[Request]" = asyncio.Queue()
        deferred: deque[_Pending] = deque()
        ever_deferred: set = set()
        groups: Dict[Tuple[int, int], deque] = {}
        group_order: List[Tuple[int, int]] = []
        open_meta: Dict[int, _Pending] = {}
        last_key: Optional[Tuple[int, int]] = None

        trace0 = self._trace_snapshot()
        commit0 = dict(COMMIT_STATS)
        planner0 = self._planner_snapshot()
        charge0 = self.harvest.charge
        harvested0, spent0 = self.harvest.harvested, self.harvest.spent
        wall0 = time.perf_counter()

        def event(kind: str, rid: int) -> None:
            report.events.append((clock.now, kind, rid))
            if TRACER.enabled:
                # each request gets its own Perfetto track; lifecycle events
                # land on it as instants carrying the virtual timestamp
                TRACER.set_thread(PID_TRAFFIC, request_tid(rid), f"request {rid}")
                TRACER.instant(
                    kind, cat="traffic", tid=request_tid(rid), rid=rid, vt=clock.now
                )

        def sample_harvest() -> None:
            if TRACER.enabled and np.isfinite(self.harvest.charge):
                TRACER.counter(
                    "harvest_charge", {"charge": self.harvest.charge},
                    tid=TID_HARVEST,
                )

        def reject(pend: _Pending, reason: str) -> None:
            report.rejected += 1
            report.reject_reasons[reason] = (
                report.reject_reasons.get(reason, 0) + 1)
            self._record_admission("rejected")
            event(f"reject:{reason}", pend.request.rid)

        def open_admitted(pend: _Pending) -> None:
            r = pend.request
            self.harvest.draw(pend.energy)
            sample_harvest()
            cont = self.executor.open(
                r.batch, r.prompt_len, r.gen, seed=r.seed,
                cycle_budget=self.cycle_budget, plan=pend.plan,
                nvm=self.nvm_factory(r) if self.nvm_factory else None,
                crash_hook=(self.crash_hook_factory(r)
                            if self.crash_hook_factory else None),
            )
            # the harness's request (rid, arrival time) is authoritative —
            # executors mint their own rids for standalone use
            cont.request = r
            key = cont.bucket_key
            if key not in groups:
                groups[key] = deque()
                group_order.append(key)
            groups[key].append(cont)
            open_meta[r.rid] = pend
            report.admitted += 1
            self._record_admission("admitted")
            event("admit", r.rid)

        def try_admit(pend: _Pending, *, arriving: bool) -> bool:
            """Admit/defer/reject one pending request; True if consumed
            (admitted or rejected), False if it should stay deferred."""
            r = pend.request
            if not self.harvest.can_ever_fit(pend.energy):
                reason = ("over_capacity"
                          if not within_budget(pend.energy,
                                               self.harvest.capacity)
                          else "no_replenishment")
                reject(pend, reason)
                return True
            if (self.max_wait is not None
                    and clock.now - r.time > self.max_wait + 1e-12):
                reject(pend, "max_wait")
                return True
            if self.harvest.fits(pend.energy):
                open_admitted(pend)
                return True
            if arriving:
                deferred.append(pend)
                if r.rid not in ever_deferred:
                    ever_deferred.add(r.rid)
                    report.deferred += 1
                    self._record_admission("deferred")
                event("defer", r.rid)
            return False

        def on_arrival(r: Request) -> None:
            report.arrived += 1
            event("arrive", r.rid)
            try:
                plan = self.planner.plan_for(r.batch, r.max_seq,
                                             self.cycle_budget)
            except Exception as e:  # UnknownBucketError / Infeasible
                pend = _Pending(r, None, [], 0.0, time.perf_counter())
                reject(pend, type(e).__name__)
                return
            cycles, energy = request_energy(
                plan, r.gen, self.cycle_budget, self.planner.e_startup)
            pend = _Pending(r, plan, cycles, energy, time.perf_counter())
            # FIFO fairness: while older requests wait for energy, newcomers
            # join the back of the deferral queue only if they don't fit the
            # *remaining* charge — cheap requests may overtake (documented,
            # pinned by the ordering tests).
            try_admit(pend, arriving=True)

        def retry_deferred() -> None:
            # deferred requests get first claim on replenished energy, FIFO
            while deferred:
                pend = deferred[0]
                consumed = try_admit(pend, arriving=False)
                if consumed:
                    deferred.popleft()
                    continue
                break  # head still waiting: preserve FIFO order

        def next_cycle() -> Optional[Continuation]:
            # continuation batching: drain the oldest bucket group before
            # switching executables; round-robin inside the group
            while group_order:
                key = group_order[0]
                grp = groups[key]
                if grp:
                    return grp[0]
                del groups[key]
                group_order.pop(0)
            return None

        def execute(cont: Continuation) -> None:
            nonlocal last_key
            rid = cont.request.rid
            c = cont.cycles_done  # index of the cycle this visit will run
            if last_key is not None and cont.bucket_key != last_key:
                report.executable_switches += 1
                if TRACER.enabled:
                    TRACER.instant(
                        "executable_switch", cat="traffic", tid=TID_SCHEDULER,
                        bucket=str(cont.bucket_key), vt=clock.now,
                    )
            last_key = cont.bucket_key
            grp = groups[cont.bucket_key]
            try:
                if TRACER.enabled:
                    with TRACER.span(
                        "cycle", cat="traffic", tid=request_tid(rid),
                        rid=rid, cycle=c, vt=clock.now,
                    ):
                        done = cont.step()
                else:
                    done = cont.step()
            except PowerFailure:
                report.power_failures += 1
                # the crashed attempt's energy was never reserved by
                # admission: book it as replay overhead, not a charge
                ledger.overhead(rid, c, cont.cycle_cost(c), vt=clock.now)
                event("power_failure", rid)
                return  # committed index intact; replay on the next visit
            report.cycles_run += 1
            restore, compute, commit = self._attribute_cycle(cont, c)
            ledger.charge(
                rid, c, restore=restore, compute=compute, commit=commit,
                vt=clock.now,
            )
            if done:
                grp.popleft()
                pend = open_meta.pop(cont.request.rid)
                report.completed += 1
                report.latency_wall_s.append(
                    time.perf_counter() - pend.arrive_wall)
                report.latency_virtual.append(
                    clock.now + self.service_time - cont.request.time)
                if self.keep_tokens:
                    report.tokens[cont.request.rid] = np.asarray(
                        cont.tokens())
                event("complete", cont.request.rid)
            else:
                grp.rotate(-1)  # round-robin within the bucket

        feeder = asyncio.ensure_future(self._feed(requests, clock, queue))
        try:
            while True:
                await asyncio.sleep(0)  # let the feeder flush due arrivals
                while not queue.empty():
                    on_arrival(queue.get_nowait())
                retry_deferred()
                cont = next_cycle()
                if cont is not None:
                    execute(cont)
                    dt = self.service_time
                    self.harvest.replenish(dt)
                    sample_harvest()
                    clock.advance_to(clock.now + dt)
                    continue
                # idle: jump to the next event (arrival / deferred-ready /
                # max-wait expiry), harvesting along the way
                horizons: List[float] = []
                nxt = clock.next_wakeup()
                if nxt is not None:
                    horizons.append(nxt)
                for pend in deferred:
                    wait = self.harvest.time_until(pend.energy)
                    if np.isfinite(wait):
                        horizons.append(clock.now + max(wait, 0.0))
                    if self.max_wait is not None:
                        horizons.append(pend.request.time + self.max_wait
                                        + 2e-12)
                if not horizons:
                    if (self._feed_done and queue.empty() and not deferred
                            and not any(groups.values())):
                        break
                    # feeder has items not yet due but no waiter registered
                    # yet: yield and re-check
                    continue
                t = min(horizons)
                self.harvest.replenish(t - clock.now)
                sample_harvest()
                clock.advance_to(t)
        finally:
            feeder.cancel()

        report.wall_seconds = time.perf_counter() - wall0
        report.virtual_makespan = clock.now
        report.trace_delta = self._trace_delta(trace0)
        report.commit_delta = {
            k: COMMIT_STATS[k] - commit0[k] for k in commit0}
        report.planner_delta = self._planner_delta(planner0)
        lk = report.planner_delta.get("lookups", 0)
        report.hit_rate = (
            report.planner_delta.get("hits", 0) / lk if lk else 0.0)
        report.energy_spent = self.harvest.spent - spent0
        report.energy_harvested = self.harvest.harvested - harvested0
        report.final_charge = self.harvest.charge
        if not np.isfinite(report.final_charge):
            report.final_charge = float("inf")
        _ = charge0  # baseline kept for debugging hooks
        # Energy-ledger closure: every admitted request drained, so the
        # charged categories must reproduce the pool delta exactly (at
        # solver tolerance); replay overhead sits outside the reservation.
        report.energy_ledger = ledger.by_category()
        report.ledger_overhead_fraction = ledger.overhead_fraction()
        report.ledger_conservation_error = ledger.conservation_error(
            report.energy_spent)
        report.ledger_conserved = ledger.conserves(report.energy_spent)
        return report

    @staticmethod
    def _attribute_cycle(cont: Continuation, c: int) -> Tuple[float, float, float]:
        """Split cycle ``c``'s tabulated cost into (restore, compute, commit).

        Preferred source is the runtime partition's own
        :class:`~repro.core.burst.BurstDetail` — it separates E_s, task
        energy, and NVM transfer traffic — but only when its total agrees
        with the admission-path :meth:`Continuation.cycle_cost` (the quantity
        the harvest pool actually drew), so ledger conservation holds by
        construction. Executors whose runtime prices cycles differently fall
        back to the admission decomposition with commit folded into zero.
        """
        total = cont.cycle_cost(c)
        try:
            d = cont.runtime.partition.bursts[c]
        except Exception:
            d = None
        if d is not None:
            dt = float(d.total)
            if abs(dt - total) <= max(abs(dt), abs(total)) * BUDGET_REL + BUDGET_ABS:
                return float(d.e_startup), float(d.e_task), float(d.e_read + d.e_write)
        return float(cont.e_startup), float(total - cont.e_startup), 0.0

    # -- snapshots (diffs, never absolutes) --------------------------------

    @staticmethod
    def _trace_snapshot() -> Dict[str, int]:
        serve = sys.modules.get("repro.launch.serve")
        return dict(serve.TRACE_COUNT) if serve is not None else {}

    @classmethod
    def _trace_delta(cls, before: Dict[str, int]) -> Dict[str, int]:
        now = cls._trace_snapshot()
        return {k: now.get(k, 0) - before.get(k, 0)
                for k in set(before) | set(now)}

    def _planner_snapshot(self) -> Dict[str, Any]:
        stats = getattr(self.planner, "stats", {})
        out = {k: v for k, v in stats.items() if isinstance(v, int)}
        out["by_bucket"] = dict(stats.get("by_bucket", {}))
        return out

    def _planner_delta(self, before: Dict[str, Any]) -> Dict[str, Any]:
        now = self._planner_snapshot()
        delta = {k: now.get(k, 0) - before.get(k, 0)
                 for k in now if k != "by_bucket"}
        by0 = before.get("by_bucket", {})
        delta["by_bucket"] = {
            k: v - by0.get(k, 0)
            for k, v in now.get("by_bucket", {}).items()
            if v - by0.get(k, 0)
        }
        return delta

    def _record_admission(self, outcome: str) -> None:
        rec = getattr(self.planner, "record_admission", None)
        if rec is not None:
            rec(outcome)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _parse_shapes(text: str) -> List[Tuple[int, int, int]]:
    """Comma-separated BATCHxPROMPTxGEN request shapes (e.g. 2x8x8)."""
    out = []
    for part in text.split(","):
        bits = part.strip().lower().split("x")
        try:
            if len(bits) != 3:
                raise ValueError
            shape = tuple(int(b) for b in bits)
            if any(v <= 0 for v in shape):
                raise ValueError
        except ValueError:
            raise ValueError(
                f"malformed shape {part.strip()!r} in {text!r}: expected "
                f"BATCHxPROMPTxGEN with positive integers (e.g. 2x8x8)"
            ) from None
        out.append(shape)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--plan-table", default=None,
                    help="precomputed PlanTable (.npz); omit with --build")
    ap.add_argument("--build", action="store_true",
                    help="build a plan table in-process from --shapes "
                         "instead of loading --plan-table")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arrivals", choices=("deterministic", "poisson",
                                           "trace"), default="deterministic")
    ap.add_argument("--n", type=int, default=8, help="number of requests")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="deterministic: virtual gap between arrivals")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="poisson: arrivals per unit virtual time")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shapes", default="2x8x8",
                    help="comma-separated BATCHxPROMPTxGEN request shapes")
    ap.add_argument("--trace", default=None,
                    help="JSON arrival trace (--arrivals trace)")
    ap.add_argument("--cycle-budget", type=float, default=None,
                    help="per-cycle energy buffer Q (table units)")
    ap.add_argument("--capacity", type=float, default=None,
                    help="harvest pool capacity (energy units)")
    ap.add_argument("--harvest-rate", type=float, default=0.0,
                    help="harvest income (energy per unit virtual time)")
    ap.add_argument("--capacity-requests", type=float, default=None,
                    help="capacity in units of one first-shape request's "
                         "tabulated energy (portable across tables)")
    ap.add_argument("--rate-requests", type=float, default=None,
                    help="harvest rate in request-energies per unit time")
    ap.add_argument("--service-time", type=float, default=1.0,
                    help="virtual time one committed cycle takes")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the pre-run compile warmup")
    ap.add_argument("--expect-admitted", type=int, default=None,
                    help="exit nonzero unless >= this many admitted")
    ap.add_argument("--expect-deferred", type=int, default=None,
                    help="exit nonzero unless >= this many deferred")
    ap.add_argument("--expect-zero-retrace", action="store_true",
                    help="exit nonzero on any post-warmup jit retrace")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON (Perfetto-loadable) "
                         "of the run; also gates on ledger conservation")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot as JSON")
    ap.add_argument("--ledger-out", default=None,
                    help="write the run's energy ledger as calibration JSON "
                         "(deterministic (rid, cycle) row order — feed back "
                         "through `dse --calibrate` or --replan)")
    ap.add_argument("--table-out", default=None,
                    help="with --build: save the in-process plan table (.npz) "
                         "so `dse --calibrate` can probe it afterwards")
    ap.add_argument("--replan", action="store_true",
                    help="close the calibration loop in-process: ingest the "
                         "run's ledger into a measured cost table, rebuild "
                         "the plan table under it, and probe the rebuild "
                         "against the measured profile (requires --build)")
    ap.add_argument("--drift-tol", type=float, default=0.05,
                    help="relative drift tolerance for the --replan probe")
    ap.add_argument("--expect-replan-identical", action="store_true",
                    help="exit nonzero unless the --replan rebuild is "
                         "byte-identical to the original table (holds when "
                         "the measured draw matches the analytical model)")
    args = ap.parse_args(argv)
    if (args.replan or args.table_out) and not (args.build
                                                or args.plan_table is None):
        ap.error("--replan/--table-out need the in-process --build path")
    if args.expect_replan_identical and not args.replan:
        ap.error("--expect-replan-identical requires --replan")

    # CLI runs report through the structured emitter on stdout; library and
    # pytest use stay silent (no handler attached).
    enable_cli_output("repro.traffic", tag="traffic")
    if args.trace_out:
        TRACER.configure(enabled=True)

    # jax-heavy imports stay here so `--help` and the pure-python pieces
    # (arrival processes, HarvestModel) never pay for them
    from .planner import ServePlanner, build_table_for_arch
    from .serve import PlannedExecutor

    shapes = _parse_shapes(args.shapes)
    if args.build or args.plan_table is None:
        buckets = sorted({(b, p + g) for (b, p, g) in shapes})
        table = build_table_for_arch(args.arch, buckets, n_q=8,
                                     smoke=not args.full)
        planner = ServePlanner(table)
        _LOG.emit(f"built {table.summary()}")
        if args.table_out:
            table.save(args.table_out)
            _LOG.emit(f"saved plan table to {args.table_out}",
                      path=args.table_out)
    else:
        planner = ServePlanner.from_file(args.plan_table)
    executor = PlannedExecutor(args.arch, planner, smoke=not args.full)

    if args.arrivals == "trace":
        if args.trace is None:
            ap.error("--arrivals trace requires --trace FILE")
        requests = load_trace(args.trace)
    elif args.arrivals == "poisson":
        requests = poisson_arrivals(args.n, args.rate, shapes,
                                    seed=args.seed)
    else:
        requests = deterministic_arrivals(args.n, args.interval, shapes[0],
                                          seed=args.seed)

    capacity, rate = args.capacity, args.harvest_rate
    if args.capacity_requests is not None or args.rate_requests is not None:
        b, p, g = shapes[0]
        plan = planner.plan_for(b, p + g, args.cycle_budget)
        _, e_req = request_energy(plan, g, args.cycle_budget,
                                  planner.e_startup)
        if args.capacity_requests is not None:
            capacity = args.capacity_requests * e_req
        if args.rate_requests is not None:
            rate = args.rate_requests * e_req
        _LOG.emit(f"one {b}x{p}x{g} request draws {e_req:.6g}; "
                  f"capacity={capacity:.6g} rate={rate:.6g}",
                  e_req=e_req, capacity=capacity, rate=rate)
    harvest = (HarvestModel(capacity=capacity, rate=rate)
               if capacity is not None else None)

    harness = TrafficHarness(executor, harvest=harvest,
                             cycle_budget=args.cycle_budget,
                             service_time=args.service_time)
    if not args.no_warmup:
        n_warm = harness.warmup(requests)
        _LOG.emit(f"warmed {n_warm} shape(s)", warmed=n_warm)
    report = harness.run(requests)
    _LOG.emit(report.summary())
    _LOG.emit(
        "energy ledger: " + ", ".join(
            f"{k}={v:.6g}" for k, v in report.energy_ledger.items()),
        **report.energy_ledger,
    )

    if args.trace_out:
        n_events = TRACER.write(args.trace_out)
        _LOG.emit(f"wrote {n_events} trace events to {args.trace_out}",
                  events=n_events, path=args.trace_out)
    if args.metrics_out:
        METRICS.dump_json(args.metrics_out, tool="traffic", arch=args.arch)
        _LOG.emit(f"wrote metrics snapshot to {args.metrics_out}",
                  path=args.metrics_out)
    if args.ledger_out:
        report.ledger.dump_json(args.ledger_out, tool="traffic",
                                arch=args.arch, kind="time", seed=args.seed)
        _LOG.emit(f"wrote {len(report.ledger.entries)} ledger entries to "
                  f"{args.ledger_out}", path=args.ledger_out)

    failures = []
    if report.ledger_conserved is False:
        failures.append(
            f"energy ledger imbalance {report.ledger_conservation_error:.3e} "
            f"vs pool delta {report.energy_spent:.6g}")
    if (args.expect_admitted is not None
            and report.admitted < args.expect_admitted):
        failures.append(f"admitted {report.admitted} < "
                        f"{args.expect_admitted}")
    if (args.expect_deferred is not None
            and report.deferred < args.expect_deferred):
        failures.append(f"deferred {report.deferred} < "
                        f"{args.expect_deferred}")
    if args.expect_zero_retrace and report.retraces:
        failures.append(f"retraces {report.trace_delta} != 0 after warmup")
    if args.replan:
        # one-round-trip calibration loop: run ledger → measured table →
        # rebuild under the measured default → drift probe of the rebuild
        from ..configs import resolve_config
        from ..core.calibration import MeasuredCostTable, use_measured
        from ..core.plan_table import StaleTableError, probe_plan_table

        measured = MeasuredCostTable.from_ledger(report.ledger, kind="time")
        restore = measured.stats["restore"]
        _LOG.emit(f"calibrated {measured.n_samples} ledger samples "
                  f"(restore mean={restore.mean:.6g} std={restore.std:.6g}, "
                  f"fingerprint {measured.fingerprint()[:12]})",
                  n_samples=measured.n_samples)
        with use_measured(measured):
            replanned = build_table_for_arch(args.arch, buckets, n_q=8,
                                             smoke=not args.full)
        try:
            n = probe_plan_table(replanned, resolve_config(args.arch,
                                                           not args.full),
                                 k=4, seed=args.seed,
                                 cost=measured.cost_model(),
                                 measured=measured,
                                 drift_tol=args.drift_tol)
            _LOG.emit(f"replan probe: {n} cells within "
                      f"{args.drift_tol:.1%} of the measured profile")
        except StaleTableError as exc:
            failures.append(f"replanned table stale vs measured profile: "
                            f"{exc}")
        identical = (replanned.content_digest() == table.content_digest())
        _LOG.emit(f"replanned table digest {replanned.content_digest()[:16]} "
                  f"({'identical to' if identical else 'differs from'} "
                  f"the original)", identical=identical)
        if args.expect_replan_identical and not identical:
            failures.append("replanned table differs from the original "
                            "(measured draw drifted from the model)")
    if failures:
        _LOG.emit(f"FAILED: {'; '.join(failures)}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
