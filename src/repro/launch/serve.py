"""Batched serving driver: prefill + decode with sequence-sharded KV caches.

Serves a batch of prompts: one prefill step builds the padded KV cache
(recurrent state for SSM/hybrid archs), then greedy decode steps extend it.
On CPU this drives the smoke configs; the same path lowers for the
production meshes (decode_32k / long_500k dry-run cells).

Usage:
    python -m repro.launch.serve --arch qwen3-4b --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SMOKE_CONFIGS, get_config
from ..models import api
from ..models.sharding import rules_for
from .mesh import make_host_mesh
from .steps import make_constrain


def serve(arch: str, batch: int, prompt_len: int, gen: int, smoke: bool = True,
          seed: int = 0):
    cfg = SMOKE_CONFIGS[arch] if smoke else get_config(arch)
    mesh = make_host_mesh()
    rules = rules_for(cfg.family)
    cons = make_constrain(rules)
    max_seq = prompt_len + gen

    with mesh:
        params, _ = api.init_params(cfg, jax.random.PRNGKey(seed), max_seq=max_seq)
        prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                     (batch, prompt_len), 0, cfg.vocab)
        pre_batch = {"tokens": prompts}
        if cfg.family == "vlm":
            pre_batch["vision"] = jnp.zeros(
                (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            pre_batch["audio"] = jnp.zeros(
                (batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)

        t0 = time.time()
        prefill = jax.jit(lambda p, b: api.prefill(cfg, p, b, max_seq,
                                                   constrain=cons))
        logits, cache = prefill(params, pre_batch)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        t_pre = time.time() - t0

        decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos, constrain=cons),
            donate_argnums=(1,))
        out = [tok]
        t1 = time.time()
        for i in range(gen - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t1
        seqs = jnp.concatenate(out, axis=1)
        print(f"[serve] {arch}: batch={batch} prefill({prompt_len} tok) "
              f"{t_pre * 1e3:.1f} ms, decode {gen - 1} steps "
              f"{t_dec * 1e3 / max(gen - 1, 1):.1f} ms/tok")
        print(f"[serve] first sequences: {np.asarray(seqs)[:2, :8]}")
        return seqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    serve(args.arch, args.batch, args.prompt_len, args.gen, smoke=not args.full)
    return 0


if __name__ == "__main__":
    sys.exit(main())
