"""Batched serving driver: prefill + decode with sequence-sharded KV caches,
optionally scheduled from a precomputed plan table.

Serves a batch of prompts: one prefill step builds the padded KV cache
(recurrent state for SSM/hybrid archs), then greedy decode steps extend it.
On CPU this drives the smoke configs; the same path lowers for the
production meshes (decode_32k / long_500k dry-run cells).

With ``--plan-table`` the request is **energy-bounded**: the request shape
is bucketed into a :class:`repro.core.plan_table.PlanTable` (an O(1) lookup
— zero partitioner solves, zero jit retraces on the request path, pinned by
tests/test_serve_plan.py), the token steps are grouped into cycles that fit
``--energy-budget``, and the whole request executes as a task graph through
:class:`repro.core.runtime.BurstRuntime`: every cycle boundary commits the
decode state to NVM, so a mid-request power failure resumes from the last
committed cycle instead of restarting the request. Scheduling changes,
results never do: planned and unplanned serving produce identical token
sequences.

Usage:
    python -m repro.launch.serve --arch qwen3-4b --prompt-len 32 --gen 16
    python -m repro.launch.planner --arch qwen3-4b --buckets 2x24,2x48 \
        --out plan.npz
    python -m repro.launch.serve --arch qwen3-4b --batch 2 --prompt-len 8 \
        --gen 8 --plan-table plan.npz --energy-budget 0.5
"""

from __future__ import annotations

import argparse
import functools
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import resolve_config
from ..models import api
from ..obs.metrics import METRICS
from ..obs.trace import TRACER
from ..models.sharding import rules_for
from .mesh import make_host_mesh
from .steps import make_constrain
from .traffic import Continuation, Request

# Trace-time counters for the serving request path (incremented only when
# XLA actually re-traces; the serving regression tests pin these at zero
# across repeated planned *and* unplanned requests of the same shape).
# Registry-backed (repro.obs.metrics) but still a plain dict in every way
# existing consumers rely on.
TRACE_COUNT = METRICS.counter_dict("serve.trace_count", ("prefill", "decode"))


def reset_trace_counts() -> None:
    """Zero the process-global retrace counters (test isolation). The jit
    caches themselves are untouched — this resets observability, not
    compilation state. Consumers that can't rely on a reset (the traffic
    harness) snapshot-and-diff instead of reading absolutes. Thin alias for
    the registry reset; ``repro.obs.metrics.reset_all()`` covers it too."""
    TRACE_COUNT.reset()


@functools.lru_cache(maxsize=None)
def _host_mesh():
    """One mesh object per process: jit caches are keyed on the ambient
    mesh, so re-creating it per request would defeat the no-retrace path."""
    return make_host_mesh()


def _resolve(arch: str, smoke: bool):
    # the shared repro.configs.resolve_config — serve, planner, DSE, and the
    # façade all bucket (arch, smoke) → ModelConfig identically
    return resolve_config(arch, smoke=smoke)


@functools.lru_cache(maxsize=None)
def _step_fns(arch: str, smoke: bool, max_seq: int, donate: bool = False):
    """Cached jitted (prefill, decode) for both serving paths.

    Cached per (arch, smoke, max_seq, donate) so repeated requests reuse the
    same compiled executables. The planned path uses ``donate=False``: a
    replayed cycle must be able to re-read the committed cache from NVM, and
    donation would invalidate it. The unplanned path uses ``donate=True``
    (cache donation on decode — donation changes performance, never values)
    to keep its original fast-path semantics while still hitting this cache
    instead of rebuilding ``jax.jit`` wrappers per call. Always pass
    ``donate=`` by keyword: ``lru_cache`` keys positional and keyword calls
    differently, and a mixed style would silently double-compile.
    """
    cfg = _resolve(arch, smoke)
    cons = make_constrain(rules_for(cfg.family))

    def _prefill(params, batch):
        TRACE_COUNT["prefill"] += 1
        return api.prefill(cfg, params, batch, max_seq, constrain=cons)

    def _decode(params, cache, tok, pos):
        TRACE_COUNT["decode"] += 1
        return api.decode_step(cfg, params, cache, tok, pos, constrain=cons)

    decode = (jax.jit(_decode, donate_argnums=(1,)) if donate
              else jax.jit(_decode))
    return jax.jit(_prefill), decode


def _pre_batch(cfg, prompts) -> Dict[str, Any]:
    batch = int(np.shape(prompts)[0])
    out: Dict[str, Any] = {"tokens": prompts}
    if cfg.family == "vlm":
        out["vision"] = jnp.zeros(
            (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["audio"] = jnp.zeros(
            (batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return out


def _cache_nbytes(cfg, batch: int, max_seq: int) -> int:
    cache, _ = api.cache_shape(cfg, batch, max_seq)
    return int(
        sum(
            int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree.leaves(cache)
        )
    )


def _request_graph(cfg, params, batch, prompt_len, gen, max_seq,
                   prefill_fn, decode_fn, step_energy):
    """The request as a Ladybirds task graph: task 1 = prefill (emits token
    1), task k = decode step k (emits token k). Each task reads the previous
    decode state packet and writes the next (SSA); the final task writes the
    ``sequence`` output. Task bodies are pure functions of their declared
    inputs — the cached jitted steps are deterministic — so replayed cycles
    are idempotent, exactly the contract BurstRuntime's recovery relies on.
    """
    from ..core import GraphBuilder

    b = GraphBuilder()
    b.packet("prompts", batch * prompt_len * 4, external=True)
    state_bytes = _cache_nbytes(cfg, batch, max_seq) + batch * 4
    for k in range(gen - 1):
        b.packet(f"state{k}", state_bytes)
    b.packet("sequence", batch * gen * 4, keep=True)

    def emit(k: int, cache, tok, seq: np.ndarray) -> Dict[str, Any]:
        if k == gen - 1:
            return {"sequence": seq}
        return {f"state{k}": {"cache": cache, "tok": tok, "seq": seq}}

    def mk_prefill():
        def fn(inp):
            logits, cache = prefill_fn(params, _pre_batch(cfg, inp["prompts"]))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            return emit(0, cache, tok, np.asarray(tok))
        return fn

    def mk_decode(k: int):
        def fn(inp):
            st = inp[f"state{k - 1}"]
            logits, cache = decode_fn(
                params, st["cache"], st["tok"], jnp.int32(prompt_len + k - 1)
            )
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            seq = np.concatenate([st["seq"], np.asarray(tok)], axis=1)
            return emit(k, cache, tok, seq)
        return fn

    b.task("prefill", reads=("prompts",),
           writes=("sequence",) if gen == 1 else ("state0",),
           cost=step_energy, fn=mk_prefill())
    for k in range(1, gen):
        b.task(f"decode{k}", reads=(f"state{k - 1}",),
               writes=("sequence",) if k == gen - 1 else (f"state{k}",),
               cost=step_energy, fn=mk_decode(k))
    return b.build()


class PlannedExecutor:
    """Reusable per-request executor for the planned path.

    Owns the pieces that amortize across a request stream — the resolved
    config, the :class:`~repro.launch.planner.ServePlanner` (O(1) lookups),
    a params cache keyed on ``(seed, max_seq)``, and the process-wide jitted
    step cache — and :meth:`open`\\ s each request as a
    :class:`~repro.launch.traffic.Continuation` whose energy cycles commit
    one :meth:`~repro.launch.traffic.Continuation.step` at a time. The
    single-request `serve()` path drives one continuation to completion; the
    continuous-traffic harness (:class:`repro.launch.traffic.TrafficHarness`)
    interleaves cycles of many.
    """

    def __init__(self, arch: str, plan_table, smoke: bool = True) -> None:
        from ..core.plan_table import PlanTableError
        from .planner import as_planner

        self.arch = arch
        self.smoke = smoke
        self.planner = as_planner(plan_table)
        self.cfg = _resolve(arch, smoke)
        if self.planner.table.arch != self.cfg.name:
            raise PlanTableError(
                f"plan table was built for {self.planner.table.arch!r} but "
                f"this request is for {self.cfg.name!r}"
            )
        self._params: Dict[Any, Any] = {}
        self._next_rid = 0

    def _params_for(self, seed: int, max_seq: int):
        key = (seed, max_seq)
        if key not in self._params:
            with _host_mesh():
                params, _ = api.init_params(
                    self.cfg, jax.random.PRNGKey(seed), max_seq=max_seq)
            self._params[key] = params
        return self._params[key]

    def make_prompts(self, batch: int, prompt_len: int, seed: int = 0):
        return jax.random.randint(jax.random.PRNGKey(seed + 1),
                                  (batch, prompt_len), 0, self.cfg.vocab)

    def open(self, batch: int, prompt_len: int, gen: int, *, seed: int = 0,
             cycle_budget: Optional[float] = None, prompts=None, plan=None,
             nvm=None, crash_hook=None) -> Continuation:
        """Open one request as a steppable Continuation.

        ``plan`` short-circuits the table lookup (the harness already looked
        it up on the admission path — passing it back avoids double-counting
        ``planner.stats``). External inputs are seeded only on a fresh NVM
        (committed index 0), so reopening against a mid-request NVM resumes
        rather than restarts — the crash-recovery contract.
        """
        from ..core import BurstRuntime, CostModel, LinearTransfer, Partition
        from ..core.burst import burst_detail
        from .planner import request_cycles

        max_seq = prompt_len + gen
        if plan is None:
            plan = self.planner.plan_for(batch, max_seq, cycle_budget)
        with _host_mesh():
            params = self._params_for(seed, max_seq)
            if prompts is None:
                prompts = self.make_prompts(batch, prompt_len, seed)
            prefill_fn, decode_fn = _step_fns(self.arch, self.smoke, max_seq,
                                              donate=False)
            graph = _request_graph(self.cfg, params, batch, prompt_len, gen,
                                   max_seq, prefill_fn, decode_fn,
                                   step_energy=plan.e_total)
        cycles = request_cycles(gen, plan.e_total, cycle_budget,
                                e_startup=self.planner.e_startup)
        cost = CostModel(e_startup=self.planner.e_startup,
                         read=LinearTransfer(0.0, 0.0),
                         write=LinearTransfer(0.0, 0.0),
                         name="request-cycles")
        part = Partition(
            cycles, [burst_detail(graph, cost, i, j) for (i, j) in cycles],
            None,
        )
        rt = BurstRuntime(graph, part, nvm=nvm, cost=cost,
                          crash_hook=crash_hook)
        if rt.nvm.read_index() == 0:
            rt.seed_inputs({"prompts": np.asarray(prompts)})
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, batch=batch, prompt_len=prompt_len, gen=gen,
                      seed=seed)
        return Continuation(request=req, plan=plan, cycles=list(cycles),
                            runtime=rt, e_startup=self.planner.e_startup,
                            scope=_host_mesh)

    def warmup(self, shapes, cycle_budget: Optional[float] = None) -> None:
        """Pre-compile: run one throwaway request per ``(batch, prompt_len,
        gen, seed)`` shape so jit tracing happens outside any measured or
        admission-controlled window."""
        for (batch, prompt_len, gen, seed) in shapes:
            cont = self.open(batch, prompt_len, gen, seed=seed,
                             cycle_budget=cycle_budget)
            cont.run_to_completion()


def _serve_planned(arch, batch, prompt_len, gen, smoke, seed,
                   plan_table, energy_budget, nvm, crash_hook, report):
    ex = PlannedExecutor(arch, plan_table, smoke=smoke)
    cont = ex.open(batch, prompt_len, gen, seed=seed,
                   cycle_budget=energy_budget, nvm=nvm, crash_hook=crash_hook)
    t0 = time.time()
    out = cont.run_to_completion()
    dt = time.time() - t0
    seqs = jnp.asarray(out)
    print(f"[serve] {arch}: planned batch={batch} "
          f"prefill({prompt_len} tok)+{gen - 1} decode steps in "
          f"{len(cont.cycles)} energy cycles ({dt * 1e3:.1f} ms total); "
          f"plan: {cont.plan.summary()}")
    print(f"[serve] first sequences: {np.asarray(seqs)[:2, :8]}")
    if report is not None:
        report.update(
            plan=cont.plan, cycles=list(cont.cycles),
            runtime_stats=cont.runtime.stats,
            planner_stats=dict(ex.planner.stats), nvm=cont.runtime.nvm,
        )
    return seqs


def serve(arch: str, batch: int, prompt_len: int, gen: int, smoke: bool = True,
          seed: int = 0, plan_table=None, energy_budget: Optional[float] = None,
          nvm=None, crash_hook=None, report: Optional[dict] = None):
    """Serve one batched request.

    ``plan_table`` (path / PlanTable / ServePlanner) switches to the
    energy-bounded planned path described in the module docstring; ``nvm``
    and ``crash_hook`` are forwarded to the BurstRuntime so tests can inject
    power failures mid-request, and ``report`` (a dict) receives the plan,
    cycle bounds, and runtime stats.
    """
    if gen < 1:
        raise ValueError("gen must be >= 1 (prefill emits the first token)")
    if plan_table is not None:
        return _serve_planned(arch, batch, prompt_len, gen, smoke, seed,
                              plan_table, energy_budget, nvm, crash_hook,
                              report)
    planned_only = {"energy_budget": energy_budget, "nvm": nvm,
                    "crash_hook": crash_hook, "report": report}
    misused = [k for k, v in planned_only.items() if v is not None]
    if misused:
        raise ValueError(
            f"{misused} require plan_table: without a plan table there are "
            "no energy cycles, NVM commits, or crash resumability"
        )

    cfg = _resolve(arch, smoke)
    mesh = _host_mesh()
    max_seq = prompt_len + gen

    with mesh:
        params, _ = api.init_params(cfg, jax.random.PRNGKey(seed), max_seq=max_seq)
        prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                     (batch, prompt_len), 0, cfg.vocab)
        pre_batch = _pre_batch(cfg, prompts)

        # the same cached executables as the planned path (donate=True keeps
        # the decode cache-donation fast path) — previously fresh
        # jax.jit(lambda ...) wrappers here retraced on every call
        prefill, decode = _step_fns(arch, smoke, max_seq, donate=True)
        t0 = time.time()
        logits, cache = prefill(params, pre_batch)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        t_pre = time.time() - t0

        out = [tok]
        t1 = time.time()
        for i in range(gen - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t1
        seqs = jnp.concatenate(out, axis=1)
        print(f"[serve] {arch}: batch={batch} prefill({prompt_len} tok) "
              f"{t_pre * 1e3:.1f} ms, decode {gen - 1} steps "
              f"{t_dec * 1e3 / max(gen - 1, 1):.1f} ms/tok")
        print(f"[serve] first sequences: {np.asarray(seqs)[:2, :8]}")
        return seqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--plan-table", default=None,
                    help="precomputed PlanTable (.npz) — enables the "
                         "energy-bounded planned path")
    ap.add_argument("--energy-budget", type=float, default=None,
                    help="per-cycle energy budget (units of the table's "
                         "cost model; default: unbounded)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON (Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot as JSON")
    ap.add_argument("--calibration", default=None,
                    help="measured-cost calibration JSON (from "
                         "`launch/dse.py --calibrate`): probe the plan table "
                         "against the measured profile before serving and "
                         "refuse stale plans (requires --plan-table)")
    ap.add_argument("--drift-tol", type=float, default=0.05,
                    help="relative drift tolerance for the --calibration "
                         "probe (default 0.05)")
    args = ap.parse_args(argv)
    if args.trace_out:
        TRACER.configure(enabled=True)
    if args.calibration:
        if not args.plan_table:
            ap.error("--calibration requires --plan-table")
        from ..core.calibration import MeasuredCostTable
        from ..core.plan_table import PlanTable, probe_plan_table

        measured = MeasuredCostTable.from_json(args.calibration)
        n = probe_plan_table(PlanTable.load(args.plan_table),
                             _resolve(args.arch, not args.full),
                             k=4, measured=measured,
                             drift_tol=args.drift_tol)
        print(f"[serve] calibration probe: {n} cells of {args.plan_table} "
              f"within {args.drift_tol:.1%} of the measured profile "
              f"({measured.n_samples} samples) — serving")
    serve(args.arch, args.batch, args.prompt_len, args.gen,
          smoke=not args.full, plan_table=args.plan_table,
          energy_budget=args.energy_budget)
    if args.trace_out:
        n_events = TRACER.write(args.trace_out)
        print(f"[serve] wrote {n_events} trace events to {args.trace_out}")
    if args.metrics_out:
        METRICS.dump_json(args.metrics_out, tool="serve", arch=args.arch)
        print(f"[serve] wrote metrics snapshot to {args.metrics_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
