"""Swarm placement CLI: partition one model across N harvesting nodes.

Loads either an NS Optimizer profile pair (``--prof prof.csv --dep
dep.csv`` — see :mod:`repro.data.ns_optimizer`) or a zoo config
(``--arch qwen3-4b --buckets 2x16``), then solves the bandwidth × memory ×
Q placement grid in **one** batched ``Engine.solve`` call and reports:

* the bandwidth sweep — per-link total energy, nodes used, transfer
  overhead and hop latency;
* the best cell's per-node split — span, burst count, span energy, peak
  NVM footprint, hop TX/RX and the node's total spent draw;
* conservation — every feasible plan's per-node
  :class:`~repro.obs.ledger.EnergyLedger` must conserve node-by-node and
  sum back to the plan total (nonzero exit on imbalance).

Telemetry mirrors the other launch CLIs: ``--trace-out`` writes a
Perfetto-loadable trace with one track per node (``PID_SWARM`` /
:func:`~repro.obs.trace.node_tid`), ``--metrics-out`` snapshots the
metrics registry, ``--ledger-out`` dumps the best plan's merged per-node
ledger rows, and ``--table-out`` persists the whole sweep as a versioned
:class:`~repro.core.placement.PlacementTable` JSON.

Example::

    python -m repro.launch.swarm --prof prof.csv --dep dep.csv \\
        --nodes 3 --bandwidths 900:3400:100 --table-out swarm.json
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional, Tuple

from ..obs.ledger import EnergyLedger, LedgerImbalance
from ..obs.metrics import METRICS
from ..obs.trace import PID_SWARM, TRACER, node_tid

__all__ = ["build_swarm_spec", "load_graph", "report_sweep", "main"]


def load_graph(args) -> Tuple[object, object, str]:
    """Resolve the (graph, cost model, label) triple from the CLI mode."""
    from ..core.layer_profile import default_cost_model

    kind = args.kind or "time"
    cm = default_cost_model(kind)
    if args.prof or args.dep:
        if not (args.prof and args.dep):
            raise SystemExit("--prof and --dep go together (NS Optimizer mode)")
        if args.arch:
            raise SystemExit("--prof/--dep and --arch are exclusive modes")
        from ..data.ns_optimizer import load_ns_model

        model = load_ns_model(args.prof, args.dep)
        return model.graph, cm, model.summary()
    from .planner import _parse_buckets, lower_buckets, resolve_config

    cfg = resolve_config(args.arch, not args.full)
    bucket = _parse_buckets(args.buckets)[0]
    graph = lower_buckets(cfg, [bucket], kind)[0]
    label = (
        f"{args.arch} bucket {bucket[0]}x{bucket[1]}: "
        f"{graph.n_tasks} tasks, {len(graph.packets)} packets"
    )
    return graph, cm, label


def build_swarm_spec(graph, cm, args):
    """The :class:`~repro.core.placement.PlacementSpec` the CLI solves.

    ``--node-q`` defaults to the graph's §4.4 storage minimum Q_min × 1.25
    (matching ``dse --placement``); ``--compute-scales`` makes the relay
    chain heterogeneous (one multiplier per node's task costs).
    """
    from ..api import Engine, PartitionSpec
    from ..core.placement import LinkModel, NodeSpec, PlacementSpec

    node_q = args.node_q
    if node_q is None:
        qmin = Engine().solve(
            PartitionSpec(graph=graph, cost=cm, objective="minimax")
        ).q_min()
        node_q = qmin * 1.25
    scales = _parse_floats(args.compute_scales) if args.compute_scales else []
    if scales and len(scales) != args.nodes:
        raise SystemExit(
            f"--compute-scales needs one value per node "
            f"({args.nodes}), got {len(scales)}"
        )
    from .dse import parse_bandwidths

    nodes = tuple(
        NodeSpec(
            q_max=float(node_q),
            memory_bytes=args.node_memory,
            compute_scale=scales[k] if scales else 1.0,
            name=f"node{k}",
        )
        for k in range(args.nodes)
    )
    return (
        PlacementSpec(
            nodes=nodes,
            links=tuple(
                LinkModel(bandwidth_mbps=float(b))
                for b in parse_bandwidths(args.bandwidths)
            ),
            q_scales=tuple(_parse_floats(args.q_scales)),
            memory_scales=tuple(_parse_floats(args.memory_scales)),
        ),
        float(node_q),
    )


def _parse_floats(text: str) -> List[float]:
    return [float(p) for p in text.split(",") if p.strip()]


def _best_cell(sweep) -> Optional[Tuple[int, int, int]]:
    """First-min grid cell by total energy (C-order ties — deterministic)."""
    import numpy as np

    flat = sweep.e_total.reshape(-1)
    if not np.isfinite(flat).any():
        return None
    idx = int(np.argmin(flat))  # first minimum in C-order
    L, M, Z = sweep.grid_shape
    return idx // (M * Z), (idx // Z) % M, idx % Z


def report_sweep(sweep, *, out=print) -> int:
    """Print the bandwidth sweep at the base (memory, Q) scales; returns
    the number of feasible links."""
    L, _, _ = sweep.grid_shape
    feasible = 0
    for li in range(L):
        link = sweep.inputs.spec.links[li]
        if not sweep.feasible(li, 0, 0):
            out(f"  {link.bandwidth_mbps:8g} mbps  infeasible")
            continue
        feasible += 1
        p = sweep.plan(li, 0, 0)
        out(
            f"  {link.bandwidth_mbps:8g} mbps  E={p.e_total:.6g}  "
            f"nodes={p.n_nodes_used}  bursts={p.n_bursts}  "
            f"transfer={100 * p.transfer_overhead:5.2f}%  "
            f"hops={len(p.hop_boundaries)} "
            f"({p.transfer_bytes:.3g} B, {p.total_hop_latency_s:.3g} s)"
        )
    return feasible


def _emit_node_tracks(plan) -> None:
    """One Perfetto track per node: a span carrying the node's split, an
    instant per hop on the sending node's track, and a node-energy counter."""
    if not TRACER.enabled:
        return
    TRACER.set_process(PID_SWARM, "swarm")
    for k, ((i, j), bursts) in enumerate(zip(plan.spans, plan.node_bursts)):
        tid = node_tid(k)
        TRACER.set_thread(PID_SWARM, tid, f"node{k}")
        with TRACER.span(
            f"span<{i},{j}>", cat="swarm", pid=PID_SWARM, tid=tid,
            bursts=len(bursts),
            energy=plan.node_energy[k],
            spent=plan.node_spent(k),
            memory_bytes=plan.node_memory_bytes[k],
        ):
            pass
        if k < len(plan.hop_boundaries):
            TRACER.instant(
                f"hop b={plan.hop_boundaries[k]}", cat="swarm",
                pid=PID_SWARM, tid=tid,
                nbytes=plan.hop_bytes[k],
                tx=plan.hop_tx[k], rx=plan.hop_rx[k],
                latency_s=plan.hop_latency_s[k],
            )
        TRACER.counter(
            "node_energy", {f"node{k}": plan.node_spent(k)},
            pid=PID_SWARM, tid=tid,
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--prof", default=None,
                    help="NS Optimizer prof.csv (layer, time, output mb, "
                    "memory mb)")
    ap.add_argument("--dep", default=None,
                    help="NS Optimizer dep.csv (Source,Destination edges)")
    ap.add_argument("--arch", default=None,
                    help="zoo config name instead of --prof/--dep")
    ap.add_argument("--buckets", default="2x16",
                    help="BATCHxSEQ bucket for --arch (first one is used)")
    ap.add_argument("--full", action="store_true",
                    help="full config instead of the smoke config (--arch)")
    ap.add_argument("--kind", choices=("time", "memory"), default=None,
                    help="cost interpretation (default time)")
    ap.add_argument("--nodes", type=int, default=3,
                    help="relay-chain length (default 3)")
    ap.add_argument("--bandwidths", default="900:3400:100",
                    help="link sweep: start:stop[:step] mbps (stop "
                    "exclusive) or a comma list (default 900:3400:100)")
    ap.add_argument("--node-q", type=float, default=None,
                    help="per-node burst budget (default: Q_min × 1.25)")
    ap.add_argument("--node-memory", type=float, default=None,
                    help="per-node NVM bytes (default unbounded)")
    ap.add_argument("--q-scales", default="1.0",
                    help="comma-separated node-budget multipliers (Q axis)")
    ap.add_argument("--memory-scales", default="1.0",
                    help="comma-separated node-memory multipliers")
    ap.add_argument("--compute-scales", default="",
                    help="comma-separated per-node task-cost multipliers "
                    "(heterogeneous chain; one per node)")
    ap.add_argument("--backend", default="auto",
                    help="solver backend (auto → the batched scan solver)")
    ap.add_argument("--table-out", default=None,
                    help="write the sweep as PlacementTable JSON")
    ap.add_argument("--ledger-out", default=None,
                    help="dump the best plan's merged per-node ledger JSON")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON with one track "
                    "per node")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args(argv)
    if not (args.prof or args.dep or args.arch):
        ap.error("pick a mode: --prof/--dep (NS Optimizer) or --arch (zoo)")
    if args.trace_out:
        TRACER.configure(enabled=True)

    from ..api import Engine, PartitionSpec

    graph, cm, label = load_graph(args)
    print(f"[swarm] loaded {label}")
    spec, node_q = build_swarm_spec(graph, cm, args)
    L, M, Z = spec.grid_shape
    t0 = time.time()
    with TRACER.span("swarm.solve", cat="swarm", pid=PID_SWARM, tid=0,
                     links=L, mem=M, q=Z, nodes=spec.n_nodes):
        sol = Engine().solve(
            PartitionSpec(
                graph=graph, cost=cm, placement=spec, backend=args.backend
            )
        )
    sweep = sol.placement_sweep()
    dt = time.time() - t0
    print(
        f"[swarm] solved {spec.n_nodes} nodes × {L} links × {M} mem × {Z} Q "
        f"grid on backend {sol.backend} in {dt:.2f}s "
        f"(node_q={node_q:.4g})"
    )
    print("[swarm] bandwidth sweep (base memory/Q scales):")
    feasible = report_sweep(sweep)
    best = _best_cell(sweep)
    if best is None:
        print("[swarm] no feasible placement anywhere on the grid — raise "
              "--node-q/--node-memory or add nodes", file=sys.stderr)
        return 2

    li, m, z = best
    plan = sweep.plan(li, m, z)
    print(
        f"[swarm] best cell: link={plan.link.bandwidth_mbps:g} mbps "
        f"memory×{plan.memory_scale:g} q×{plan.q_scale:g} — {plan.summary()}"
    )
    print("[swarm] per-node split:")
    for k, ((i, j), bursts) in enumerate(zip(plan.spans, plan.node_bursts)):
        tx = plan.hop_tx[k] if k < len(plan.hop_tx) else 0.0
        rx = plan.hop_rx[k - 1] if k >= 1 else 0.0
        print(
            f"  node{k}  span<{i},{j}>  bursts={len(bursts)}  "
            f"E={plan.node_energy[k]:.6g}  "
            f"mem={plan.node_memory_bytes[k]:.3g} B  "
            f"tx={tx:.3g}  rx={rx:.3g}  spent={plan.node_spent(k):.6g}"
        )
    print(
        f"[swarm] transfer overhead {100 * plan.transfer_overhead:.2f}% "
        f"({plan.transfer_energy:.6g} of E_total {plan.e_total:.6g}; "
        f"{plan.transfer_bytes:.3g} B, {plan.total_hop_latency_s:.3g} s "
        f"hop latency)"
    )
    _emit_node_tracks(plan)

    # Conservation gate: every feasible cell's plan must be structurally
    # sound and conserve energy node-by-node.
    checked = 0
    try:
        for p in sweep.plans():
            if p is None:
                continue
            p.validate()
            p.check_conservation()
            checked += 1
    except (AssertionError, LedgerImbalance) as exc:
        print(f"[swarm] CONSERVATION FAILURE: {exc}", file=sys.stderr)
        return 1
    print(
        f"[swarm] ledger: {checked} feasible plans conserve node-by-node "
        f"(per-node ledgers sum to each plan total)"
    )

    if args.table_out:
        from ..core.placement import PlacementTable

        meta = {
            "tool": "swarm",
            "nodes": spec.n_nodes,
            "node_q": node_q,
            "kind": args.kind or "time",
            "backend": sol.backend,
        }
        if args.arch:
            meta["arch"] = args.arch
        if args.prof:
            meta["prof"] = args.prof
            meta["dep"] = args.dep
        table = PlacementTable(sweep, meta=meta)
        table.to_json(args.table_out)
        print(f"[swarm] wrote {table.summary()} → {args.table_out}")
    if args.ledger_out:
        merged = EnergyLedger()
        for led in plan.ledgers():
            merged.entries.extend(led.entries)
        merged.dump_json(
            args.ledger_out, tool="swarm", nodes=plan.n_nodes_used,
            link_mbps=plan.link.bandwidth_mbps, e_total=plan.e_total,
        )
        print(f"[swarm] wrote {len(merged.entries)} ledger rows "
              f"→ {args.ledger_out}")
    if args.trace_out:
        n_ev = TRACER.write(args.trace_out)
        print(f"[swarm] wrote {n_ev} trace events to {args.trace_out}")
    if args.metrics_out:
        METRICS.dump_json(args.metrics_out, tool="swarm")
        print(f"[swarm] wrote metrics snapshot to {args.metrics_out}")
    return 0 if feasible else 2


if __name__ == "__main__":
    sys.exit(main())
