"""Sharded design-space exploration: multi-device plan-table builds.

The paper's Julienning flow is an offline DSE — solve the energy-bounded
partition for every (application, E_burst) point of interest. This module is
that flow at bucket-fleet scale: the (shape-bucket × Q-grid) work partitions
across a device mesh (:func:`repro.launch.mesh.make_shard_mesh`; pmap over
the Q-shard axis inside :func:`repro.core.partition_jax.sweep_jax_sharded`)
and the gathered per-shard columns assemble into one versioned table whose
content is byte-identical to a single-host :func:`build_plan_table` run.

Growth is incremental: :func:`extend_for_arch` appends new shape buckets (and
optionally new Q points) to an existing table without re-solving any tabulated
cell, and the header's ``lineage`` fingerprint chain records each extension.
On load, :func:`probe_table` re-validates K random cells against the live
engine so a table that outlived an engine or cost-model change fails loudly
(:class:`repro.core.plan_table.StaleTableError`) instead of serving stale
plans.

CLI::

    # fresh sharded build (emulate a mesh with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8)
    python -m repro.launch.dse --arch qwen3-4b --buckets 2x24,2x48 \
        --q-points 16 --shards 8 --out plan_qwen.npz

    # incremental: append a bucket + two Q points, no re-solve of old cells
    python -m repro.launch.dse --arch qwen3-4b --buckets 2x24,2x48,4x48 \
        --extend --add-q 1.5e-3,2.5e-3 --shards 8 --out plan_qwen.npz

    # load-time staleness probe of an existing table (no rebuild)
    python -m repro.launch.dse --arch qwen3-4b --probe-only --probe 8 \
        --out plan_qwen.npz

    # close the calibration loop: captured ledger → measured cost table →
    # drift probe of the tabulated plans against the refreshed profile
    python -m repro.launch.dse --arch qwen3-4b --calibrate ledger.json \
        --out plan_qwen.npz --probe 4

    # swarm placement DSE: sweep link bandwidths × per-node budgets across a
    # relay chain in one batched solve, into a versioned placement table
    python -m repro.launch.dse --arch qwen3-4b --placement --nodes 3 \
        --bandwidths 900:3400:100 --out placement_qwen.json
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional, Sequence, Tuple, Union

from ..api import QGridSharding
from ..obs.metrics import METRICS
from ..obs.trace import TRACER
from ..core.plan_table import (
    PlanTable,
    build_plan_table,
    extend_plan_table,
    probe_plan_table,
    _default_cost,
)
from .mesh import shard_devices
from .planner import _parse_buckets, derive_q_grid, lower_buckets, resolve_config

__all__ = [
    "build_placement_table_for_arch",
    "build_sharded_table_for_arch",
    "calibrate_table",
    "extend_for_arch",
    "parse_bandwidths",
    "probe_table",
]


def build_sharded_table_for_arch(
    arch: str,
    shape_buckets: List[Tuple[int, int]],
    n_q: int = 16,
    *,
    n_shards: int,
    smoke: bool = True,
    kind: str = "time",
    cache_dir: Optional[str] = None,
) -> PlanTable:
    """Sharded sibling of :func:`repro.launch.planner.build_table_for_arch`:
    same derived Q grid, same bytes, Q-sharded solve across the device mesh
    (sequential same-decomposition fallback when the host has fewer devices
    than shards)."""
    cfg = resolve_config(arch, smoke)
    cm = _default_cost(kind)
    graphs = lower_buckets(cfg, shape_buckets, kind)
    qs = derive_q_grid(graphs, cm, n_q)
    return build_plan_table(
        cfg, shape_buckets, qs, kind=kind, cost=cm,
        cache_dir=cache_dir, graphs=graphs,
        sharding=QGridSharding(n_shards, shard_devices(n_shards)),
    )


def extend_for_arch(
    base: Union[PlanTable, str],
    arch: str,
    shape_buckets: Sequence[Tuple[int, int]],
    *,
    add_q_values: Sequence[Optional[float]] = (),
    smoke: bool = True,
    n_shards: Optional[int] = None,
    cache_dir: Optional[str] = None,
) -> PlanTable:
    """Extend an existing table with whatever of ``shape_buckets`` /
    ``add_q_values`` it does not already tabulate (existing cells are
    byte-moved, never re-solved). ``n_shards`` shards the extension solves."""
    if isinstance(base, str):
        base = PlanTable.load(base)
    cfg = resolve_config(arch, smoke)
    # extend_plan_table itself ignores already-tabulated buckets/Q points,
    # so the full request list passes straight through.
    return extend_plan_table(
        base, cfg, add_buckets=shape_buckets, add_q_values=add_q_values,
        n_shards=n_shards,
        devices=None if n_shards is None else shard_devices(n_shards),
        cache_dir=cache_dir,
    )


def probe_table(
    table: Union[PlanTable, str],
    arch: str,
    *,
    k: Optional[int] = 4,
    seed: int = 0,
    smoke: bool = True,
    measured=None,
    drift_tol: float = 0.05,
) -> int:
    """Load-time staleness probe by arch name (see
    :func:`repro.core.plan_table.probe_plan_table`). ``measured`` (a
    :class:`repro.core.calibration.MeasuredCostTable`) additionally checks
    probed cells' tabulated draw against the refreshed measured profile."""
    if isinstance(table, str):
        table = PlanTable.load(table)
    return probe_plan_table(table, resolve_config(arch, smoke), k=k, seed=seed,
                            measured=measured, drift_tol=drift_tol)


def calibrate_table(
    ledger_json: str,
    *,
    kind: str = "time",
    out_json: Optional[str] = None,
):
    """Rebuild a measured cost table from a captured ledger dump
    (``EnergyLedger.dump_json`` / ``launch/traffic.py --ledger-out``) and
    optionally persist it as versioned calibration JSON."""
    from ..core.calibration import MeasuredCostTable

    measured = MeasuredCostTable.from_ledger_json(ledger_json, kind=kind)
    if out_json:
        measured.to_json(out_json, source=ledger_json)
    return measured


def parse_bandwidths(text: str) -> List[float]:
    """``"900:3400:100"`` (start:stop:step, stop exclusive — the NS
    Optimizer sweep convention) or a comma list ``"900,1800,3400"``."""
    text = text.strip()
    if ":" in text:
        parts = text.split(":")
        if len(parts) not in (2, 3):
            raise ValueError(
                f"bandwidth range is start:stop[:step], got {text!r}"
            )
        start, stop = float(parts[0]), float(parts[1])
        step = float(parts[2]) if len(parts) == 3 else 100.0
        if step <= 0 or stop <= start:
            raise ValueError(f"empty bandwidth range {text!r}")
        out = []
        v = start
        while v < stop:
            out.append(v)
            v += step
        return out
    vals = [float(p) for p in text.split(",") if p.strip()]
    if not vals:
        raise ValueError(f"no bandwidths in {text!r}")
    return vals


def build_placement_table_for_arch(
    arch: str,
    bucket: Tuple[int, int],
    *,
    n_nodes: int = 3,
    bandwidths_mbps: Sequence[float] = (),
    node_q: Optional[float] = None,
    node_memory: Optional[float] = None,
    q_scales: Sequence[float] = (1.0,),
    memory_scales: Sequence[float] = (1.0,),
    smoke: bool = True,
    kind: str = "time",
    backend: str = "auto",
):
    """Solve one arch bucket's placement grid (links × memory × Q) in one
    batched façade call and wrap it as a versioned
    :class:`~repro.core.placement.PlacementTable`.

    ``node_q=None`` derives the per-node burst budget from the graph: the
    §4.4 storage minimum Q_min × 1.25 — enough headroom that a single node
    stays feasible while tight enough that the budget axis bites.
    """
    from ..api import Engine, PartitionSpec
    from ..core.placement import LinkModel, NodeSpec, PlacementSpec, PlacementTable

    cfg = resolve_config(arch, smoke)
    cm = _default_cost(kind)
    graph = lower_buckets(cfg, [tuple(bucket)], kind)[0]
    if node_q is None:
        qmin = Engine().solve(
            PartitionSpec(graph=graph, cost=cm, objective="minimax")
        ).q_min()
        node_q = qmin * 1.25
    pspec = PlacementSpec(
        nodes=tuple(
            NodeSpec(q_max=float(node_q), memory_bytes=node_memory)
            for _ in range(int(n_nodes))
        ),
        links=tuple(LinkModel(bandwidth_mbps=float(b)) for b in bandwidths_mbps),
        q_scales=tuple(q_scales),
        memory_scales=tuple(memory_scales),
    )
    sol = Engine().solve(
        PartitionSpec(graph=graph, cost=cm, placement=pspec, backend=backend)
    )
    return PlacementTable(
        sol.placement_sweep(),
        meta={
            "arch": arch,
            "bucket": list(bucket),
            "kind": kind,
            "smoke": bool(smoke),
            "backend": sol.backend,
            "node_q": float(node_q),
        },
    )


def _parse_q_list(text: str) -> List[float]:
    return [float(part) for part in text.split(",") if part.strip()]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--buckets", default="2x24,2x48",
                    help="comma-separated BATCHxSEQ buckets, e.g. 2x24,4x48")
    ap.add_argument("--q-points", type=int, default=None,
                    help="geometric Q grid size, default 16 (an unbounded "
                    "point is added; fresh builds only)")
    ap.add_argument("--kind", choices=("time", "memory"), default=None,
                    help="cost interpretation, default time (fresh builds "
                    "only — an extension keeps the base table's kind)")
    ap.add_argument("--shards", type=int, default=1,
                    help="Q-grid shards (pmap across that many devices; "
                    "sequential fallback when the host has fewer)")
    ap.add_argument("--extend", action="store_true",
                    help="extend the existing table at --out instead of "
                    "rebuilding (only missing buckets/Q points are solved)")
    ap.add_argument("--add-q", default="",
                    help="comma-separated Q_max values to append (--extend)")
    ap.add_argument("--probe", type=int, default=0,
                    help="after build/load, re-validate this many random "
                    "cells against the live engine")
    ap.add_argument("--probe-only", action="store_true",
                    help="only probe the existing table at --out — no build, "
                    "no extend, nothing written")
    ap.add_argument("--calibrate", default=None, metavar="LEDGER_JSON",
                    help="rebuild a measured cost table from a captured "
                    "energy-ledger dump (traffic --ledger-out / "
                    "EnergyLedger.dump_json), write it as calibration JSON "
                    "(--calibration-out), and probe the table at --out "
                    "against the measured profile — exits nonzero when any "
                    "probed cell's measured draw drifts beyond --drift-tol")
    ap.add_argument("--calibration-out", default=None,
                    help="measured-table JSON path (--calibrate; default "
                    "<out>.calib.json)")
    ap.add_argument("--drift-tol", type=float, default=0.05,
                    help="relative per-cycle drift tolerance for the "
                    "calibration probe (default 0.05)")
    ap.add_argument("--placement", action="store_true",
                    help="swarm placement DSE: solve the bandwidth × memory "
                    "× Q placement grid for the first --buckets shape across "
                    "--nodes relay nodes in one batched call, writing a "
                    "versioned placement table JSON to --out")
    ap.add_argument("--nodes", type=int, default=3,
                    help="relay-chain length for --placement (default 3)")
    ap.add_argument("--bandwidths", default="900:3400:100",
                    help="link sweep for --placement: start:stop[:step] mbps "
                    "(stop exclusive, NS Optimizer convention) or a comma "
                    "list (default 900:3400:100)")
    ap.add_argument("--node-q", type=float, default=None,
                    help="per-node burst budget for --placement (default: "
                    "the graph's Q_min × 1.25)")
    ap.add_argument("--node-memory", type=float, default=None,
                    help="per-node NVM bytes for --placement (default "
                    "unbounded)")
    ap.add_argument("--q-scales", default="1.0",
                    help="comma-separated node-budget multipliers "
                    "(--placement Q axis)")
    ap.add_argument("--memory-scales", default="1.0",
                    help="comma-separated node-memory multipliers "
                    "(--placement memory axis)")
    ap.add_argument("--backend", default="auto",
                    help="solver backend for --placement (auto → the "
                    "batched scan grid solver)")
    ap.add_argument("--seed", type=int, default=0, help="probe cell RNG seed")
    ap.add_argument("--out", required=True, help="table .npz path")
    ap.add_argument("--full", action="store_true",
                    help="use the full config instead of the smoke config")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON (Perfetto-loadable) "
                         "of the build/extend/probe")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot as JSON")
    args = ap.parse_args(argv)
    if args.trace_out:
        TRACER.configure(enabled=True)

    import jax

    buckets = _parse_buckets(args.buckets)
    smoke = not args.full
    if args.extend or args.probe_only or args.calibrate:
        # the base table fixes the grid parameters — refuse silent drops
        if args.kind is not None or args.q_points is not None:
            ap.error("--kind/--q-points are fixed by the existing table; "
                     "not valid with --extend/--probe-only/--calibrate")
    if args.calibrate and (args.extend or args.probe_only):
        ap.error("--calibrate is its own mode; drop --extend/--probe-only")
    if args.placement and (args.extend or args.probe_only or args.calibrate):
        ap.error("--placement is its own mode; drop "
                 "--extend/--probe-only/--calibrate")
    def _flush_telemetry() -> None:
        if args.trace_out:
            n_ev = TRACER.write(args.trace_out)
            print(f"[dse] wrote {n_ev} trace events to {args.trace_out}")
        if args.metrics_out:
            METRICS.dump_json(args.metrics_out, tool="dse", arch=args.arch)
            print(f"[dse] wrote metrics snapshot to {args.metrics_out}")

    if args.placement:
        t0 = time.time()
        table = build_placement_table_for_arch(
            args.arch, buckets[0],
            n_nodes=args.nodes,
            bandwidths_mbps=parse_bandwidths(args.bandwidths),
            node_q=args.node_q,
            node_memory=args.node_memory,
            q_scales=_parse_q_list(args.q_scales),
            memory_scales=_parse_q_list(args.memory_scales),
            smoke=smoke, kind=args.kind or "time", backend=args.backend,
        )
        table.to_json(args.out)
        dt = time.time() - t0
        print(f"[dse] solved {table.summary()} in {dt:.2f}s → {args.out}")
        L, M, Z = table.grid_shape
        print(f"[dse]   grid: {L} links × {M} memory × {Z} Q "
              f"({args.nodes} nodes, node_q={table.meta['node_q']:.4g})")
        _flush_telemetry()
        return 0
    if args.probe_only:
        n = probe_table(args.out, args.arch, k=args.probe or None,
                        seed=args.seed, smoke=smoke)
        print(f"[dse] probe: {n} cells of {args.out} re-validated against "
              f"the live engine — clean")
        _flush_telemetry()
        return 0
    if args.calibrate:
        from ..core.plan_table import StaleTableError

        table = PlanTable.load(args.out)
        calib_out = args.calibration_out or args.out + ".calib.json"
        measured = calibrate_table(args.calibrate, kind=table.kind,
                                   out_json=calib_out)
        restore = measured.stats["restore"]
        print(f"[dse] calibrated {measured.n_samples} ledger samples from "
              f"{args.calibrate} → {calib_out}")
        print(f"[dse]   restore: n={restore.count} mean={restore.mean:.3e} "
              f"std={restore.std:.3e} (analytical "
              f"e_startup={float(measured.base.e_startup):.3e})")
        print(f"[dse]   fingerprint: {measured.fingerprint()[:16]}")
        try:
            n = probe_table(table, args.arch, k=args.probe or None,
                            seed=args.seed, smoke=smoke, measured=measured,
                            drift_tol=args.drift_tol)
        except StaleTableError as exc:
            print(f"[dse]   STALE: {exc}", file=sys.stderr)
            _flush_telemetry()
            return 1
        print(f"[dse]   probe:   {n} cells of {args.out} within "
              f"{args.drift_tol:.1%} of the measured profile — accepted")
        _flush_telemetry()
        return 0
    t0 = time.time()
    if args.extend:
        table = extend_for_arch(
            args.out, args.arch, buckets,
            add_q_values=_parse_q_list(args.add_q),
            smoke=smoke, n_shards=args.shards,
        )
        verb = "extended"
    else:
        if args.add_q:
            ap.error("--add-q only makes sense with --extend")
        table = build_sharded_table_for_arch(
            args.arch, buckets, args.q_points or 16,
            n_shards=args.shards, smoke=smoke, kind=args.kind or "time",
        )
        verb = "built"
    table.save(args.out)
    dt = time.time() - t0
    print(f"[dse] {verb} {table.summary()} in {dt:.2f}s "
          f"({args.shards} shards, {len(jax.local_devices())} devices) "
          f"→ {args.out}")
    print(f"[dse]   lineage: {' → '.join(f[:12] for f in table.lineage)}")
    print(f"[dse]   digest:  {table.content_digest()[:16]}")
    if args.probe:
        n = probe_table(args.out, args.arch, k=args.probe, seed=args.seed,
                        smoke=smoke)
        print(f"[dse]   probe:   {n} cells re-validated against the live "
              f"engine — clean")
    _flush_telemetry()
    return 0


if __name__ == "__main__":
    sys.exit(main())
