"""Step builders: jit-able train / prefill / decode steps with shardings.

``build_cell`` returns everything the dry-run, trainer, and server need for
one (arch × shape) cell: the step function, abstract arguments, and the
in/out shardings resolved from the logical-axis annotations against a mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import api
from ..models.sharding import Rules, constrain, logical_to_spec, rules_for, shardings_for_tree
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["CellSpec", "build_cell", "make_constrain"]


def make_constrain(rules: Rules):
    def c(x):
        if x.ndim == 3:
            return constrain(x, rules, "batch", "act_seq", None)
        if x.ndim == 4:  # q/k/v [B, S, H, hd] inside attention
            return constrain(x, rules, "batch", "act_seq", None, None)
        return x
    return c


def _batch_sharding(mesh: Mesh, rules: Rules, tree):
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = logical_to_spec(("batch",) + (None,) * (leaf.ndim - 1), rules,
                               mesh, shape=tuple(leaf.shape))
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, tree)


def _replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


@dataclasses.dataclass
class CellSpec:
    """One lowerable (arch × shape × mesh) cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    fn: Callable            # the step function (donation-ready)
    args: Tuple[Any, ...]   # abstract ShapeDtypeStruct arguments
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        with self.mesh:
            return self.jitted().lower(*self.args)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               adamw: Optional[AdamWConfig] = None,
               remat: bool = True) -> CellSpec:
    rules = rules_for(cfg.family)
    cons = make_constrain(rules)
    max_seq = shape.seq_len

    params_abs, logical = api.init_params(cfg, None, max_seq=max_seq)
    params_sh = shardings_for_tree(logical, params_abs, rules, mesh)
    specs = api.input_specs(cfg, shape)

    if shape.kind == "train":
        adamw = adamw or AdamWConfig()
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        opt_sh = {
            "m": params_sh, "v": params_sh,
            "step": NamedSharding(mesh, P()),
        }
        batch_abs = specs
        batch_sh = _batch_sharding(mesh, rules, batch_abs)

        def step(params, opt_state, batch):
            def lf(p):
                return api.loss(cfg, p, batch, constrain=cons, remat=remat)

            (l, ce), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_p, new_o, stats = adamw_update(adamw, params, grads, opt_state)
            return new_p, new_o, {"loss": l, "ce": ce, **stats}

        metrics_abs = {"loss": jax.ShapeDtypeStruct((), jnp.float32),
                       "ce": jax.ShapeDtypeStruct((), jnp.float32),
                       "grad_norm": jax.ShapeDtypeStruct((), jnp.float32),
                       "lr": jax.ShapeDtypeStruct((), jnp.float32)}
        return CellSpec(
            cfg, shape, mesh, step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, _replicated(mesh, metrics_abs)),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        batch_abs = specs
        batch_sh = _batch_sharding(mesh, rules, batch_abs)
        cache_abs, cache_logical = api.cache_shape(cfg, shape.global_batch, max_seq)
        cache_sh = shardings_for_tree(cache_logical, cache_abs, rules, mesh)
        logits_sh = NamedSharding(
            mesh, logical_to_spec(("batch", None, "vocab"), rules, mesh,
                                  shape=(shape.global_batch, 1, cfg.vocab)))

        def step(params, batch):
            return api.prefill(cfg, params, batch, max_seq, constrain=cons)

        return CellSpec(
            cfg, shape, mesh, step,
            args=(params_abs, batch_abs),
            in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, cache_sh),
        )

    if shape.kind == "decode":
        cache_abs, cache_logical = api.cache_shape(cfg, shape.global_batch, max_seq)
        cache_sh = shardings_for_tree(cache_logical, cache_abs, rules, mesh)
        token_abs = specs["token"]
        pos_abs = specs["pos"]
        token_sh = _batch_sharding(mesh, rules, token_abs)
        logits_sh = NamedSharding(
            mesh, logical_to_spec(("batch", None, "vocab"), rules, mesh,
                                  shape=(shape.global_batch, 1, cfg.vocab)))

        def step(params, cache, token, pos):
            return api.decode_step(cfg, params, cache, token, pos, constrain=cons)

        return CellSpec(
            cfg, shape, mesh, step,
            args=(params_abs, cache_abs, token_abs, pos_abs),
            in_shardings=(params_sh, cache_sh, token_sh, NamedSharding(mesh, P())),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(1,),
        )

    raise ValueError(shape.kind)
