"""End-to-end burst-checkpointed training driver.

Fault tolerance is the paper's Algorithm 1: train in bursts of k steps,
checkpoint + atomically commit the burst index after each burst, resume from
the committed index after any crash (the deterministic data pipeline
regenerates the exact batches). ``--crash-after-burst N`` injects a hard
process exit for testing; rerunning the same command resumes and converges
to the same trajectory.

On CPU this drives the reduced smoke configs (``--smoke``, default); the same
code path drives full configs on a real mesh.

Usage:
    python -m repro.launch.train --arch tinyllama-1.1b --steps 50 --smoke
    python -m repro.launch.train --arch tinyllama-1.1b --steps 50 --smoke \
        --crash-after-burst 2   # then rerun without the flag to resume
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.burst_ckpt import BurstCheckpointer, plan_burst_schedule
from ..configs import SMOKE_CONFIGS, get_config
from ..data.synthetic import SyntheticConfig, SyntheticData
from ..models import api
from ..models.sharding import rules_for, shardings_for_tree
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .mesh import make_host_mesh, make_production_mesh
from .steps import make_constrain


def train(arch: str, steps: int, batch: int, seq: int, burst_steps: int,
          ckpt_dir: str, smoke: bool = True, production_mesh: bool = False,
          crash_after_burst: int = -1, seed: int = 0, log_every: int = 10,
          lr: float = 1e-3):
    cfg = SMOKE_CONFIGS[arch] if smoke else get_config(arch)
    mesh = make_production_mesh() if production_mesh else make_host_mesh()
    rules = rules_for(cfg.family)
    cons = make_constrain(rules)
    adamw = AdamWConfig(lr=lr, warmup_steps=20)
    data = SyntheticData(SyntheticConfig(cfg.vocab, seq, batch, seed=seed))
    ck = BurstCheckpointer(ckpt_dir)

    def step_fn(params, opt_state, tokens, labels):
        def lf(p):
            batch_d = {"tokens": tokens, "labels": labels}
            if cfg.family == "vlm":
                batch_d["vision"] = jnp.zeros(
                    (tokens.shape[0], cfg.n_vision_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.family == "encdec":
                batch_d["audio"] = jnp.zeros(
                    (tokens.shape[0], cfg.n_audio_frames, cfg.d_model),
                    jnp.bfloat16)
            return api.loss(cfg, p, batch_d, constrain=cons, remat=True)

        (l, ce), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_o, stats = adamw_update(adamw, params, grads, opt_state)
        return new_p, new_o, l

    with mesh:
        restored = ck.restore()
        if restored is None:
            params, _ = api.init_params(cfg, jax.random.PRNGKey(seed), max_seq=seq)
            opt_state = adamw_init(params)
            start_burst = 0
            print(f"[train] fresh start: {arch} ({cfg.name}), "
                  f"{sum(np.prod(p.shape) for p in jax.tree.leaves(params)) / 1e6:.1f}M params")
        else:
            start_burst, state = restored
            params, opt_state = state["params"], state["opt_state"]
            print(f"[train] resumed from burst {start_burst} "
                  f"(step {start_burst * burst_steps})")

        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        n_bursts = (steps + burst_steps - 1) // burst_steps
        losses = []
        for burst in range(start_burst, n_bursts):
            t0 = time.time()
            for s in range(burst * burst_steps,
                           min((burst + 1) * burst_steps, steps)):
                b = data.batch(s)
                params, opt_state, loss = jstep(
                    params, opt_state, jnp.asarray(b["tokens"]),
                    jnp.asarray(b["labels"]))
                losses.append(float(loss))
                if s % log_every == 0:
                    print(f"[train] step {s:5d}  loss {float(loss):.4f}  "
                          f"({time.time() - t0:.1f}s into burst {burst})")
            ck.save(burst + 1, {"params": params, "opt_state": opt_state})
            print(f"[train] burst {burst + 1}/{n_bursts} committed "
                  f"({time.time() - t0:.1f}s)")
            if crash_after_burst == burst + 1:
                print("[train] injected crash! rerun to resume.")
                os._exit(1)
        print(f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}")
        return losses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--burst-steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--crash-after-burst", type=int, default=-1)
    ap.add_argument("--plan-bursts", action="store_true",
                    help="print the julienne checkpoint-cadence plan and exit")
    args = ap.parse_args(argv)
    if args.plan_bursts:
        part = plan_burst_schedule(args.steps, step_seconds=1.0,
                                   state_bytes=10**9, max_loss_seconds=60.0)
        print(part.summary())
        print("burst bounds:", part.bounds)
        return 0
    train(args.arch, args.steps, args.batch, args.seq, args.burst_steps,
          args.ckpt_dir, smoke=not args.full,
          production_mesh=args.production_mesh,
          crash_after_burst=args.crash_after_burst)
    return 0


if __name__ == "__main__":
    sys.exit(main())
