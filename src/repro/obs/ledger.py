"""Energy ledger: per-request / per-cycle attribution of tabulated draw.

Every successful energy cycle charges three categories, read straight off
the cycle's :class:`repro.core.burst.BurstDetail`:

- ``restore`` — the fixed activation cost E_s (``e_startup``) paid on every
  wake-from-power-loss,
- ``compute`` — the task energy executed in the cycle (``e_task``),
- ``commit`` — NVM transfer traffic (``e_read + e_write``) for loading and
  committing the burst's live set.

Crashed cycle attempts are recorded under the separate ``replay`` overhead
category: the admission controller reserved energy for each cycle *once*
(the tabulated draw), so energy burned by an attempt that failed to commit
is overhead on top of the reservation, not part of it. That split is exactly
what makes the conservation check work: for a drained run, the sum of the
three charged categories must equal the ``HarvestModel`` pool delta
(``energy_spent``) to within solver tolerance, while ``replay`` quantifies
the paper's activation-overhead figure per run.

Stdlib-only; the solver tolerance constants are imported lazily so
``repro.obs`` stays importable without numpy.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

__all__ = [
    "CATEGORIES",
    "CHARGED_CATEGORIES",
    "EnergyLedger",
    "LedgerEntry",
    "LedgerImbalance",
]

CHARGED_CATEGORIES = ("restore", "compute", "commit")
CATEGORIES = CHARGED_CATEGORIES + ("replay",)


class LedgerImbalance(AssertionError):
    """Ledger charged total disagrees with the harvest pool delta."""


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    rid: int
    cycle: int
    category: str
    energy: float
    vt: Optional[float] = None  # virtual-clock time, when the caller has one


def _tolerance() -> Tuple[float, float]:
    try:
        from ..core.partition import BUDGET_ABS, BUDGET_REL

        return BUDGET_REL, BUDGET_ABS
    except Exception:  # pragma: no cover - partition always importable in-repo
        return 1e-9, 1e-12


class EnergyLedger:
    """Append-only energy attribution with conservation checking."""

    def __init__(self):
        self.entries: List[LedgerEntry] = []

    # -- recording ---------------------------------------------------------

    def charge(
        self,
        rid: int,
        cycle: int,
        *,
        restore: float = 0.0,
        compute: float = 0.0,
        commit: float = 0.0,
        vt: Optional[float] = None,
    ) -> None:
        """Attribute one committed cycle's draw across the three categories."""
        for category, energy in (
            ("restore", restore),
            ("compute", compute),
            ("commit", commit),
        ):
            if energy:
                self.entries.append(LedgerEntry(rid, cycle, category, float(energy), vt))

    def overhead(self, rid: int, cycle: int, energy: float, vt: Optional[float] = None) -> None:
        """Record a crashed attempt's energy as replay overhead (outside the
        admission reservation — see module docstring)."""
        self.entries.append(LedgerEntry(rid, cycle, "replay", float(energy), vt))

    # -- aggregation -------------------------------------------------------

    def by_category(self) -> Dict[str, float]:
        out = {c: 0.0 for c in CATEGORIES}
        for e in self.entries:
            out[e.category] = out.get(e.category, 0.0) + e.energy
        return out

    def by_request(self, rid: int) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.entries:
            if e.rid == rid:
                out[e.category] = out.get(e.category, 0.0) + e.energy
        return out

    def charged_total(self) -> float:
        return sum(e.energy for e in self.entries if e.category != "replay")

    def overhead_total(self) -> float:
        return sum(e.energy for e in self.entries if e.category == "replay")

    def overhead_fraction(self) -> float:
        """Replay energy as a fraction of charged energy — the per-run analog
        of the paper's 0.12% activation-overhead figure. 0.0 on empty runs."""
        charged = self.charged_total()
        return self.overhead_total() / charged if charged else 0.0

    # -- conservation ------------------------------------------------------

    def conservation_error(self, pool_spent: float) -> float:
        """Absolute disagreement between charged total and the pool delta."""
        return abs(self.charged_total() - pool_spent)

    def conserves(self, pool_spent: float) -> bool:
        """True iff charged total equals ``pool_spent`` at solver tolerance
        (the same BUDGET_REL/BUDGET_ABS every feasibility check uses)."""
        rel, abs_tol = _tolerance()
        scale = max(abs(self.charged_total()), abs(pool_spent))
        return self.conservation_error(pool_spent) <= scale * rel + abs_tol

    def check_conservation(self, pool_spent: float) -> None:
        """Raise :class:`LedgerImbalance` unless the ledger conserves."""
        if not self.conserves(pool_spent):
            raise LedgerImbalance(
                f"energy ledger charged {self.charged_total()!r} but the "
                f"harvest pool spent {pool_spent!r} "
                f"(err={self.conservation_error(pool_spent):.3e})"
            )

    # -- export ------------------------------------------------------------

    def summary(self) -> Dict[str, float]:
        out = self.by_category()
        out["charged_total"] = self.charged_total()
        out["overhead_fraction"] = self.overhead_fraction()
        out["entries"] = len(self.entries)
        return out

    def to_rows(self) -> List[Dict[str, object]]:
        return [dataclasses.asdict(e) for e in self.entries]

    def sorted_rows(self) -> List[Dict[str, object]]:
        """:meth:`to_rows` in deterministic (rid, cycle) order. Append order
        depends on interleaving (the traffic harness commits many requests'
        cycles through one batched executor), so exports sort: the stable
        sort keeps each (rid, cycle)'s category rows in charge order while
        making the file — and any calibration fingerprint built from it —
        reproducible across schedules that charged the same work."""
        return [
            dataclasses.asdict(e)
            for e in sorted(self.entries, key=lambda e: (e.rid, e.cycle))
        ]

    def dump_json(self, path: str, **meta) -> None:
        payload = dict(meta)
        payload["summary"] = self.summary()
        payload["entries"] = self.sorted_rows()
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
