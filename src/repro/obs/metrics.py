"""Process-global metrics registry: counters, gauges, histograms, and the
registry-backed counter dicts behind the historical ``TRACE_COUNT`` /
``SOLVE_COUNT`` / ``COMMIT_STATS`` module globals.

Everything here is stdlib-only and importable without jax. All consumers use
the *snapshot-and-diff* pattern — absolute values are meaningless in a
process that has run other work — and tests get a clean baseline from one
:func:`reset_all` in the autouse conftest fixture.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Tuple, Union

__all__ = [
    "Counter",
    "CounterDict",
    "Gauge",
    "Histogram",
    "METRICS",
    "MetricsRegistry",
    "diff_snapshots",
    "reset_all",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Mapping[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Base class: a named instrument owned by one registry."""

    kind = "metric"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def reset(self) -> None:  # pragma: no cover - overridden
        raise NotImplementedError

    def snapshot(self):  # pragma: no cover - overridden
        raise NotImplementedError


class Counter(_Metric):
    """Monotonic counter, optionally split by labels.

    ``inc()`` with no labels accumulates under the empty label set; with
    labels (``c.inc(1, backend="numpy")``) each distinct label combination
    gets its own cell. ``snapshot()`` returns a plain dict keyed by a
    ``"k=v,k2=v2"`` string (``""`` for the unlabeled cell) so it JSON-dumps
    cleanly.
    """

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._cells: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1, **labels: str) -> None:
        key = _label_key(labels)
        self._cells[key] = self._cells.get(key, 0) + value

    def value(self, **labels: str) -> float:
        return self._cells.get(_label_key(labels), 0)

    def reset(self) -> None:
        self._cells.clear()

    def snapshot(self) -> Dict[str, float]:
        return {
            ",".join(f"{k}={v}" for k, v in key): val
            for key, val in sorted(self._cells.items())
        }


class Gauge(_Metric):
    """Last-write-wins value, optionally split by labels."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._cells: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: str) -> None:
        self._cells[_label_key(labels)] = value

    def value(self, **labels: str) -> Optional[float]:
        return self._cells.get(_label_key(labels))

    def reset(self) -> None:
        self._cells.clear()

    def snapshot(self) -> Dict[str, float]:
        return {
            ",".join(f"{k}={v}" for k, v in key): val
            for key, val in sorted(self._cells.items())
        }


class Histogram(_Metric):
    """Streaming count/sum/min/max summary (no stored samples, no buckets —
    enough for overhead accounting without unbounded memory)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self.reset()

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        if self.min is None or value < self.min:
            self.min = value
        if self.max is None or value > self.max:
            self.max = value

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def reset(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "mean": self.mean,
        }


class CounterDict(dict, _Metric):
    """A plain ``dict`` that is also a registered metric.

    This is the back-compat bridge for the historical module-global counter
    dicts (``serve.TRACE_COUNT``, ``runtime.COMMIT_STATS``, ...): existing
    code keeps doing ``TRACE_COUNT["prefill"] += 1`` and tests keep asserting
    ``TRACE_COUNT == {"prefill": 0, "decode": 0}``, while
    :func:`reset_all` now reaches them through the registry.
    """

    kind = "counter_dict"

    def __init__(self, name: str, keys: Iterable[str], help: str = ""):
        _Metric.__init__(self, name, help)
        self._initial_keys = tuple(keys)
        dict.__init__(self, {k: 0 for k in self._initial_keys})

    def reset(self) -> None:
        # Re-zero the *initial* schema and drop any ad-hoc keys added since,
        # matching the semantics of the old reset_* helpers which rebuilt the
        # dict contents in place.
        for k in [k for k in self if k not in self._initial_keys]:
            del self[k]
        for k in self._initial_keys:
            self[k] = 0

    def snapshot(self) -> Dict[str, float]:
        return dict(self)


class MetricsRegistry:
    """Owns every instrument; one ``snapshot()``/``reset()``/``diff()``."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, metric: _Metric) -> _Metric:
        with self._lock:
            existing = self._metrics.get(metric.name)
            if existing is not None and type(existing) is type(metric):
                return existing
            self._metrics[metric.name] = metric
            return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))  # type: ignore[return-value]

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._register(Histogram(name, help))  # type: ignore[return-value]

    def counter_dict(
        self, name: str, keys: Iterable[str], help: str = ""
    ) -> CounterDict:
        return self._register(CounterDict(name, keys, help))  # type: ignore[return-value]

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Plain-dict view of every registered instrument (JSON-safe)."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    def diff(
        self, before: Mapping[str, Mapping[str, float]]
    ) -> Dict[str, Dict[str, float]]:
        """Delta of the current snapshot against an earlier one; cells that
        did not change are omitted, so the result reads as "what this span
        of work did"."""
        return diff_snapshots(before, self.snapshot())

    def dump_json(self, path: str, **meta) -> None:
        payload = dict(meta)
        payload["metrics"] = self.snapshot()
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")


def diff_snapshots(
    before: Mapping[str, Mapping[str, float]],
    after: Mapping[str, Mapping[str, float]],
) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for name, cells in after.items():
        prev = before.get(name, {})
        if not isinstance(cells, Mapping):  # pragma: no cover - defensive
            continue
        delta = {}
        for key, val in cells.items():
            p = prev.get(key, 0)
            if isinstance(val, (int, float)) and isinstance(p, (int, float)):
                if val != p:
                    delta[key] = val - p
            elif val != p:
                delta[key] = val
        if delta:
            out[name] = delta
    return out


#: The process-global registry. Module-level counter dicts across the repo
#: register themselves here at import time.
METRICS = MetricsRegistry()


def reset_all() -> None:
    """Zero every registered instrument — the one reset behind the historical
    ``reset_trace_counts`` / ``reset_commit_stats`` / ``reset_stats`` trio."""
    METRICS.reset()
