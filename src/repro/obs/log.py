"""Structured logging emitter for CLI-facing progress lines.

``launch/traffic.py`` historically reported through bare ``print(f"[traffic]
...")`` calls, which made the harness noisy under pytest and impossible to
redirect. The emitter routes the same lines through :mod:`logging`:

- under the CLIs, :func:`enable_cli_output` attaches a plain
  ``[<tag>] message`` handler to the *current* ``sys.stdout`` (resolved at
  call time, so pytest's ``capsys`` still captures it), preserving the old
  stdout behavior byte-for-byte;
- under pytest / library use no handler is attached, so INFO records
  propagate nowhere and the harness is silent.

Structured fields ride on the record as ``record.fields`` for any future
JSON handler; the human formatter ignores them.
"""

from __future__ import annotations

import logging
import sys
from typing import IO, Optional

__all__ = ["Emitter", "enable_cli_output", "get_emitter"]

_CLI_HANDLER_FLAG = "_repro_cli_handler"


class Emitter:
    """Thin wrapper: ``emit("admitted 3/5", admitted=3, total=5)``."""

    def __init__(self, logger: logging.Logger):
        self.logger = logger

    def emit(self, message: str, **fields) -> None:
        self.logger.info(message, extra={"fields": fields})

    def warn(self, message: str, **fields) -> None:
        self.logger.warning(message, extra={"fields": fields})


def get_emitter(name: str) -> Emitter:
    """Emitter over ``logging.getLogger(name)`` (e.g. ``"repro.traffic"``)."""
    return Emitter(logging.getLogger(name))


def enable_cli_output(
    name: str, tag: Optional[str] = None, stream: Optional[IO[str]] = None
) -> logging.Handler:
    """Attach the CLI stdout handler to logger ``name`` (idempotent).

    ``tag`` defaults to the last dotted component of ``name``; lines render
    as ``[<tag>] message`` exactly like the old prints. The stream default is
    resolved *here*, not at import, so test harnesses that swap
    ``sys.stdout`` see the output.
    """
    logger = logging.getLogger(name)
    resolved = stream if stream is not None else sys.stdout
    for h in logger.handlers:
        if getattr(h, _CLI_HANDLER_FLAG, False):
            # Rebind to the current stdout: successive CLI runs under a test
            # harness each get a fresh replaced stream.
            if getattr(h, "stream", None) is not resolved:
                try:
                    h.setStream(resolved)  # type: ignore[attr-defined]
                except ValueError:
                    # setStream flushes the old stream first; a test harness
                    # may have closed it (capsys teardown) — rebind directly
                    h.stream = resolved  # type: ignore[attr-defined]
            return h
    handler = logging.StreamHandler(resolved)
    setattr(handler, _CLI_HANDLER_FLAG, True)
    tag = tag if tag is not None else name.rsplit(".", 1)[-1]
    handler.setFormatter(logging.Formatter(f"[{tag}] %(message)s"))
    logger.addHandler(handler)
    logger.setLevel(logging.INFO)
    logger.propagate = False
    return handler


def disable_cli_output(name: str) -> None:
    """Detach any CLI handler previously attached by :func:`enable_cli_output`."""
    logger = logging.getLogger(name)
    for h in list(logger.handlers):
        if getattr(h, _CLI_HANDLER_FLAG, False):
            logger.removeHandler(h)
