"""Unified telemetry for the Julienning stack.

Three zero-dependency pieces (stdlib only — importable from `repro.core`
without dragging in jax):

- :mod:`repro.obs.metrics` — a process-global registry of named counters,
  gauges, and histograms with label support and one
  ``snapshot()``/``reset()``/``diff()`` API. The historical ad-hoc counter
  dicts (``TRACE_COUNT`` ×3, ``SOLVE_COUNT``, ``COMMIT_STATS``) are now
  registry-backed dict subclasses, so every existing snapshot-and-diff pin
  keeps working unchanged and one :func:`repro.obs.metrics.reset_all` zeroes
  everything.
- :mod:`repro.obs.trace` — a span tracer emitting Chrome ``trace_event``
  JSON loadable in Perfetto (https://ui.perfetto.dev). Spans carry wall-clock
  timestamps (the trace timeline) and, where the caller has one, the
  harness's virtual-clock time in ``args.vt``. Disabled by default; when
  disabled every ``span()`` returns a shared no-op context manager and hot
  paths guard on ``TRACER.enabled`` so tracing costs one attribute check.
- :mod:`repro.obs.ledger` — per-request / per-cycle attribution of tabulated
  energy draw into restore (E_s), compute, and NVM-commit categories, plus a
  replay-overhead category, with a conservation check against the
  ``HarvestModel`` pool delta at solver tolerance.
"""

from . import ledger, log, metrics, trace  # noqa: F401
from .ledger import EnergyLedger
from .metrics import METRICS, reset_all
from .trace import TRACER

__all__ = [
    "METRICS",
    "TRACER",
    "EnergyLedger",
    "ledger",
    "log",
    "metrics",
    "reset_all",
    "trace",
]
