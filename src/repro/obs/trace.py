"""Span tracer emitting Chrome ``trace_event`` JSON (Perfetto-loadable).

Design points:

- **Disabled by default, near-zero cost when off.** ``TRACER.span(...)``
  returns a shared no-op context manager when disabled; hot paths (plan-table
  lookup, ``BurstRuntime`` bursts, the traffic step loop) additionally guard
  on ``TRACER.enabled`` so the disabled cost is one attribute check — the
  ``telemetry_overhead`` benchmark section pins this.
- **Two clocks.** The trace timeline (``ts``/``dur``) is wall-clock
  microseconds from ``time.perf_counter()`` relative to the moment tracing
  was enabled — that is what Perfetto renders. Callers that live on the
  traffic harness's virtual clock pass ``vt=...`` and the virtual timestamp
  rides along in the event ``args`` so both timelines are recoverable.
- **Tracks.** ``pid``/``tid`` pairs map to Perfetto tracks; ``set_process``
  / ``set_thread`` emit the ``ph:"M"`` metadata events that name them. The
  traffic harness uses one tid per request plus scheduler and harvest
  tracks; solver/plan-table spans live on their own pid.

Event phases used: ``X`` (complete span, ``ts``+``dur``), ``i`` (instant),
``C`` (counter series, e.g. the harvest pool charge), ``M`` (metadata).
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "TRACER",
    "Tracer",
    "PID_TRAFFIC",
    "PID_SOLVER",
    "PID_RUNTIME",
    "PID_SWARM",
    "TID_SCHEDULER",
    "TID_HARVEST",
    "request_tid",
    "node_tid",
]

# Track layout shared by all instrumented call sites. Request tracks are
# allocated as TID_REQUEST_BASE + rid (see request_tid); swarm node tracks
# as TID_NODE_BASE + node index (see node_tid) on the swarm pid.
PID_TRAFFIC = 1
PID_SOLVER = 2
PID_RUNTIME = 3
PID_SWARM = 4
TID_SCHEDULER = 0
TID_HARVEST = 1
TID_REQUEST_BASE = 100
TID_NODE_BASE = 200


def request_tid(rid: int) -> int:
    """Perfetto thread id for request ``rid``'s per-request track."""
    return TID_REQUEST_BASE + int(rid)


def node_tid(node: int) -> int:
    """Perfetto thread id for swarm node ``node``'s per-node track
    (one track per harvesting device on the :data:`PID_SWARM` process)."""
    return TID_NODE_BASE + int(node)


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **args: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """An open ``ph:"X"`` complete event; closing the context records it."""

    __slots__ = ("_tracer", "name", "cat", "pid", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, pid: int, tid: int, args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.pid = pid
        self.tid = tid
        self.args = args

    def set(self, **args: Any) -> None:
        """Attach additional args to the span before it closes."""
        self.args.update(args)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        tracer = self._tracer
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        ev: Dict[str, Any] = {
            "name": self.name,
            "cat": self.cat or "repro",
            "ph": "X",
            "ts": (self._t0 - tracer._t0) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": self.pid,
            "tid": self.tid,
        }
        if self.args:
            ev["args"] = self.args
        tracer._events.append(ev)
        return False


class Tracer:
    """Process-global event collector; see module docstring for the model."""

    def __init__(self):
        self.enabled = False
        self._events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._tracks: Dict[Any, str] = {}

    # -- lifecycle ---------------------------------------------------------

    def configure(self, enabled: bool = True, clear: bool = True) -> None:
        """Turn tracing on/off. ``clear`` drops buffered events and re-zeroes
        the wall-clock origin so a fresh capture starts at ts=0."""
        if clear:
            self._events = []
            self._tracks = {}
            self._t0 = time.perf_counter()
        self.enabled = enabled

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        self.configure(enabled=False, clear=True)

    # -- track naming ------------------------------------------------------

    def set_process(self, pid: int, name: str) -> None:
        if not self.enabled or ("p", pid) in self._tracks:
            return
        self._tracks[("p", pid)] = name
        self._events.append(
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0, "args": {"name": name}}
        )

    def set_thread(self, pid: int, tid: int, name: str) -> None:
        if not self.enabled or ("t", pid, tid) in self._tracks:
            return
        self._tracks[("t", pid, tid)] = name
        self._events.append(
            {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid, "args": {"name": name}}
        )

    # -- event emission ----------------------------------------------------

    def span(self, name: str, cat: str = "", pid: int = PID_TRAFFIC, tid: int = TID_SCHEDULER, **args: Any):
        """Context manager timing a nested span. No-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, pid, tid, args)

    def instant(self, name: str, cat: str = "", pid: int = PID_TRAFFIC, tid: int = TID_SCHEDULER, **args: Any) -> None:
        """Point-in-time event (admit/defer/reject, NVM commit, crash...)."""
        if not self.enabled:
            return
        ev: Dict[str, Any] = {
            "name": name,
            "cat": cat or "repro",
            "ph": "i",
            "ts": (time.perf_counter() - self._t0) * 1e6,
            "pid": pid,
            "tid": tid,
            "s": "t",  # thread-scoped instant
        }
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, name: str, values: Dict[str, float], pid: int = PID_TRAFFIC, tid: int = TID_HARVEST) -> None:
        """Counter-series sample (rendered as a filled chart in Perfetto)."""
        if not self.enabled:
            return
        self._events.append(
            {
                "name": name,
                "cat": "repro",
                "ph": "C",
                "ts": (time.perf_counter() - self._t0) * 1e6,
                "pid": pid,
                "tid": tid,
                "args": dict(values),
            }
        )

    # -- export ------------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def chrome_trace(self) -> Dict[str, Any]:
        """The JSON object Perfetto / chrome://tracing loads directly."""
        return {"traceEvents": list(self._events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the number of events."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")
        return len(self._events)


#: Process-global tracer shared by every instrumented call site.
TRACER = Tracer()
