"""DeepSeek-Coder-33B — llama-arch [arXiv:2401.14196; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32256, source="arXiv:2401.14196",
))

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
    vocab=256, source="smoke",
)
