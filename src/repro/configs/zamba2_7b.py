"""Zamba2-7B — Mamba2 blocks + shared attention [arXiv:2411.15242; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_expand=2, ssm_headdim=64, attn_every=6,
    source="arXiv:2411.15242",
))

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab=256, ssm_state=16, ssm_expand=2, ssm_headdim=16, attn_every=2,
    source="smoke",
)
