"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=8,
    source="arXiv:2405.04517",
))

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
    vocab=256, slstm_every=2, source="smoke",
)
