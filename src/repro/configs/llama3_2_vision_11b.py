"""Llama-3.2-11B-Vision — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only: the vision tower is a stub; ``input_specs()`` provides
precomputed patch embeddings (n_vision_tokens × d_model).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, cross_attn_every=5, n_vision_tokens=1601, rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=256, cross_attn_every=2, n_vision_tokens=17, source="smoke",
)
