"""Architecture configuration system.

One :class:`ModelConfig` per assigned architecture (see the sibling modules),
plus named :class:`ShapeConfig` workloads (train_4k / prefill_32k / decode_32k
/ long_500k). Every field is static metadata — configs never touch jax device
state, so they are safe to import anywhere (including before the dry-run sets
XLA_FLAGS).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["MoEConfig", "ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "register", "get_config"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention flavor
    qkv_bias: bool = False          # qwen1.5
    qk_norm: bool = False           # qwen3
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # MoE
    moe: Optional[MoEConfig] = None
    # SSM / hybrid
    ssm_state: int = 0              # mamba2 d_state (zamba2) — 0 = no ssm
    ssm_expand: int = 2
    ssm_headdim: int = 64
    slstm_every: int = 0            # xlstm: every k-th block is sLSTM (0 = none)
    attn_every: int = 0             # zamba2: shared attention every k-th block
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500      # encoder input length (frontend stub)
    # vlm
    cross_attn_every: int = 0       # llama-3.2-vision: cross-attn layer period
    n_vision_tokens: int = 1601
    # numerics
    norm_eps: float = 1e-5
    # notes for DESIGN/EXPERIMENTS provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_subquadratic(self) -> bool:
        """May run the long_500k shape (SSM / hybrid / linear attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        embed = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d

        def attn_params() -> int:
            return d * n_q + 2 * d * n_kv + n_q * d + (
                (n_q + 2 * n_kv) if self.qkv_bias else 0
            )

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gated (SwiGLU): w1, w3, w2

        total = embed + head + 2 * d  # final norm (+pos stub)
        if self.family in ("dense", "vlm"):
            per = attn_params() + mlp_params(self.d_ff) + 2 * d
            total += self.n_layers * per
            if self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                total += n_cross * (attn_params() + 2 * d)
        elif self.family == "moe":
            m = self.moe
            assert m is not None
            per = attn_params() + 2 * d + d * m.n_experts  # router
            per += m.n_experts * 3 * d * m.d_ff_expert
            total += self.n_layers * per
        elif self.family == "encdec":
            per_enc = attn_params() + 2 * d * self.d_ff + 2 * d  # GELU mlp: w1,w2
            per_dec = 2 * attn_params() + 2 * d * self.d_ff + 3 * d
            total += self.n_encoder_layers * per_enc + self.n_layers * per_dec
        elif self.family == "ssm":  # xlstm
            d_in = 2 * d  # expanded mLSTM inner dim
            per = 2 * d * d_in + d_in * d + 3 * d * (d_in // 4) + 2 * d
            total += self.n_layers * per
        elif self.family == "hybrid":  # zamba2
            d_in = self.ssm_expand * d
            per_mamba = d * (2 * d_in) + d_in * d + d_in  # in/out proj + dt
            total += self.n_layers * per_mamba
            if self.attn_every:
                total += attn_params() + mlp_params(self.d_ff) + 2 * d  # shared block
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        assert m is not None
        inactive = self.n_layers * (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import the arch modules lazily so REGISTRY is populated
    from . import ALL_ARCHS  # noqa: F401

    if name not in REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch × shape) is a runnable cell; reason if skipped.

    Skips follow DESIGN.md §Shape-skips: long_500k is sub-quadratic-only.
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""
