"""Assigned-architecture configs (one module per arch) + the paper's app."""

from . import (  # noqa: F401
    deepseek_coder_33b,
    granite_moe_1b,
    llama3_2_vision_11b,
    phi3_5_moe,
    qwen1_5_0_5b,
    qwen3_4b,
    tinyllama_1_1b,
    whisper_large_v3,
    xlstm_1_3b,
    zamba2_7b,
)
from .base import REGISTRY, SHAPES, ModelConfig, ShapeConfig, get_config, shape_applicable

ALL_ARCHS = sorted(REGISTRY)


def resolve_config(cfg, smoke: bool = False) -> ModelConfig:
    """The one (config-or-arch-name, smoke) → :class:`ModelConfig` mapping.

    Accepts a ready :class:`ModelConfig` (passed through untouched) or a
    registry arch name, resolved against the smoke registry when ``smoke``
    — shared by the façade (:mod:`repro.core.engine`), the plan-table
    builders, and the launch CLIs, which used to each carry their own copy.
    """
    if isinstance(cfg, ModelConfig):
        return cfg
    if not isinstance(cfg, str):
        raise TypeError(
            f"expected a ModelConfig or arch name, got {type(cfg).__name__}"
        )
    if smoke:
        try:
            return SMOKE_CONFIGS[cfg]
        except KeyError:
            raise KeyError(
                f"unknown smoke arch {cfg!r}; known: {sorted(SMOKE_CONFIGS)}"
            ) from None
    return get_config(cfg)

SMOKE_CONFIGS = {
    "xlstm-1.3b": xlstm_1_3b.SMOKE,
    "qwen1.5-0.5b": qwen1_5_0_5b.SMOKE,
    "qwen3-4b": qwen3_4b.SMOKE,
    "tinyllama-1.1b": tinyllama_1_1b.SMOKE,
    "deepseek-coder-33b": deepseek_coder_33b.SMOKE,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe.SMOKE,
    "granite-moe-1b-a400m": granite_moe_1b.SMOKE,
    "whisper-large-v3": whisper_large_v3.SMOKE,
    "zamba2-7b": zamba2_7b.SMOKE,
    "llama-3.2-vision-11b": llama3_2_vision_11b.SMOKE,
}
