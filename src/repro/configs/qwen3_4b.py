"""Qwen3-4B — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
))

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=256, head_dim=16, qk_norm=True, source="smoke",
)
