"""Whisper-large-v3 — enc-dec, conv frontend stub [arXiv:2212.04356; unverified].

The assignment specifies the transformer BACKBONE only: ``input_specs()``
provides precomputed 1500×d_model frame embeddings (the conv frontend stub).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_encoder_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, n_audio_frames=1500,
    source="arXiv:2212.04356",
))

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=256, n_audio_frames=16, source="smoke",
)
