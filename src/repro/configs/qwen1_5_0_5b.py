"""Qwen1.5-0.5B — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    source="hf:Qwen/Qwen1.5-0.5B",
))

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
    vocab=256, qkv_bias=True, tie_embeddings=True, source="smoke",
)
