"""Runtime-at-scale soak (ROADMAP): a 500+-task reduced head-count graph
executed through :class:`BurstRuntime` on :class:`DirNVM` under ≥20
randomized, seeded crash schedules.

Every schedule injects power failures at random (burst, phase) sites via
``crash_hook`` — including repeated crashes of the same burst — and half the
schedules additionally simulate full *reboots* by rebuilding the runtime
object from the on-disk NVM between activations. Final outputs (and every
persisted NVM packet file) must bit-match a crash-free run, the paper's
consistency argument at scale.
"""

import os
import pickle
import random

import numpy as np
import pytest

from repro.core import (
    BurstRuntime,
    DirNVM,
    PAPER_FRAM_MODEL,
    PowerFailure,
    execute_atomic,
    optimal_partition,
    q_min,
)
from repro.core.apps.headcount import VISUAL, build_graph

pytestmark = [pytest.mark.slow,  # ~30 s of repeated 550-task executions
              pytest.mark.legacy]  # drives the legacy optimal_partition shim

CM = PAPER_FRAM_MODEL
N_SCHEDULES = 20
CRASH_P = 0.12          # per-(burst, phase) crash probability
MAX_CRASHES = 60        # per schedule, so every schedule terminates


class RandomCrashes:
    """Seeded random PowerFailure injection at any (burst, phase) site."""

    def __init__(self, seed: int):
        self.rng = random.Random(seed)
        self.fired = 0

    def __call__(self, b: int, phase: str) -> None:
        if self.fired < MAX_CRASHES and self.rng.random() < CRASH_P:
            self.fired += 1
            raise PowerFailure(f"injected at burst {b} @ {phase}")


@pytest.fixture(scope="module")
def soak_case(tmp_path_factory):
    """(graph, partition, atomic reference, crash-free DirNVM packet bytes)."""
    graph = build_graph(VISUAL.reduced(10), with_fns=True)
    assert graph.n_tasks >= 500, "soak graph must be large-scale"
    part = optimal_partition(graph, CM, q_min(graph, CM) * 1.5)
    assert part.n_bursts >= 20, "soak partition should have many crash sites"
    ref = execute_atomic(graph, {})

    clean_dir = tmp_path_factory.mktemp("nvm_clean")
    rt = BurstRuntime(graph, part, DirNVM(str(clean_dir)), cost=CM)
    out = rt.run()
    assert rt.stats.bursts_run == part.n_bursts
    for name in ref:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(ref[name]), err_msg=name)
    clean_pkts = _packet_bytes(str(clean_dir))
    assert clean_pkts, "crash-free run persisted no packets"
    return graph, part, ref, clean_pkts


def _packet_bytes(nvm_dir: str):
    out = {}
    for fname in sorted(os.listdir(nvm_dir)):
        if fname.startswith("pkt_") and fname.endswith(".pkl"):
            with open(os.path.join(nvm_dir, fname), "rb") as fh:
                out[fname] = fh.read()
    return out


def _run_with_reboots(graph, part, nvm, hook, max_activations=10_000):
    """Each activation uses a *fresh* BurstRuntime over the same DirNVM —
    the strongest recovery claim: nothing survives but the NVM directory."""
    total_tasks = 0
    for _ in range(max_activations):
        rt = BurstRuntime(graph, part, nvm, cost=CM, crash_hook=hook)
        try:
            out = rt.run()
            total_tasks += rt.stats.tasks_run
            return out, total_tasks
        except PowerFailure:
            total_tasks += rt.stats.tasks_run
            continue
    raise RuntimeError("did not complete within max_activations")


@pytest.mark.parametrize("seed", range(N_SCHEDULES))
def test_randomized_crash_schedule_bitmatches_clean_run(
    seed, soak_case, tmp_path
):
    graph, part, ref, clean_pkts = soak_case
    hook = RandomCrashes(1000 + seed)
    nvm = DirNVM(str(tmp_path / "nvm"))

    if seed % 2 == 0:
        # in-place recovery: one runtime rides through all failures
        rt = BurstRuntime(graph, part, nvm, cost=CM, crash_hook=hook)
        out = rt.run_to_completion({})
        tasks_run = rt.stats.tasks_run
    else:
        # reboot recovery: a fresh runtime per activation, state from disk only
        out, tasks_run = _run_with_reboots(graph, part, nvm, hook)

    assert hook.fired >= 1, "schedule injected no crashes — vacuous"
    assert nvm.read_index() == part.n_bursts
    if hook.fired:
        assert tasks_run > graph.n_tasks or hook.fired <= part.n_bursts

    # outputs bit-match the atomic reference
    assert set(out) == set(ref)
    for name in ref:
        a, b = np.asarray(out[name]), np.asarray(ref[name])
        assert a.dtype == b.dtype and a.shape == b.shape, name
        np.testing.assert_array_equal(a, b, err_msg=name)
        assert pickle.dumps(a, protocol=pickle.HIGHEST_PROTOCOL) == \
            pickle.dumps(b, protocol=pickle.HIGHEST_PROTOCOL), name

    # every persisted NVM packet is byte-identical to the crash-free run's
    pkts = _packet_bytes(str(tmp_path / "nvm"))
    assert set(pkts) == set(clean_pkts)
    for fname, blob in pkts.items():
        assert blob == clean_pkts[fname], f"NVM file {fname} diverged"
