"""Differential/property tier for the sharded DSE subsystem.

Locks in the three builder paths of the offline design-space exploration:

* **sharded = single-host**: builds on 1/2/4/8 (emulated) devices are
  byte-identical to the single-host table for every smoke config — payload
  arrays, header fingerprint, and content digest all match, and
  ``ServePlanner`` lookups against a sharded table match direct engine
  solves bit-exactly;
* **incremental = fresh**: a bucket/Q grid randomly split into
  ``extend_plan_table`` steps applied in shuffled order reassembles the
  fresh full build bit-for-bit, while an extend of an untouched base never
  re-solves an existing cell (pinned by ``SOLVE_COUNT``);
* **staleness probe**: accepts every clean table and rejects any table with
  one perturbed cell or a mismatched engine config.

The property checks run under a stdlib-``random`` seeded driver always, and
additionally under hypothesis when it is installed (the test_partition.py
idiom). Under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
shard tier) the 2/4/8-shard builds pmap across a real device mesh; on a
one-device host the same chunk decomposition runs sequentially — both must
produce identical bytes, so the suite is environment-agnostic.
"""

import random

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from conftest import PLAN_BUCKETS
from helpers_random import random_cost_model, random_q_grid, random_task_graph

from repro.configs import SMOKE_CONFIGS
from repro.core import (
    PlanTable,
    PlanTableError,
    StaleTableError,
    build_plan_table,
    extend_plan_table,
    lower_config,
    probe_plan_table,
    q_min,
    shard_plan_table,
    shard_q_grid,
    sweep_jax,
    sweep_jax_batched,
    sweep_jax_sharded,
    whole_app_partition,
)
from repro.core import partition_jax
from repro.core import plan_table as pt_mod
from repro.launch.planner import ServePlanner
import repro.launch.planner as planner_mod

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SHARD_COUNTS = (1, 2, 4, 8)


def _clone(table: PlanTable) -> PlanTable:
    return PlanTable(
        dict(table.header),
        *(getattr(table, name).copy() for name in PlanTable._PAYLOAD),
    )


def _assert_tables_bitidentical(a: PlanTable, b: PlanTable) -> None:
    assert a.fingerprint == b.fingerprint
    assert a.header == b.header
    for name in PlanTable._PAYLOAD:
        x, y = getattr(a, name), getattr(b, name)
        assert x.dtype == y.dtype and x.shape == y.shape, name
        assert x.tobytes() == y.tobytes(), f"{name} bytes differ"
    assert a.content_digest() == b.content_digest()


# -- engine level: sharded sweep == batched sweep ------------------------------


def test_shard_q_grid_is_balanced_and_covering():
    assert shard_q_grid(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert shard_q_grid(3, 8) == [(0, 1), (1, 2), (2, 3)]  # clamped
    assert shard_q_grid(5, 1) == [(0, 5)]
    for nq, ns in [(1, 1), (7, 3), (100, 8)]:
        chunks = shard_q_grid(nq, ns)
        assert chunks[0][0] == 0 and chunks[-1][1] == nq
        assert all(lo < hi for lo, hi in chunks)
        assert all(a[1] == b[0] for a, b in zip(chunks, chunks[1:]))
        assert max(hi - lo for lo, hi in chunks) - min(
            hi - lo for lo, hi in chunks) <= 1
    with pytest.raises(ValueError):
        shard_q_grid(0, 2)
    with pytest.raises(ValueError):
        shard_q_grid(4, 0)


@pytest.mark.parametrize("seed", range(5))
def test_sweep_jax_sharded_matches_batched(seed):
    """Random-graph batches: every output array of the sharded sweep is
    byte-identical to the one-call batched sweep, at every shard count."""
    rng = random.Random(seed)
    graphs = [random_task_graph(rng, max_tasks=7) for _ in range(3)]
    cm = random_cost_model(rng)
    qmn = max(q_min(g, cm) for g in graphs)
    whole = max(whole_app_partition(g, cm).e_total for g in graphs)
    qs = random_q_grid(rng, qmn, whole)
    ref = sweep_jax_batched(graphs, cm, qs, backend="scan")
    for n_shards in (1, 2, 3, len(qs)):
        got = sweep_jax_sharded(graphs, cm, qs, n_shards=n_shards,
                                backend="scan")
        for g_idx, (r, s) in enumerate(zip(ref, got)):
            assert r.n_tasks == s.n_tasks
            for field in ("dp", "parent", "e_total", "feasible", "starts"):
                a, b = getattr(r, field), getattr(s, field)
                assert a.tobytes() == b.tobytes(), (n_shards, g_idx, field)


def test_sweep_jax_sharded_pallas_chunks_match():
    """The CSR/Pallas backend shards as host-side Q chunks — still
    bit-identical (the kernel lanes the Q axis per call)."""
    rng = random.Random(7)
    g = random_task_graph(rng, max_tasks=8, min_tasks=4)
    cm = random_cost_model(rng)
    qs = random_q_grid(rng, q_min(g, cm), whole_app_partition(g, cm).e_total)
    ref = sweep_jax_batched([g], cm, qs, backend="pallas")
    got = sweep_jax_sharded([g], cm, qs, n_shards=3, backend="pallas")
    for field in ("dp", "parent", "e_total", "feasible", "starts"):
        assert getattr(ref[0], field).tobytes() == \
            getattr(got[0], field).tobytes(), field


# -- table level: sharded builds are byte-identical ----------------------------


@pytest.mark.parametrize("arch", sorted(SMOKE_CONFIGS))
def test_sharded_build_bitidentical_to_single_host(arch, smoke_plan_table):
    """Every smoke config: 1/2/4/8-shard builds replay the single-host
    bytes exactly (npz payload + header fingerprint + content digest)."""
    cfg, cm, qs, single = smoke_plan_table(arch)
    for n_shards in SHARD_COUNTS:
        sharded = shard_plan_table(
            cfg, PLAN_BUCKETS, qs, n_shards=n_shards, cost=cm
        )
        _assert_tables_bitidentical(single, sharded)


def test_sharded_save_load_roundtrip_preserves_digest(tmp_path,
                                                      smoke_plan_table):
    _, _, _, table = smoke_plan_table("qwen3-4b", builder=shard_plan_table,
                                      n_shards=4)
    path = str(tmp_path / "sharded.npz")
    table.save(path)
    loaded = PlanTable.load(path)
    _assert_tables_bitidentical(table, loaded)


def test_sharded_table_lookups_match_direct_solves(smoke_plan_table):
    """ServePlanner against a sharded table answers every (bucket, Q) with
    bounds/energies bit-identical to direct engine solves."""
    cfg, cm, qs, table = smoke_plan_table("zamba2-7b",
                                          builder=shard_plan_table,
                                          n_shards=4)
    planner = ServePlanner(table)
    n_feasible = 0
    for (b, s) in PLAN_BUCKETS:
        g = lower_config(cfg, b, s, kind="time")
        direct = sweep_jax(g, cm, qs)
        for qi, q in enumerate(qs):
            if not direct.feasible[qi]:
                continue
            n_feasible += 1
            plan = planner.plan_for(b, s, q)
            assert list(plan.bounds) == direct.bounds(qi), (b, s, q)
            assert plan.e_total == direct.e_total[qi]
    assert n_feasible and planner.stats["lookups"] == n_feasible


# -- incremental extension -----------------------------------------------------


def test_extend_of_untouched_base_never_solves(smoke_plan_table):
    cfg, cm, _, base = smoke_plan_table("tinyllama-1.1b")
    solves = dict(partition_jax.SOLVE_COUNT)
    stats = dict(pt_mod.BUILD_STATS)
    out = extend_plan_table(base, cfg, cost=cm)
    assert out is base
    # re-adding already-tabulated cells is also a no-op
    out = extend_plan_table(
        base, cfg, add_buckets=PLAN_BUCKETS, add_q_values=base.q_values(),
        cost=cm,
    )
    assert out is base
    assert dict(partition_jax.SOLVE_COUNT) == solves, \
        "untouched extend must not hit the engine"
    assert dict(pt_mod.BUILD_STATS) == stats


def test_extend_solves_only_new_cells(plan_grid):
    """Growing (2 buckets × 4 Q) → (3 × 6) re-solves nothing tabulated:
    exactly one batched call for the new bucket × final grid and one for the
    old buckets × new Q points, and the old cells' bytes are moved, not
    recomputed."""
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cm, qs = plan_grid(cfg)
    base = build_plan_table(cfg, PLAN_BUCKETS[:2], [qs[0], qs[2], qs[4], qs[5]],
                            cost=cm)
    fresh = build_plan_table(cfg, PLAN_BUCKETS, qs, cost=cm)
    solves = dict(partition_jax.SOLVE_COUNT)
    ext = extend_plan_table(
        base, cfg, add_buckets=[PLAN_BUCKETS[2]], add_q_values=[qs[1], qs[3]],
        cost=cm,
    )
    delta = {k: partition_jax.SOLVE_COUNT[k] - solves[k] for k in solves}
    assert delta == {"sweep_jax": 0, "sweep_jax_batched": 2,
                     "sweep_jax_sharded": 0, "q_min_scan": 0,
                     "optimal_k_scan": 0, "q_min_pallas": 0,
                     "optimal_k_pallas": 0}
    _assert_tables_bitidentical(
        _strip_lineage(ext), _strip_lineage(fresh)
    )
    # provenance: the chain records base → extension, fresh is a single link
    assert ext.lineage == [base.fingerprint, fresh.fingerprint]
    assert fresh.lineage == [fresh.fingerprint]
    # old cells were byte-moved from the base table
    b_old = base.buckets().index(PLAN_BUCKETS[0])
    e_old = ext.buckets().index(PLAN_BUCKETS[0])
    for q in base.q_values():
        k_old = base.q_values().index(q)
        k_new = ext.q_values().index(q)
        assert base.e_total[b_old, k_old] == ext.e_total[e_old, k_new]


def _strip_lineage(table: PlanTable) -> PlanTable:
    out = _clone(table)
    out.header.pop("lineage", None)
    return out


def test_extend_sharded_matches_fresh(plan_grid):
    """Sharded extension solves land on the same bytes."""
    cfg = SMOKE_CONFIGS["whisper-large-v3"]
    cm, qs = plan_grid(cfg)
    base = build_plan_table(cfg, PLAN_BUCKETS[:1], qs, cost=cm)
    fresh = build_plan_table(cfg, PLAN_BUCKETS, qs, cost=cm)
    ext = extend_plan_table(base, cfg, add_buckets=PLAN_BUCKETS[1:], cost=cm,
                            n_shards=4)
    assert ext.content_digest() == fresh.content_digest()


def test_extend_rejects_mismatched_engine_config(plan_grid, smoke_plan_table):
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cm, qs = plan_grid(cfg)
    _, _, _, base = smoke_plan_table("qwen3-4b")
    other = SMOKE_CONFIGS["xlstm-1.3b"]
    with pytest.raises(PlanTableError):
        extend_plan_table(base, other, add_buckets=[(8, 64)], cost=cm)


def test_planner_cli_shard_and_extend_roundtrip(tmp_path):
    """--shards builds and --extend grows the on-disk table; the grown table
    is content-identical to a fresh build of the same final grid."""
    out = str(tmp_path / "cli.npz")
    assert planner_mod.main(
        ["--arch", "qwen3-4b", "--buckets", "2x16,2x24", "--q-points", "5",
         "--out", out, "--shards", "2", "--probe", "3"]
    ) == 0
    base = PlanTable.load(out)
    assert base.buckets() == [(2, 16), (2, 24)]
    assert planner_mod.main(
        ["--arch", "qwen3-4b", "--buckets", "2x16,2x24,2x32", "--out", out,
         "--extend", "--shards", "2"]
    ) == 0
    grown = PlanTable.load(out)
    assert grown.buckets() == [(2, 16), (2, 24), (2, 32)]
    assert grown.lineage[0] == base.fingerprint and len(grown.lineage) == 2
    fresh = build_plan_table(
        SMOKE_CONFIGS["qwen3-4b"], grown.buckets(), grown.q_values(),
        cost=pt_mod._default_cost("time"),
    )
    assert grown.content_digest() == fresh.content_digest()


# -- staleness probe -----------------------------------------------------------


def test_probe_accepts_clean_tables(smoke_plan_table):
    for arch in ("qwen3-4b", "xlstm-1.3b"):
        cfg, cm, _, table = smoke_plan_table(arch)
        assert probe_plan_table(table, cfg, k=4, cost=cm) == 4
        assert probe_plan_table(table, cfg, k=None, cost=cm) == \
            table.n_buckets * table.n_q


def test_probe_rejects_any_single_perturbed_cell(smoke_plan_table):
    """Every feasible cell, perturbed alone (e_total, a cycle energy, or a
    segment bound), turns the full probe into a StaleTableError; flipping
    any feasibility flag does too."""
    cfg, cm, _, table = smoke_plan_table("qwen3-4b")
    nb, nq = table.feasible.shape
    probed = 0
    for b in range(nb):
        for k in range(nq):
            flipped = _clone(table)
            flipped.feasible[b, k] = not flipped.feasible[b, k]
            with pytest.raises(StaleTableError):
                probe_plan_table(flipped, cfg, k=None, cost=cm)
            if not table.feasible[b, k]:
                continue
            probed += 1
            bad = _clone(table)
            bad.e_total[b, k] = np.nextafter(bad.e_total[b, k], np.inf)
            with pytest.raises(StaleTableError):
                probe_plan_table(bad, cfg, k=None, cost=cm)
            lo = int(table.seg_ptr[b * nq + k])
            bad = _clone(table)
            bad.cycle_energy[lo] = np.nextafter(bad.cycle_energy[lo], np.inf)
            with pytest.raises(StaleTableError):
                probe_plan_table(bad, cfg, k=None, cost=cm)
            bad = _clone(table)
            bad.seg_end[lo] = bad.seg_end[lo] + 1 if \
                bad.seg_end[lo] < table.n_tasks[b] else bad.seg_end[lo] - 1
            with pytest.raises(StaleTableError):
                probe_plan_table(bad, cfg, k=None, cost=cm)
    assert probed  # the grid straddles feasibility, so some cells are live


def test_probe_rejects_mismatched_engine_config(smoke_plan_table):
    from repro.core import PAPER_FRAM_MODEL

    cfg, cm, _, table = smoke_plan_table("qwen3-4b")
    with pytest.raises(StaleTableError):
        probe_plan_table(table, cfg, k=2, cost=PAPER_FRAM_MODEL)
    with pytest.raises(StaleTableError):
        probe_plan_table(table, SMOKE_CONFIGS["xlstm-1.3b"], k=2, cost=cm)


def test_from_file_probe_wiring(tmp_path, smoke_plan_table):
    cfg, cm, _, table = smoke_plan_table("whisper-large-v3")
    path = str(tmp_path / "probed.npz")
    table.save(path)
    planner = ServePlanner.from_file(path, probe=cfg, probe_k=3)
    assert planner.table.fingerprint == table.fingerprint
    bad = _clone(table)
    bad.e_total[0, -1] = np.nextafter(bad.e_total[0, -1], np.inf)
    bad.save(path)
    with pytest.raises(StaleTableError):
        ServePlanner.from_file(path, probe=cfg, probe_k=None)


# -- property: shuffled incremental assembly == fresh build --------------------


def check_shuffled_extension_chain(cfg, cm, qs, rng: random.Random):
    """Randomly split PLAN_BUCKETS × qs into a base build plus extension
    steps, apply the steps in shuffled order, and require the final table to
    be content-identical to the fresh full build (with the lineage chain one
    link per applied step)."""
    buckets = list(PLAN_BUCKETS)
    n_base_b = rng.randint(1, len(buckets))
    n_base_q = rng.randint(1, len(qs))
    base_buckets = rng.sample(buckets, n_base_b)
    base_qs = rng.sample(qs, n_base_q)
    rest_b = [b for b in buckets if b not in base_buckets]
    rest_q = [q for q in qs if q not in base_qs]

    steps = []
    for b in rest_b:
        steps.append(("bucket", b))
    for q in rest_q:
        steps.append(("q", q))
    rng.shuffle(steps)
    # group the shuffled atoms into 1..3 extension calls
    n_calls = rng.randint(1, min(3, len(steps))) if steps else 0
    calls = [steps[i::n_calls] for i in range(n_calls)] if n_calls else []

    table = build_plan_table(cfg, base_buckets, base_qs, cost=cm)
    applied = 1
    for call in calls:
        add_b = [x for kind_, x in call if kind_ == "bucket"]
        add_q = [x for kind_, x in call if kind_ == "q"]
        table = extend_plan_table(table, cfg, add_buckets=add_b,
                                  add_q_values=add_q, cost=cm)
        applied += 1
    fresh = build_plan_table(cfg, buckets, qs, cost=cm)
    assert table.content_digest() == fresh.content_digest()
    assert table.fingerprint == fresh.fingerprint
    assert len(table.lineage) == applied
    assert table.lineage[-1] == fresh.fingerprint


@pytest.mark.parametrize("seed", range(6))
def test_shuffled_extension_chain_seeded(seed, plan_grid):
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cm, qs = plan_grid(cfg)
    check_shuffled_extension_chain(cfg, cm, qs, random.Random(seed))


if HAVE_HYPOTHESIS:

    class TestShuffledExtensionFuzz:
        @settings(max_examples=12, deadline=None)
        @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
        def test_any_extension_order_reassembles_fresh_build(
            self, seed, plan_grid
        ):
            cfg = SMOKE_CONFIGS["qwen3-4b"]
            cm, qs = plan_grid(cfg)
            check_shuffled_extension_chain(cfg, cm, qs, random.Random(seed))

else:

    def test_extension_fuzz_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")
