"""Sharding resolver invariants: dedupe, divisibility, greedy axis skipping."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import logical_to_spec, rules_for

pytestmark = pytest.mark.skipif(len(jax.devices()) < 1, reason="no devices")


def fake_mesh(shape, axes):
    """AbstractMesh stands in for a device mesh (no allocation)."""
    from jax.sharding import AbstractMesh

    return AbstractMesh(shape, axes)


SINGLE = fake_mesh((16, 16), ("data", "model"))
MULTI = fake_mesh((2, 16, 16), ("pod", "data", "model"))


class TestResolver:
    def test_dense_train_batch(self):
        r = rules_for("dense")
        spec = logical_to_spec(("batch", "act_seq", None), r, SINGLE,
                               shape=(256, 4096, 1024))
        assert spec == P(("data",), ("model",)) or spec == P("data", "model")

    def test_no_duplicate_axes_in_one_spec(self):
        r = rules_for("ssm")
        # batch wants (data, model, pod); kv_seq wants (data, model):
        # whatever batch takes, kv_seq must not reuse
        spec = logical_to_spec(("batch", "kv_seq"), r, SINGLE,
                               shape=(128, 32768))
        used = []
        for entry in spec:
            if entry is None:
                continue
            used.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(used) == len(set(used))

    def test_greedy_skips_non_dividing_axis(self):
        # batch=128 on multi-pod ssm rules: model (16·16=256) does not divide,
        # but pod (·2) after skipping model does → (data, pod)
        r = rules_for("ssm")
        spec = logical_to_spec(("batch",), r, MULTI, shape=(128,))
        axes = spec[0]
        axes = axes if isinstance(axes, tuple) else (axes,)
        assert "data" in axes and "pod" in axes and "model" not in axes

    def test_batch_one_replicated(self):
        r = rules_for("ssm")
        spec = logical_to_spec(("batch", "kv_seq"), r, MULTI,
                               shape=(1, 524288))
        assert spec[0] is None  # batch=1 cannot shard
        kv = spec[1] if len(spec) > 1 else None
        assert kv is not None  # kv_seq takes the freed axes

    def test_unknown_logical_raises(self):
        with pytest.raises(KeyError):
            logical_to_spec(("nope",), rules_for("dense"), SINGLE, shape=(8,))

    def test_smoke_mesh_all_replicated(self):
        tiny = fake_mesh((1, 1), ("data", "model"))
        r = rules_for("dense")
        spec = logical_to_spec(("batch", "act_seq", None), r, tiny,
                               shape=(2, 32, 64))
        # 1-sized axes technically divide; spec may name them but they are
        # size-1 → effectively replicated. Just ensure it resolves.
        assert isinstance(spec, P)
