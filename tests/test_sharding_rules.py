"""Sharding resolver invariants: dedupe, divisibility, greedy axis skipping."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import logical_to_spec, rules_for

try:
    from jax.sharding import AbstractMesh
except ImportError:  # pre-0.4.31 jax has no AbstractMesh at all
    AbstractMesh = None

pytestmark = [
    pytest.mark.skipif(len(jax.devices()) < 1, reason="no devices"),
    pytest.mark.skipif(AbstractMesh is None, reason="AbstractMesh unavailable"),
]


def fake_mesh(shape, axes):
    """AbstractMesh stands in for a device mesh (no allocation).

    The constructor signature changed across jax releases: newer versions
    take ``(axis_sizes, axis_names)``, 0.4.x takes a single tuple of
    ``(name, size)`` pairs. Try the new form first and fall back.
    """
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


SINGLE = fake_mesh((16, 16), ("data", "model")) if AbstractMesh else None
MULTI = fake_mesh((2, 16, 16), ("pod", "data", "model")) if AbstractMesh else None


class TestResolver:
    def test_dense_train_batch(self):
        r = rules_for("dense")
        spec = logical_to_spec(("batch", "act_seq", None), r, SINGLE,
                               shape=(256, 4096, 1024))
        assert spec == P(("data",), ("model",)) or spec == P("data", "model")

    def test_no_duplicate_axes_in_one_spec(self):
        r = rules_for("ssm")
        # batch wants (data, model, pod); kv_seq wants (data, model):
        # whatever batch takes, kv_seq must not reuse
        spec = logical_to_spec(("batch", "kv_seq"), r, SINGLE,
                               shape=(128, 32768))
        used = []
        for entry in spec:
            if entry is None:
                continue
            used.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(used) == len(set(used))

    def test_greedy_skips_non_dividing_axis(self):
        # batch=128 on multi-pod ssm rules: model (16·16=256) does not divide,
        # but pod (·2) after skipping model does → (data, pod)
        r = rules_for("ssm")
        spec = logical_to_spec(("batch",), r, MULTI, shape=(128,))
        axes = spec[0]
        axes = axes if isinstance(axes, tuple) else (axes,)
        assert "data" in axes and "pod" in axes and "model" not in axes

    def test_batch_one_replicated(self):
        r = rules_for("ssm")
        spec = logical_to_spec(("batch", "kv_seq"), r, MULTI,
                               shape=(1, 524288))
        assert spec[0] is None  # batch=1 cannot shard
        kv = spec[1] if len(spec) > 1 else None
        assert kv is not None  # kv_seq takes the freed axes

    def test_unknown_logical_raises(self):
        with pytest.raises(KeyError):
            logical_to_spec(("nope",), rules_for("dense"), SINGLE, shape=(8,))

    def test_smoke_mesh_all_replicated(self):
        tiny = fake_mesh((1, 1), ("data", "model"))
        r = rules_for("dense")
        spec = logical_to_spec(("batch", "act_seq", None), r, tiny,
                               shape=(2, 32, 64))
        # 1-sized axes technically divide; spec may name them but they are
        # size-1 → effectively replicated. Just ensure it resolves.
        assert isinstance(spec, P)
