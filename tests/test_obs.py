"""Unified telemetry (repro.obs): metrics registry semantics, Chrome
trace_event export schema, energy-ledger conservation, and the zero-division
guards on rate fields.

Fast tier throughout — the trace/ledger integration tests drive the real
TrafficHarness over the synthetic-chain executor from tests/test_traffic.py
(no jax). The real-model `--trace-out` CLI path runs in the slow tier of
tests/test_traffic.py and in CI's traffic smoke.
"""

import json

import pytest

from test_traffic import (
    E_STARTUP,
    E_TOTAL,
    GEN,
    FakeTable,
    SyntheticExecutor,
    _req,
)


# -- metrics registry --------------------------------------------------------


def test_counter_labels_and_snapshot_diff():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    c = reg.counter("solves")
    c.inc()
    c.inc(2, backend="numpy")
    c.inc(1, backend="scan")
    before = reg.snapshot()
    assert before["solves"] == {"": 1, "backend=numpy": 2, "backend=scan": 1}
    c.inc(5, backend="numpy")
    assert reg.diff(before) == {"solves": {"backend=numpy": 5}}
    reg.reset()
    assert reg.snapshot()["solves"] == {}
    assert c.value(backend="numpy") == 0


def test_gauge_and_histogram():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    g = reg.gauge("charge")
    g.set(1.5)
    g.set(0.5)
    assert g.value() == 0.5
    h = reg.histogram("latency_ms")
    for v in (2.0, 4.0, 6.0):
        h.observe(v)
    snap = reg.snapshot()["latency_ms"]
    assert snap == {"count": 3, "sum": 12.0, "min": 2.0, "max": 6.0, "mean": 4.0}
    reg.reset()
    assert reg.snapshot()["latency_ms"]["count"] == 0


def test_registry_reregistration_returns_same_instrument():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    a = reg.counter("x")
    b = reg.counter("x")
    assert a is b
    d1 = reg.counter_dict("y", ("k",))
    d2 = reg.counter_dict("y", ("k",))
    assert d1 is d2


def test_counter_dict_is_plain_dict_to_consumers():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    d = reg.counter_dict("trace", ("prefill", "decode"))
    assert d == {"prefill": 0, "decode": 0}
    d["prefill"] += 3
    assert dict(d) == {"prefill": 3, "decode": 0}
    d["adhoc"] = 7  # ad-hoc keys are allowed but dropped on reset
    reg.reset()
    assert d == {"prefill": 0, "decode": 0}


def test_reset_all_covers_the_legacy_counter_dicts():
    """The historical reset trio is now one reset_all(); the old names stay
    as thin aliases and plain-dict equality (pinned by the serving tests)
    still holds."""
    from repro.core import runtime
    from repro.obs.metrics import METRICS, reset_all

    runtime.COMMIT_STATS["commits"] += 5
    runtime.COMMIT_STATS["replays"] += 2
    assert METRICS.get("runtime.commit_stats") is runtime.COMMIT_STATS
    reset_all()
    assert runtime.COMMIT_STATS == {"commits": 0, "replays": 0}
    # the alias keeps working
    runtime.COMMIT_STATS["commits"] += 1
    runtime.reset_commit_stats()
    assert runtime.COMMIT_STATS == {"commits": 0, "replays": 0}


def test_metrics_dump_json_roundtrip(tmp_path):
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.counter("n").inc(4)
    path = tmp_path / "metrics.json"
    reg.dump_json(str(path), tool="test")
    payload = json.loads(path.read_text())
    assert payload["tool"] == "test"
    assert payload["metrics"]["n"] == {"": 4}


# -- span tracer -------------------------------------------------------------


def _fresh_tracer():
    from repro.obs.trace import Tracer

    t = Tracer()
    t.configure(enabled=True)
    return t


def test_tracer_disabled_is_noop():
    from repro.obs.trace import Tracer

    t = Tracer()
    assert not t.enabled
    with t.span("work", answer=42):
        pass
    t.instant("tick")
    t.counter("charge", {"charge": 1.0})
    assert t.events() == []
    # the disabled span is one shared object — no per-call allocation
    assert t.span("a") is t.span("b")


def test_span_schema_and_nesting():
    t = _fresh_tracer()
    with t.span("outer", cat="test", pid=7, tid=3, depth=0):
        with t.span("inner", cat="test", pid=7, tid=3, depth=1):
            pass
    t.instant("blip", pid=7, tid=3)
    events = t.events()
    assert [e["name"] for e in events] == ["inner", "outer", "blip"]
    for e in events:
        assert set(e) >= {"name", "ph", "ts", "pid", "tid"}
        assert e["ts"] >= 0
    inner, outer, blip = events
    assert inner["ph"] == outer["ph"] == "X"
    assert blip["ph"] == "i"
    # monotonic nesting: inner is contained in outer on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_span_records_exception_and_reraises():
    t = _fresh_tracer()
    with pytest.raises(ValueError):
        with t.span("boom"):
            raise ValueError("x")
    (ev,) = t.events()
    assert ev["args"]["error"] == "ValueError"


def test_track_metadata_and_write(tmp_path):
    t = _fresh_tracer()
    t.set_process(1, "traffic")
    t.set_thread(1, 100, "request 0")
    t.set_thread(1, 100, "request 0")  # idempotent
    with t.span("cycle", tid=100, vt=2.5):
        pass
    path = tmp_path / "trace.json"
    n = t.write(str(path))
    payload = json.loads(path.read_text())
    assert payload["displayTimeUnit"] == "ms"
    events = payload["traceEvents"]
    assert len(events) == n == 3
    meta = [e for e in events if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    (cycle,) = [e for e in events if e["ph"] == "X"]
    assert cycle["args"]["vt"] == 2.5


# -- energy ledger -----------------------------------------------------------


def test_ledger_charge_overhead_and_conservation():
    from repro.obs.ledger import EnergyLedger, LedgerImbalance

    led = EnergyLedger()
    led.charge(0, 0, restore=0.1, compute=0.75, commit=0.0, vt=1.0)
    led.charge(0, 1, restore=0.1, compute=0.25)
    led.overhead(0, 1, 0.35)
    cat = led.by_category()
    assert cat["restore"] == pytest.approx(0.2)
    assert cat["compute"] == pytest.approx(1.0)
    assert cat["replay"] == pytest.approx(0.35)
    assert led.charged_total() == pytest.approx(1.2)
    assert led.overhead_total() == pytest.approx(0.35)
    assert led.overhead_fraction() == pytest.approx(0.35 / 1.2)
    assert led.by_request(0)["compute"] == pytest.approx(1.0)
    led.check_conservation(1.2)  # replay excluded by design
    assert not led.conserves(1.0)
    with pytest.raises(LedgerImbalance):
        led.check_conservation(1.0)


def test_empty_ledger_guards():
    from repro.obs.ledger import EnergyLedger

    led = EnergyLedger()
    assert led.overhead_fraction() == 0.0
    assert led.conserves(0.0)
    assert led.summary()["entries"] == 0


def test_ledger_dump_json(tmp_path):
    from repro.obs.ledger import EnergyLedger

    led = EnergyLedger()
    led.charge(3, 0, restore=0.1, compute=0.2, vt=4.0)
    path = tmp_path / "ledger.json"
    led.dump_json(str(path), run="test")
    payload = json.loads(path.read_text())
    assert payload["run"] == "test"
    assert payload["summary"]["charged_total"] == pytest.approx(0.3)
    assert payload["entries"][0] == {
        "rid": 3, "cycle": 0, "category": "restore", "energy": 0.1, "vt": 4.0,
    }


def test_ledger_dump_json_rows_sorted_by_rid_cycle(tmp_path):
    """Regression: dump_json exports rows in deterministic (rid, cycle)
    order regardless of charge order, so calibration fingerprints built
    from a dumped ledger don't depend on the traffic schedule."""
    from repro.obs.ledger import EnergyLedger

    led = EnergyLedger()
    # charge in a schedule-ish interleaved order: rid 2 first, rid 0 last
    led.charge(2, 0, restore=0.1, compute=0.2)
    led.charge(1, 1, compute=0.4)
    led.overhead(1, 0, 0.05)
    led.charge(1, 0, restore=0.1)
    led.charge(0, 0, commit=0.3)
    path = tmp_path / "ledger.json"
    led.dump_json(str(path))
    rows = json.loads(path.read_text())["entries"]
    keys = [(r["rid"], r["cycle"]) for r in rows]
    assert keys == sorted(keys)
    assert keys[0] == (0, 0) and keys[-1] == (2, 0)
    # stable within one (rid, cycle): replay was appended before the charge
    rid1c0 = [r["category"] for r in rows if (r["rid"], r["cycle"]) == (1, 0)]
    assert rid1c0 == ["replay", "restore"]
    # in-memory to_rows() keeps raw append order — only the export sorts
    assert [(r["rid"], r["cycle"]) for r in led.to_rows()][0] == (2, 0)


def test_ledger_dump_json_interleaving_invariant(tmp_path):
    """Two schedules charging the same (rid, cycle, category, energy) set
    in different orders dump byte-identical entry lists."""
    import random

    from repro.obs.ledger import EnergyLedger

    rng = random.Random(17)
    charges = [(rid, cyc, rng.uniform(0.01, 1.0), rng.uniform(0.0, 0.5))
               for rid in range(3) for cyc in range(4)]
    a, b = EnergyLedger(), EnergyLedger()
    for rid, cyc, compute, commit in charges:
        a.charge(rid, cyc, restore=0.1, compute=compute, commit=commit)
    rng.shuffle(charges)
    for rid, cyc, compute, commit in charges:
        b.charge(rid, cyc, restore=0.1, compute=compute, commit=commit)
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    a.dump_json(str(pa))
    b.dump_json(str(pb))
    assert (json.loads(pa.read_text())["entries"]
            == json.loads(pb.read_text())["entries"])


# -- ledger properties under random request/cycle/crash schedules ------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_ledger_schedule(rng):
    """Build a ledger from a random request/cycle/crash schedule, returning
    (ledger, expected per-category totals, expected per-rid totals,
    expected overhead total)."""
    from repro.obs.ledger import CHARGED_CATEGORIES, EnergyLedger

    led = EnergyLedger()
    by_cat = {c: 0.0 for c in CHARGED_CATEGORIES}
    by_rid = {}
    overhead = 0.0
    events = []
    for rid in range(rng.randint(1, 5)):
        for cycle in range(rng.randint(1, 6)):
            for _ in range(rng.randint(0, 2)):  # crashed attempts first
                events.append(("crash", rid, cycle, rng.uniform(0.0, 0.5)))
            events.append(("commit", rid, cycle, rng.uniform(0.0, 0.2),
                           rng.uniform(0.0, 1.0), rng.uniform(0.0, 0.1)))
    rng.shuffle(events)  # schedule interleaving is arbitrary
    for ev in events:
        if ev[0] == "crash":
            _, rid, cycle, e = ev
            led.overhead(rid, cycle, e)
            overhead += e
        else:
            _, rid, cycle, restore, compute, commit = ev
            led.charge(rid, cycle, restore=restore, compute=compute,
                       commit=commit)
            req = by_rid.setdefault(rid, {c: 0.0 for c in CHARGED_CATEGORIES})
            for cat, e in (("restore", restore), ("compute", compute),
                           ("commit", commit)):
                by_cat[cat] += e
                req[cat] += e
    return led, by_cat, by_rid, overhead


def check_ledger_schedule_invariants(rng):
    from repro.obs.ledger import CHARGED_CATEGORIES, LedgerImbalance

    led, by_cat, by_rid, overhead = _random_ledger_schedule(rng)
    charged = sum(by_cat.values())
    # conservation: charged categories sum to the total; replay is booked
    # outside the admission reservation by design
    assert led.charged_total() == pytest.approx(charged, rel=1e-12)
    assert led.overhead_total() == pytest.approx(overhead, rel=1e-12)
    assert led.conserves(charged)
    if charged > 0:
        with pytest.raises(LedgerImbalance):
            led.check_conservation(charged * 1.5 + 1.0)
    # by_category / by_request sum consistency
    cat = led.by_category()
    for c in CHARGED_CATEGORIES:
        assert cat[c] == pytest.approx(by_cat[c], rel=1e-12, abs=1e-15)
        per_req = sum(led.by_request(rid)[c] for rid in by_rid)
        assert per_req == pytest.approx(cat[c], rel=1e-12, abs=1e-15)
    assert cat["replay"] == pytest.approx(overhead, rel=1e-12, abs=1e-15)
    for rid, want in by_rid.items():
        got = led.by_request(rid)
        for c in CHARGED_CATEGORIES:
            assert got[c] == pytest.approx(want[c], rel=1e-12, abs=1e-15)


def test_ledger_random_schedule_invariants_seeded():
    import random

    for seed in range(25):
        check_ledger_schedule_invariants(random.Random(seed))


def test_ledger_crash_heavy_schedule_overhead_fraction():
    """All-crash schedules keep charged_total at 0 and the overhead
    fraction guard still divides safely."""
    from repro.obs.ledger import EnergyLedger

    led = EnergyLedger()
    for attempt in range(4):
        led.overhead(0, 0, 0.25)
    assert led.charged_total() == 0.0
    assert led.overhead_total() == pytest.approx(1.0)
    assert led.overhead_fraction() == 0.0  # guard: no charged base
    assert led.conserves(0.0)


if HAVE_HYPOTHESIS:

    class TestLedgerHypothesis:
        @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
        @settings(max_examples=50, deadline=None)
        def test_random_schedule_invariants(self, seed):
            import random

            check_ledger_schedule_invariants(random.Random(seed))

else:

    def test_ledger_fuzz_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")


# -- CLI log stream rebind ---------------------------------------------------


def test_cli_output_rebinds_over_closed_stream():
    """Regression: a second CLI run must survive the previous run's stream
    having been closed under it (pytest capsys teardown) — setStream
    flushes the old stream, which raises on a closed file."""
    import io

    from repro.obs.log import disable_cli_output, enable_cli_output

    name = "repro.test_rebind"
    try:
        first = io.StringIO()
        enable_cli_output(name, tag="t", stream=first)
        first.close()
        second = io.StringIO()
        h = enable_cli_output(name, tag="t", stream=second)  # must not raise
        assert h.stream is second
        import logging

        logging.getLogger(name).info("alive")
        assert second.getvalue() == "[t] alive\n"
    finally:
        disable_cli_output(name)


# -- zero-division guards (satellite regression tests) -----------------------


def test_hit_rate_guard_zero_lookups():
    from repro.launch.planner import ServePlanner

    planner = ServePlanner(FakeTable([(1, 8)]))
    assert planner.hit_rate == 0.0


def test_traffic_report_rate_guards_zero_duration():
    from repro.launch.traffic import TrafficReport

    report = TrafficReport()
    assert report.requests_per_s == 0.0
    assert report.latency_percentiles_ms() == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert report.retraces == 0


def test_empty_run_reports_zero_rates():
    from repro.launch.planner import ServePlanner
    from repro.launch.traffic import TrafficHarness

    planner = ServePlanner(FakeTable([(1, 8)]))
    report = TrafficHarness(SyntheticExecutor(planner)).run([])
    assert report.arrived == report.completed == 0
    assert report.hit_rate == 0.0
    assert report.requests_per_s == 0.0
    assert report.ledger_conserved is True
    assert report.ledger_conservation_error == 0.0


# -- harness integration: trace export + ledger conservation -----------------


def _validate_chrome_trace(payload):
    """Schema checks for Perfetto-loadable trace_event JSON: required keys
    per phase, and monotonic (properly nested) spans per (pid, tid) track."""
    assert set(payload) >= {"traceEvents"}
    spans_by_track = {}
    for e in payload["traceEvents"]:
        assert set(e) >= {"name", "ph", "pid", "tid"}
        if e["ph"] == "M":
            continue
        assert "ts" in e and e["ts"] >= 0
        if e["ph"] == "X":
            assert "dur" in e and e["dur"] >= 0
            spans_by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    # events are appended at close time, so within a track each span must
    # either contain or be disjoint from every earlier-closing span
    for track, spans in spans_by_track.items():
        for i, a in enumerate(spans):
            for b in spans[i + 1:]:
                a0, a1 = a["ts"], a["ts"] + a["dur"]
                b0, b1 = b["ts"], b["ts"] + b["dur"]
                nested = b0 <= a0 + 1e-6 and a1 <= b1 + 1e-6
                disjoint = a1 <= b0 + 1e-6 or b1 <= a0 + 1e-6
                assert nested or disjoint, (track, a["name"], b["name"])


def _traced_run(requests, **harness_kw):
    from repro.launch.planner import ServePlanner
    from repro.launch.traffic import TrafficHarness
    from repro.obs.trace import TRACER

    planner = ServePlanner(FakeTable([(1, 8), (2, 8)]))
    harness = TrafficHarness(SyntheticExecutor(planner), **harness_kw)
    TRACER.configure(enabled=True)
    try:
        report = harness.run(requests)
        payload = TRACER.chrome_trace()
    finally:
        TRACER.reset()
    return report, payload


def test_traced_run_exports_per_request_tracks():
    from repro.launch.traffic import HarvestModel
    from repro.obs.trace import PID_TRAFFIC, request_tid

    # at Q=0.4 each request splits into 3 one-step cycles paying E_s each:
    # 3 × (0.1 + 0.25) = 1.05 energy units; capacity 1.2 holds one request
    # at a time and the slow trickle (0.1/t) forces the second arrival to
    # defer until the pool refills
    report, payload = _traced_run(
        [_req(0), _req(1, t=0.5)],
        harvest=HarvestModel(capacity=1.2, rate=0.1),
        cycle_budget=0.4,
    )
    assert report.completed == 2
    _validate_chrome_trace(payload)
    events = payload["traceEvents"]
    # one named track per request, plus scheduler/harvest tracks
    thread_names = {
        (e["pid"], e["tid"]): e["args"]["name"]
        for e in events if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert thread_names[(PID_TRAFFIC, request_tid(0))] == "request 0"
    assert thread_names[(PID_TRAFFIC, request_tid(1))] == "request 1"
    assert "scheduler" in thread_names.values()
    assert "harvest" in thread_names.values()
    # request 0's track carries its lifecycle instants and cycle spans
    r0 = [e for e in events if e.get("tid") == request_tid(0)
          and e["ph"] in ("i", "X")]
    kinds = [e["name"] for e in r0]
    assert kinds[0] == "arrive"
    assert "admit" in kinds and "complete" in kinds
    cycles = [e for e in r0 if e["name"] == "cycle"]
    assert len(cycles) == 3  # gen=3 at Q=0.4 → 3 cycles
    assert [c["args"]["cycle"] for c in cycles] == [0, 1, 2]
    assert all("vt" in c["args"] for c in cycles)
    # pool too small for both at once → the deferred request shows it
    assert "defer" in [e["name"] for e in events
                       if e.get("tid") == request_tid(1)]
    # harvest track carries counter samples of the pool charge
    assert any(e["ph"] == "C" and e["name"] == "harvest_charge"
               for e in events)
    # burst runtime spans landed on their own process
    assert any(e["ph"] == "X" and e["name"] == "burst" for e in events)


def test_ledger_conservation_on_synthetic_traffic():
    from repro.launch.traffic import HarvestModel

    e_req = 3 * (E_STARTUP + E_TOTAL)  # 3 one-step cycles at Q=0.4
    report, _ = _traced_run(
        [_req(i, t=0.3 * i) for i in range(4)],
        harvest=HarvestModel(capacity=2 * e_req, rate=0.5),
        cycle_budget=0.4,
    )
    assert report.completed == 4
    assert report.ledger_conserved is True
    assert report.energy_spent == pytest.approx(4 * e_req)
    cat = report.energy_ledger
    # 4 requests × 3 cycles, each cycle pays E_s once
    assert cat["restore"] == pytest.approx(4 * 3 * E_STARTUP)
    assert cat["compute"] == pytest.approx(4 * GEN * E_TOTAL)
    assert cat["commit"] == 0.0  # synthetic cost model prices transfers at 0
    assert cat["replay"] == 0.0
    assert (cat["restore"] + cat["compute"]
            == pytest.approx(report.energy_spent))


def test_crash_replay_attributed_as_overhead():
    """A mid-run PowerFailure books the lost attempt as replay overhead:
    conservation still holds against the pool (the replayed energy was never
    reserved), the trace shows the power_failure instant, and the report's
    overhead fraction is the paper's per-run activation-overhead figure."""
    from repro.core import PowerFailure
    from repro.launch.traffic import HarvestModel
    from repro.obs.trace import request_tid

    class CrashOnce:
        fired = False

        def __call__(self, b, phase):
            if not self.fired and b == 1 and phase == "executed":
                CrashOnce.fired = True
                raise PowerFailure(f"injected at burst {b}")

    report, payload = _traced_run(
        [_req(0)],
        harvest=HarvestModel(capacity=2 * 3 * (E_STARTUP + E_TOTAL), rate=1.0),
        cycle_budget=0.4,
        crash_hook_factory=lambda r: CrashOnce(),
    )
    assert CrashOnce.fired
    assert report.completed == 1 and report.power_failures == 1
    _validate_chrome_trace(payload)
    cat = report.energy_ledger
    # the crashed cycle-1 attempt costs E_s + one step, booked as replay
    e_req = 3 * (E_STARTUP + E_TOTAL)  # 3 one-step cycles at Q=0.4
    assert cat["replay"] == pytest.approx(E_STARTUP + E_TOTAL)
    assert report.ledger_conserved is True
    assert report.energy_spent == pytest.approx(e_req)
    assert report.ledger_overhead_fraction == pytest.approx(
        (E_STARTUP + E_TOTAL) / e_req)
    names = [e["name"] for e in payload["traceEvents"]
             if e.get("tid") == request_tid(0)]
    assert "power_failure" in names
    # ledger rows pin the replayed cycle index
    replays = [e for e in report.ledger.entries if e.category == "replay"]
    assert [(e.rid, e.cycle) for e in replays] == [(0, 1)]


def test_engine_solve_emits_spans():
    from repro.api import PartitionSpec, solve
    from repro.core import CostModel, GraphBuilder, LinearTransfer
    from repro.obs.trace import PID_SOLVER, TRACER

    b = GraphBuilder()
    b.packet("x", 8, external=True)
    b.packet("y", 8, keep=True)
    b.task("t0", reads=("x",), writes=("y",), cost=1.0)
    g = b.build()
    cm = CostModel(e_startup=0.1, read=LinearTransfer(0.0, 0.0),
                   write=LinearTransfer(0.0, 0.0), name="test")
    TRACER.configure(enabled=True)
    try:
        solve(PartitionSpec(graph=g, cost=cm, q_max=2.0, backend="numpy"))
        events = TRACER.events()
    finally:
        TRACER.reset()
    solves = [e for e in events if e["name"] == "engine.solve"]
    assert len(solves) == 1
    assert solves[0]["pid"] == PID_SOLVER
    assert solves[0]["args"]["backend"] == "numpy"
    assert any(e["name"] == "engine.dispatch" for e in events)
