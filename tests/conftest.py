"""Test bootstrap: import paths, marker registration, shared fixtures.

Makes ``repro`` importable without an install (the repo is src-layout and has
no setup.py) and the sibling test helpers importable regardless of how pytest
was invoked.

The plan-table fixtures below are the single source of the smoke-config
table-build helpers shared by tests/test_plan_table.py,
tests/test_serve_plan.py, and tests/test_dse_shard.py (they used to be
duplicated per module). All repro imports stay inside the fixture bodies so
collection never pays the jax import.
"""

import os
import sys

import pytest

_TESTS = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_TESTS), "src")
for _p in (_SRC, _TESTS):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running model-zoo smoke / kernel sweeps "
        "(deselect with -m 'not slow' for the fast tier-1 job)",
    )
    config.addinivalue_line(
        "markers",
        "legacy: intentionally exercises the deprecated solver entry points "
        "(the pre-façade differential/byte-identity pins). The CI "
        "deprecation-gate step runs the fast tier with "
        "-W error::DeprecationWarning and -m 'not legacy', proving no "
        "internal module still routes through a legacy entry point.",
    )


@pytest.fixture(autouse=True)
def _reset_serving_counters():
    """Zero every process-global counter after each test so test ordering
    can't leak TRACE_COUNT / SOLVE_COUNT / COMMIT_STATS / BUILD_STATS between
    suites. All counter dicts register with the repro.obs metrics registry at
    module import, so one ``reset_all()`` covers whatever subset this test
    actually imported — and repro.obs itself is stdlib-only, so pure-numpy
    tests never pay the jax import just for the reset. The tracer is
    disarmed too, so a test that enabled tracing can't leak spans."""
    yield
    from repro.obs.metrics import reset_all
    from repro.obs.trace import TRACER

    reset_all()
    if TRACER.enabled or TRACER.events():
        TRACER.reset()


# -- shared plan-table fixtures ------------------------------------------------

# The canonical smoke bucket set for plan-table suites (two seq buckets at
# batch 2 plus one at batch 4 — exercises both bucket axes).
PLAN_BUCKETS = [(2, 16), (2, 32), (4, 32)]

# Serving-regression shapes (test_serve_plan.py + the DSE serving check).
SERVE_ARCHS = ["qwen3-4b", "xlstm-1.3b"]  # dense GQA + SSM
SERVE_BATCH, SERVE_PROMPT, SERVE_GEN = 2, 8, 6
SERVE_MAX_SEQ = SERVE_PROMPT + SERVE_GEN


@pytest.fixture(scope="session")
def plan_grid():
    """Factory: cfg → (cost model, small Q grid spanning infeasible →
    whole-app across PLAN_BUCKETS)."""
    import numpy as np

    from repro.core import lower_config, q_min, whole_app_partition
    from repro.core.plan_table import _default_cost

    def _grid(cfg, kind="time"):
        cm = _default_cost(kind)
        graphs = [lower_config(cfg, b, s, kind=kind) for (b, s) in PLAN_BUCKETS]
        qmn = min(q_min(g, cm) for g in graphs)
        hi = max(whole_app_partition(g, cm).e_total for g in graphs)
        qs = [qmn * 0.5] + list(np.geomspace(qmn, hi * 1.1, 4)) + [None]
        return cm, qs

    return _grid


@pytest.fixture(scope="session")
def smoke_plan_table(plan_grid):
    """Factory: smoke arch (or ModelConfig) → (cfg, cm, qs, table) built on
    PLAN_BUCKETS. ``builder`` swaps in shard_plan_table etc.; extra kwargs
    (n_shards, cache_dir, ...) forward to the builder."""
    def _build(arch, kind="time", *, builder=None, buckets=None, **kwargs):
        from repro.configs import SMOKE_CONFIGS
        from repro.core import build_plan_table

        cfg = SMOKE_CONFIGS[arch] if isinstance(arch, str) else arch
        cm, qs = plan_grid(cfg, kind)
        build = builder if builder is not None else build_plan_table
        table = build(cfg, buckets or PLAN_BUCKETS, qs, kind=kind, cost=cm,
                      **kwargs)
        return cfg, cm, qs, table

    return _build


@pytest.fixture(scope="session")
def serve_tables():
    """One derived-grid plan table per serving regression arch."""
    from repro.launch.planner import build_table_for_arch

    return {
        arch: build_table_for_arch(
            arch, [(SERVE_BATCH, SERVE_MAX_SEQ), (SERVE_BATCH, 2 * SERVE_MAX_SEQ)],
            n_q=8,
        )
        for arch in SERVE_ARCHS
    }
