"""Test bootstrap: import paths + marker registration.

Makes ``repro`` importable without an install (the repo is src-layout and has
no setup.py) and the sibling test helpers importable regardless of how pytest
was invoked.
"""

import os
import sys

_TESTS = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_TESTS), "src")
for _p in (_SRC, _TESTS):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running model-zoo smoke / kernel sweeps "
        "(deselect with -m 'not slow' for the fast tier-1 job)",
    )
