"""Serving regression: the plan-table path changes scheduling, never results.

* ``serve()`` with and without ``plan_table`` produces identical token
  sequences on two smoke archs (different families);
* the planned request path does **zero partitioner solves** and **zero jit
  retraces** across repeated requests (trace/solve counters pinned);
* an energy budget splits the request into multiple committed cycles, and a
  mid-request power failure resumes from the last committed cycle boundary
  with identical output tokens.
"""

import numpy as np
import pytest

from conftest import (
    SERVE_ARCHS,
    SERVE_BATCH,
    SERVE_GEN,
    SERVE_MAX_SEQ,
    SERVE_PROMPT,
)

from repro.core import MemoryNVM, PowerFailure
from repro.core import partition_jax
from repro.core.plan_table import PlanTableError
from repro.launch import serve as serve_mod
from repro.launch.planner import ServePlanner
from repro.launch.serve import serve

pytestmark = pytest.mark.slow  # XLA model compiles; fast job skips these

# Shapes + the table-build fixture live in conftest.py (`serve_tables`),
# shared with the sharded-DSE tier.
ARCHS = SERVE_ARCHS
BATCH, PROMPT, GEN = SERVE_BATCH, SERVE_PROMPT, SERVE_GEN
MAX_SEQ = SERVE_MAX_SEQ


@pytest.fixture(scope="module")
def tables(serve_tables):
    return serve_tables


@pytest.fixture(scope="module")
def plain_tokens():
    return {
        arch: np.asarray(serve(arch, BATCH, PROMPT, GEN)) for arch in ARCHS
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_planned_tokens_identical_to_unplanned(arch, tables, plain_tokens):
    rep = {}
    planned = serve(arch, BATCH, PROMPT, GEN, plan_table=tables[arch],
                    report=rep)
    np.testing.assert_array_equal(plain_tokens[arch], np.asarray(planned))
    assert rep["cycles"] == [(1, GEN)]  # unbounded budget: one cycle
    assert rep["runtime_stats"].bursts_run == 1
    assert rep["planner_stats"]["lookups"] == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_plan_lookup_adds_zero_retraces_and_zero_solves(
    arch, tables, plain_tokens
):
    planner = ServePlanner(tables[arch])
    first = serve(arch, BATCH, PROMPT, GEN, plan_table=planner)
    traces = dict(serve_mod.TRACE_COUNT)
    solves = dict(partition_jax.SOLVE_COUNT)
    dp_traces = partition_jax.TRACE_COUNT["dp_sweep"]
    for _ in range(2):
        again = serve(arch, BATCH, PROMPT, GEN, plan_table=planner)
        np.testing.assert_array_equal(np.asarray(first), np.asarray(again))
    assert dict(serve_mod.TRACE_COUNT) == traces, "request path re-traced"
    assert dict(partition_jax.SOLVE_COUNT) == solves, "request path re-solved"
    assert partition_jax.TRACE_COUNT["dp_sweep"] == dp_traces
    assert planner.stats["lookups"] == 3  # but every request did look up
    np.testing.assert_array_equal(plain_tokens[arch], np.asarray(first))


def test_energy_budget_splits_into_committed_cycles(tables, plain_tokens):
    arch = ARCHS[0]
    table = tables[arch]
    plan = table.lookup(BATCH, MAX_SEQ, None)
    budget = plan.e_total * 2.2 + table.e_startup  # ~2 steps per cycle
    rep = {}
    planned = serve(arch, BATCH, PROMPT, GEN, plan_table=table,
                    energy_budget=budget, report=rep)
    np.testing.assert_array_equal(plain_tokens[arch], np.asarray(planned))
    assert len(rep["cycles"]) == 3
    assert rep["runtime_stats"].bursts_run == 3
    assert rep["nvm"].read_index() == 3
    # modeled energy: 3 activations + GEN activation-graph traversals
    expect = 3 * table.e_startup + GEN * plan.e_total
    assert rep["runtime_stats"].energy == pytest.approx(expect, rel=1e-12)


def test_crash_mid_request_resumes_from_committed_cycle(tables, plain_tokens):
    arch = ARCHS[0]
    table = tables[arch]
    plan = table.lookup(BATCH, MAX_SEQ, None)
    budget = plan.e_total * 2.2 + table.e_startup

    class CrashOnce:
        def __init__(self):
            self.fired = 0
            self.sites = []

        def __call__(self, b, phase):
            self.sites.append((b, phase))
            if b == 1 and phase == "executed" and not self.fired:
                self.fired += 1
                raise PowerFailure("injected mid-request")

    hook = CrashOnce()
    rep = {}
    planned = serve(arch, BATCH, PROMPT, GEN, plan_table=table,
                    energy_budget=budget, nvm=MemoryNVM(), crash_hook=hook,
                    report=rep)
    assert hook.fired == 1
    np.testing.assert_array_equal(plain_tokens[arch], np.asarray(planned))
    st = rep["runtime_stats"]
    assert st.bursts_run == 3                 # each cycle committed once
    assert st.tasks_run > GEN                 # cycle 1 replayed after the crash
    # resume replayed burst 1, not burst 0: cycle 0's commit survived
    assert (0, "loaded") in hook.sites
    assert hook.sites.count((0, "loaded")) == 1


def test_table_arch_mismatch_raises(tables):
    with pytest.raises(PlanTableError):
        serve(ARCHS[1], BATCH, PROMPT, GEN, plan_table=tables[ARCHS[0]])


@pytest.mark.parametrize("arch", ARCHS)
def test_unplanned_requests_add_zero_retraces(arch, plain_tokens):
    # regression: the unplanned path used to rebuild jax.jit(lambda ...)
    # wrappers per call, retracing every repeated same-shape request; it now
    # routes through the cached _step_fns (donate=True fast path)
    first = serve(arch, BATCH, PROMPT, GEN)
    traces = dict(serve_mod.TRACE_COUNT)
    for _ in range(2):
        again = serve(arch, BATCH, PROMPT, GEN)
        np.testing.assert_array_equal(np.asarray(first), np.asarray(again))
    assert dict(serve_mod.TRACE_COUNT) == traces, "unplanned path re-traced"
    np.testing.assert_array_equal(plain_tokens[arch], np.asarray(first))


def test_reset_trace_counts_zeroes_counters():
    serve_mod.TRACE_COUNT["prefill"] += 1  # simulate leaked state
    serve_mod.reset_trace_counts()
    assert serve_mod.TRACE_COUNT == {"prefill": 0, "decode": 0}
