"""Fault-injection matrix over the executable workloads (paper Algorithm 1).

For every lowered model-zoo graph with runtime bodies, plus the head-count
app, a single ``run_to_completion`` rides through an injected power failure
at *every* (burst, phase) point — 'loaded', 'executed' and 'stored', i.e.
before the index commit — and must still produce outputs identical to
``execute_atomic``. A recording NVM additionally proves replayed bursts are
idempotent: every re-write of a packet is byte-identical (pickle bytes) to
the first write, the paper's consistency argument made literal.
"""

import pickle

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from repro.configs import SMOKE_CONFIGS
from repro.core import (
    BurstRuntime,
    MemoryNVM,
    PowerFailure,
    execute_atomic,
    external_inputs,
    lower_config,
    optimal_partition,
    q_min,
)
from repro.core.apps.headcount import THERMAL, build_graph


class RecordingNVM(MemoryNVM):
    """MemoryNVM that keeps every serialized write per packet."""

    def __init__(self):
        super().__init__()
        self.writes = {}

    def write(self, name, value):
        self.writes.setdefault(name, []).append(
            pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        )
        super().write(name, value)


class CrashEverywhere:
    """Raises PowerFailure once at each distinct (burst, phase) site."""

    def __init__(self):
        self.seen = set()
        self.fired = 0

    def __call__(self, b, phase):
        if (b, phase) not in self.seen:
            self.seen.add((b, phase))
            self.fired += 1
            raise PowerFailure(f"injected at burst {b} @ {phase}")


def _zoo_cases():
    for arch, cfg in sorted(SMOKE_CONFIGS.items()):
        yield arch, lower_config(cfg, batch=2, seq=16, with_fns=True)
    yield "headcount-thermal", build_graph(THERMAL.reduced(2048), with_fns=True)


CASES = list(_zoo_cases())


@pytest.mark.parametrize("arch,graph", CASES, ids=[c[0] for c in CASES])
def test_crash_at_every_burst_phase_matches_atomic(arch, graph):
    from repro.core import PAPER_FRAM_MODEL as CM

    inputs = external_inputs(graph)
    ref = execute_atomic(graph, inputs)
    assert ref, f"{arch}: graph has no kept outputs"

    # a mid-granularity partition: several bursts, several tasks per burst
    qmn = q_min(graph, CM)
    part = optimal_partition(graph, CM, qmn * 1.5)
    hook = CrashEverywhere()
    nvm = RecordingNVM()
    rt = BurstRuntime(graph, part, nvm, cost=CM, crash_hook=hook)
    out = rt.run_to_completion(inputs or None)

    # every (burst, phase) site actually crashed once
    assert hook.fired == part.n_bursts * 3
    # committed bursts counted exactly once despite all the replays
    assert rt.stats.bursts_run == part.n_bursts
    assert rt.stats.tasks_run > graph.n_tasks  # replays really happened

    assert set(out) == set(ref)
    for name in ref:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(ref[name]), err_msg=name)

    # idempotency: every replayed store wrote byte-identical NVM packets
    replayed = {n: w for n, w in nvm.writes.items() if len(w) > 1}
    assert replayed, f"{arch}: crash matrix produced no replayed stores"
    for name, blobs in nvm.writes.items():
        for blob in blobs[1:]:
            assert blob == blobs[0], f"packet {name!r} not idempotent"


@pytest.mark.parametrize("arch,graph", CASES[:3], ids=[c[0] for c in CASES[:3]])
def test_single_task_bursts_survive_crash_matrix(arch, graph):
    """The Single Task scheme (one task per burst) under the same matrix."""
    from repro.core import PAPER_FRAM_MODEL as CM
    from repro.core import single_task_partition

    inputs = external_inputs(graph)
    ref = execute_atomic(graph, inputs)
    part = single_task_partition(graph, CM, naive_state_retention=False)
    rt = BurstRuntime(graph, part, RecordingNVM(), cost=CM,
                      crash_hook=CrashEverywhere())
    out = rt.run_to_completion(inputs or None)
    for name in ref:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(ref[name]))
    assert rt.stats.bursts_run == graph.n_tasks
