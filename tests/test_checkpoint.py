"""Burst-checkpointed training: atomic commit, bit-exact resume, cadence
planning (Algorithm 1 at pod scale)."""

import glob
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.burst_ckpt import BurstCheckpointer, plan_burst_schedule
from repro.launch.train import train


class TestCheckpointer:
    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            ck = BurstCheckpointer(d)
            state = {"w": jnp.arange(10.0), "step": jnp.int32(7)}
            ck.save(3, state)
            b, restored = ck.restore()
            assert b == 3
            np.testing.assert_array_equal(restored["w"], np.arange(10.0))

    def test_uncommitted_burst_invisible(self):
        """A checkpoint file without a committed index must not be restored —
        simulates a crash between the state write and the index commit."""
        with tempfile.TemporaryDirectory() as d:
            ck = BurstCheckpointer(d)
            ck.save(1, {"w": jnp.zeros(3)})
            # fake a crash: newer ckpt file exists but index still says 1
            import pickle
            with open(os.path.join(d, "ckpt_00000002.pkl"), "wb") as fh:
                pickle.dump({"w": np.ones(3)}, fh)
            b, st = ck.restore()
            assert b == 1
            np.testing.assert_array_equal(st["w"], np.zeros(3))

    def test_gc_keeps_recent(self):
        with tempfile.TemporaryDirectory() as d:
            ck = BurstCheckpointer(d, keep=2)
            for b in range(1, 6):
                ck.save(b, {"w": jnp.full(2, b)})
            files = glob.glob(os.path.join(d, "ckpt_*"))
            assert len(files) == 2
            assert ck.restore()[0] == 5


class TestTrainResume:
    def test_resume_matches_uninterrupted(self):
        """Crash after burst 1, resume → identical final loss trajectory."""
        kw = dict(arch="qwen1.5-0.5b", steps=6, batch=2, seq=16, burst_steps=2,
                  smoke=True, log_every=100)
        with tempfile.TemporaryDirectory() as d1:
            ref = train(ckpt_dir=d1, **kw)
        with tempfile.TemporaryDirectory() as d2:
            # run only burst 1 (steps 0-1), "crash", then resume
            try:
                train(ckpt_dir=d2, steps=2, **{k: v for k, v in kw.items()
                                               if k != "steps"})
            except SystemExit:
                pass
            out = train(ckpt_dir=d2, **kw)
        # resumed losses (steps 2..5) must match the uninterrupted run exactly
        np.testing.assert_allclose(out, ref[2:], rtol=1e-6)


class TestBurstSchedule:
    def test_bound_respected(self):
        part = plan_burst_schedule(100, step_seconds=1.0, state_bytes=10**9,
                                   max_loss_seconds=20.0, restart_seconds=5.0)
        for b in part.bursts:
            assert b.total <= 20.0 * (1 + 1e-9)
        assert part.n_bursts >= 100 / 20

    def test_expensive_checkpoints_force_more_bursts(self):
        """An expensive state write eats into the per-burst loss budget, so
        fewer steps fit per burst → more bursts (the paper's Fig. 7 shape:
        transfer costs shrink the effective burst capacity)."""
        fast = plan_burst_schedule(60, 1.0, 10**8, 20.0, restart_seconds=1.0,
                                   disk_bw=1e10)
        slow = plan_burst_schedule(60, 1.0, int(5e9), 20.0,
                                   restart_seconds=1.0, disk_bw=1e9)
        assert slow.n_bursts >= fast.n_bursts
        # and the optimizer never exceeds the loss budget either way
        for p in (fast, slow):
            assert p.max_burst <= 20.0 * (1 + 1e-9)
