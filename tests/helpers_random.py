"""Stdlib-``random`` generators for random task graphs and cost models.

Shared by the partitioner property tests and the jitted-engine differential
suite. These mirror the hypothesis strategies in test_partition.py but only
need the standard library, so the core invariants still run in environments
without hypothesis (e.g. the seed container). CI installs hypothesis, so
there both drivers run — the seeded one as a deterministic floor, the
fuzzer on top.
"""

import random
from typing import List, Optional, Tuple

from repro.core import CostModel, GraphBuilder, LinearTransfer, TaskGraph


def random_task_graph(
    rng: random.Random, max_tasks: int = 9, min_tasks: int = 1
) -> TaskGraph:
    """A random SSA-valid sequential application (shape mirrors the
    hypothesis ``task_graphs`` strategy)."""
    n = rng.randint(min_tasks, max_tasks)
    b = GraphBuilder()
    avail: List[str] = []
    for i in range(rng.randint(0, 2)):
        b.packet(f"e{i}", rng.randint(1, 4000), external=True)
        avail.append(f"e{i}")
    for t in range(n):
        n_reads = rng.randint(0, min(3, len(avail)))
        reads = rng.sample(avail, n_reads)
        writes = []
        for w in range(rng.randint(0, 2)):
            name = f"p{t}_{w}"
            b.packet(name, rng.randint(1, 4000), keep=rng.random() < 0.5)
            writes.append(name)
        b.task(f"t{t}", reads=tuple(reads), writes=tuple(writes),
               cost=rng.uniform(0.01, 10.0))
        avail.extend(writes)
    return b.build()


def random_cost_model(rng: random.Random) -> CostModel:
    return CostModel(
        e_startup=rng.uniform(0.0, 1.0),
        read=LinearTransfer(rng.uniform(0.0, 0.1), rng.uniform(0.0, 1e-3)),
        write=LinearTransfer(rng.uniform(0.0, 0.1), rng.uniform(0.0, 1e-3)),
    )


def random_q_grid(
    rng: random.Random, q_min_val: float, q_whole: float
) -> List[Optional[float]]:
    """A Q_max grid straddling the feasibility boundary: None (unbounded),
    0 and a sub-Q_min point (infeasible unless Q_min == 0), Q_min itself,
    and a few random points up to past the whole-app cost."""
    qs: List[Optional[float]] = [None, 0.0, q_min_val * 0.9, q_min_val]
    hi = max(q_whole, q_min_val) * 1.1 + 1e-9
    qs.extend(rng.uniform(0.0, hi) for _ in range(4))
    return qs
