"""Stdlib-``random`` generators for random task graphs and cost models.

Shared by the partitioner property tests and the jitted-engine differential
suite. These mirror the hypothesis strategies in test_partition.py but only
need the standard library, so the core invariants still run in environments
without hypothesis (e.g. the seed container). CI installs hypothesis, so
there both drivers run — the seeded one as a deterministic floor, the
fuzzer on top.
"""

import random
from typing import List, Optional, Tuple

from repro.core import CostModel, GraphBuilder, LinearTransfer, TaskGraph


def random_task_graph(
    rng: random.Random, max_tasks: int = 9, min_tasks: int = 1
) -> TaskGraph:
    """A random SSA-valid sequential application (shape mirrors the
    hypothesis ``task_graphs`` strategy)."""
    n = rng.randint(min_tasks, max_tasks)
    b = GraphBuilder()
    avail: List[str] = []
    for i in range(rng.randint(0, 2)):
        b.packet(f"e{i}", rng.randint(1, 4000), external=True)
        avail.append(f"e{i}")
    for t in range(n):
        n_reads = rng.randint(0, min(3, len(avail)))
        reads = rng.sample(avail, n_reads)
        writes = []
        for w in range(rng.randint(0, 2)):
            name = f"p{t}_{w}"
            b.packet(name, rng.randint(1, 4000), keep=rng.random() < 0.5)
            writes.append(name)
        b.task(f"t{t}", reads=tuple(reads), writes=tuple(writes),
               cost=rng.uniform(0.01, 10.0))
        avail.extend(writes)
    return b.build()


def random_cost_model(rng: random.Random) -> CostModel:
    return CostModel(
        e_startup=rng.uniform(0.0, 1.0),
        read=LinearTransfer(rng.uniform(0.0, 0.1), rng.uniform(0.0, 1e-3)),
        write=LinearTransfer(rng.uniform(0.0, 0.1), rng.uniform(0.0, 1e-3)),
    )


def adversarial_tie_graph(
    rng: random.Random, max_tasks: int = 18, min_tasks: int = 4
) -> TaskGraph:
    """Equal-cost graph family for the exact-tie audit (ROADMAP).

    Every energy quantity is a small dyadic rational (task costs from
    {0.25, 0.5, 1.0}, packet sizes powers of two, dyadic c0/c1 — see
    :func:`tie_cost_model`), so every burst cost and DP candidate is exactly
    representable in float64 *regardless of summation order*. Many tasks
    share identical costs, which makes DP argmin ties the common case
    instead of a measure-zero event — locking in the "smallest burst start
    wins" tie-break across the numpy DP, the scan backend, and the
    CSR/Pallas backend (they must all reconstruct identical bounds, not just
    identical totals). Shapes stay within the differential suite's padding
    (≤ 20 tasks, ≤ 3 reads, ≤ 2 writes per task).
    """
    n = rng.randint(min_tasks, max_tasks)
    b = GraphBuilder()
    avail: List[str] = []
    for i in range(rng.randint(0, 2)):
        b.packet(f"e{i}", 2 ** rng.randint(3, 10), external=True)
        avail.append(f"e{i}")
    costs = [0.25, 0.5, 0.5, 1.0]  # repeats on purpose: identical tasks tie
    for t in range(n):
        n_reads = rng.randint(0, min(3, len(avail)))
        reads = rng.sample(avail, n_reads)
        writes = []
        for w in range(rng.randint(0, 2)):
            name = f"p{t}_{w}"
            b.packet(name, 2 ** rng.randint(3, 10), keep=rng.random() < 0.25)
            writes.append(name)
        b.task(f"t{t}", reads=tuple(reads), writes=tuple(writes),
               cost=rng.choice(costs))
        avail.extend(writes)
    return b.build()


def tie_cost_model(rng: random.Random) -> CostModel:
    """Dyadic cost model companion to :func:`adversarial_tie_graph`."""
    return CostModel(
        e_startup=rng.choice([0.0, 0.25, 0.5]),
        read=LinearTransfer(rng.choice([0.0, 0.25]), rng.choice([0.0, 2.0 ** -10])),
        write=LinearTransfer(rng.choice([0.0, 0.25]), rng.choice([0.0, 2.0 ** -12])),
    )


def tie_q_grid(
    rng: random.Random, q_min_val: float, q_whole: float
) -> List[Optional[float]]:
    """Q grid for the tie audit: exact burst-cost lattice points (so the
    ≤-budget mask itself ties) plus the usual feasibility straddle."""
    qs: List[Optional[float]] = [None, 0.0, q_min_val, q_whole]
    lo, hi = min(q_min_val, q_whole), max(q_min_val, q_whole)
    for _ in range(4):
        # dyadic interpolation keeps the grid on the exact lattice
        frac = rng.randint(0, 8) / 8.0
        qs.append(lo + (hi - lo) * frac)
    return qs


def random_q_grid(
    rng: random.Random, q_min_val: float, q_whole: float
) -> List[Optional[float]]:
    """A Q_max grid straddling the feasibility boundary: None (unbounded),
    0 and a sub-Q_min point (infeasible unless Q_min == 0), Q_min itself,
    and a few random points up to past the whole-app cost."""
    qs: List[Optional[float]] = [None, 0.0, q_min_val * 0.9, q_min_val]
    hi = max(q_whole, q_min_val) * 1.1 + 1e-9
    qs.extend(rng.uniform(0.0, hi) for _ in range(4))
    return qs
