"""Algorithm 1 runtime: atomic equivalence, accounting, crash recovery."""

import random
import tempfile

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from repro.core import (
    PAPER_FRAM_MODEL,
    BurstRuntime,
    DirNVM,
    GraphBuilder,
    MemoryNVM,
    PowerFailure,
    execute_atomic,
    optimal_partition,
    single_task_partition,
)

CM = PAPER_FRAM_MODEL


def pipeline_graph(n=12, seed=0):
    """A numeric pipeline with reconvergent dataflow and real bodies."""
    rng = np.random.RandomState(seed)
    b = GraphBuilder()
    b.packet("x0", 64, external=True)
    prev = "x0"
    checkpoints = ["x0"]
    for t in range(n):
        name = f"x{t + 1}"
        b.packet(name, 64, keep=(t == n - 1))
        skip = checkpoints[len(checkpoints) // 2]
        deps = (prev,) if (t % 3 or skip == prev) else (prev, skip)
        c = float(rng.rand() + 0.1)
        mults = rng.randn(len(deps)).astype(np.float64)

        def fn(inp, deps=deps, mults=mults, name=name):
            acc = sum(m * np.asarray(inp[d]) for d, m in zip(deps, mults))
            return {name: np.tanh(acc)}

        b.task(f"t{t}", reads=deps, writes=(name,), cost=c, fn=fn)
        checkpoints.append(name)
        prev = name
    return b.build()


@pytest.fixture
def graph():
    return pipeline_graph()


@pytest.fixture
def inputs():
    return {"x0": np.linspace(-1, 1, 8)}


def test_partitioned_equals_atomic(graph, inputs):
    ref = execute_atomic(graph, inputs)
    from repro.core import q_min
    qmn = q_min(graph, CM)
    for qmax in [None, 3 * qmn, qmn]:
        part = optimal_partition(graph, CM, qmax)
        rt = BurstRuntime(graph, part, MemoryNVM(), cost=CM)
        out = rt.run(inputs)
        np.testing.assert_array_equal(out["x12"], ref["x12"])


def test_energy_and_bytes_match_model(graph, inputs):
    from repro.core import q_min
    part = optimal_partition(graph, CM, 1.5 * q_min(graph, CM))
    rt = BurstRuntime(graph, part, MemoryNVM(), cost=CM)
    rt.run(inputs)
    assert rt.stats.energy == pytest.approx(part.e_total, rel=1e-12)
    model_bytes = sum(b.read_bytes + b.write_bytes for b in part.bursts)
    assert rt.stats.bytes_loaded + rt.stats.bytes_stored == model_bytes
    assert rt.stats.tasks_run == graph.n_tasks
    assert rt.stats.bursts_run == part.n_bursts


@pytest.mark.parametrize("crash_p", [0.2, 0.5, 0.8])
def test_crash_recovery_bit_exact(graph, inputs, crash_p):
    from repro.core import q_min
    ref = execute_atomic(graph, inputs)
    part = optimal_partition(graph, CM, 1.5 * q_min(graph, CM))
    rng = random.Random(int(crash_p * 100))

    def chaos(b, phase):
        if rng.random() < crash_p:
            raise PowerFailure(f"burst {b} @ {phase}")

    rt = BurstRuntime(graph, part, MemoryNVM(), cost=CM, crash_hook=chaos)
    out = rt.run_to_completion(inputs)
    np.testing.assert_array_equal(out["x12"], ref["x12"])
    # committed bursts counted exactly once despite replays
    assert rt.stats.bursts_run == part.n_bursts


def test_crash_before_commit_replays_burst(graph, inputs):
    from repro.core import q_min
    part = optimal_partition(graph, CM, 1.5 * q_min(graph, CM))
    crashed = []

    def crash_once(b, phase):
        if b == 1 and phase == "stored" and not crashed:
            crashed.append(True)
            raise PowerFailure()

    rt = BurstRuntime(graph, part, MemoryNVM(), cost=CM, crash_hook=crash_once)
    out = rt.run_to_completion(inputs)
    ref = execute_atomic(graph, inputs)
    np.testing.assert_array_equal(out["x12"], ref["x12"])
    assert crashed  # the injection actually fired
    assert rt.stats.tasks_run > graph.n_tasks  # some tasks re-ran (idempotent)


def test_disk_nvm_resume_across_instances(graph, inputs):
    """Simulates full process death: a NEW runtime resumes from disk."""
    from repro.core import q_min
    ref = execute_atomic(graph, inputs)
    part = optimal_partition(graph, CM, 1.5 * q_min(graph, CM))
    with tempfile.TemporaryDirectory() as d:
        nvm = DirNVM(d)
        hits = [0]

        def crash_at_2(b, phase):
            if b == 2 and phase == "executed" and hits[0] == 0:
                hits[0] = 1
                raise PowerFailure()

        rt1 = BurstRuntime(graph, part, nvm, cost=CM, crash_hook=crash_at_2)
        with pytest.raises(PowerFailure):
            rt1.run(inputs)
        # fresh process, fresh runtime, same NVM directory
        rt2 = BurstRuntime(graph, part, DirNVM(d), cost=CM)
        out = rt2.run()
        np.testing.assert_array_equal(out["x12"], ref["x12"])
        assert rt2.nvm.read_index() == part.n_bursts


def test_single_task_partition_runs(graph, inputs):
    ref = execute_atomic(graph, inputs)
    part = single_task_partition(graph, CM, naive_state_retention=False)
    rt = BurstRuntime(graph, part, MemoryNVM(), cost=CM)
    out = rt.run(inputs)
    np.testing.assert_array_equal(out["x12"], ref["x12"])
    assert rt.stats.bursts_run == graph.n_tasks


def test_missing_external_input_raises(graph):
    part = optimal_partition(graph, CM, None)
    rt = BurstRuntime(graph, part, MemoryNVM())
    with pytest.raises(ValueError, match="missing external packet"):
        rt.run({})
