"""Unit tests for the burst energy model E⟨i,j⟩ (paper §4.2)."""

import numpy as np
import pytest

from repro.core import (
    PAPER_FRAM_MODEL,
    ColumnSweep,
    GraphBuilder,
    burst_cost,
    burst_detail,
)


def listing1_graph():
    """The paper's Listing 1: sense → process → transmit."""
    b = GraphBuilder()
    b.packet("img", 80 * 60, )
    b.packet("headCount", 1, keep=True)
    b.task("sense", writes=("img",), cost=131.9e-3)
    b.task("process", reads=("img",), writes=("headCount",), cost=2.16)
    b.task("transmit", reads=("headCount",), cost=0.086e-3)
    return b.build()


CM = PAPER_FRAM_MODEL


class TestSingleTaskBurst:
    def test_sense_alone_stores_image(self):
        g = listing1_graph()
        d = burst_detail(g, CM, 1, 1)
        # E⟨1,1⟩ = E_s + E_task + E_w(img): img is read later (l_inf > 1)
        assert d.loads == []
        assert d.stores == ["img"]
        expected = 9e-6 + 131.9e-3 + (0.9e-6 + 4800 * 6.2e-9)
        assert d.total == pytest.approx(expected, rel=1e-12)

    def test_paper_image_store_cost(self):
        # §6.2: "saving the entire 80×60 thermal picture into FRAM only
        # requires 59.5 µJ" (the paper quotes the per-byte part)
        assert 9600 * 6.2e-9 == pytest.approx(59.5e-6, rel=2e-3)

    def test_process_alone_loads_and_stores(self):
        g = listing1_graph()
        d = burst_detail(g, CM, 2, 2)
        assert d.loads == ["img"]
        assert d.stores == ["headCount"]  # read by transmit

    def test_transmit_alone(self):
        g = listing1_graph()
        d = burst_detail(g, CM, 3, 3)
        assert d.loads == ["headCount"]
        # headCount is keep=True → survives the application → stored? No:
        # transmit does not write it; the packet is already in NVM.
        assert d.stores == []


class TestMultiTaskBurst:
    def test_fusion_removes_intermediate_transfer(self):
        g = listing1_graph()
        # sense+process in one burst: img never touches NVM
        d = burst_detail(g, CM, 1, 2)
        assert d.loads == []
        assert "img" not in d.stores
        assert d.stores == ["headCount"]

    def test_whole_app_only_keeps_output(self):
        g = listing1_graph()
        d = burst_detail(g, CM, 1, 3)
        assert d.loads == []
        # headCount written in-burst, keep=True → l_inf = n+1 > 3 → stored
        assert d.stores == ["headCount"]

    def test_shared_input_loaded_once(self):
        b = GraphBuilder()
        b.packet("x", 1000, external=True)
        b.packet("a", 10, keep=True)
        b.packet("b", 10, keep=True)
        b.task("t1", reads=("x",), writes=("a",), cost=1.0)
        b.task("t2", reads=("x",), writes=("b",), cost=1.0)
        g = b.build()
        d = burst_detail(g, CM, 1, 2)
        assert d.loads.count("x") == 1  # second reader reuses volatile copy

    def test_burst_cost_superadditivity(self):
        # Merging bursts never increases cost beyond the separate parts
        # (one fewer startup, never more transfers).
        g = listing1_graph()
        for i in range(1, 4):
            for j in range(i, 4):
                for k in range(i, j):
                    merged = burst_cost(g, CM, i, j)
                    split = burst_cost(g, CM, i, k) + burst_cost(g, CM, k + 1, j)
                    assert merged <= split + 1e-15


class TestColumnSweep:
    def test_matches_reference_on_dense_graph(self):
        rng = np.random.RandomState(0)
        b = GraphBuilder()
        b.packet("seed", 128, external=True)
        avail = ["seed"]
        for t in range(25):
            reads = [avail[i] for i in rng.choice(len(avail), size=min(len(avail), 2), replace=False)]
            w = b.packet(f"p{t}", int(rng.randint(1, 5000)), keep=bool(rng.rand() < 0.2))
            b.task(f"t{t}", reads=tuple(reads), writes=(w,), cost=float(rng.rand()))
            avail.append(w)
        g = b.build()
        for j, col in zip(range(1, g.n_tasks + 1), ColumnSweep(g, CM)):
            for i in range(1, j + 1):
                assert col[i] == pytest.approx(burst_cost(g, CM, i, j), rel=1e-9), (i, j)


class TestValidation:
    def test_ssa_violation(self):
        b = GraphBuilder()
        b.packet("x", 4)
        b.task("t1", writes=("x",), cost=1)
        b.task("t2", writes=("x",), cost=1)
        with pytest.raises(ValueError, match="SSA"):
            b.build()

    def test_read_before_write(self):
        b = GraphBuilder()
        b.packet("x", 4)
        b.task("t1", reads=("x",), cost=1)
        b.task("t2", writes=("x",), cost=1)
        with pytest.raises(ValueError, match="before it is written"):
            b.build()

    def test_inout_rejected(self):
        b = GraphBuilder()
        b.packet("x", 4, external=True)
        with pytest.raises(ValueError, match="inout"):
            b.task("t1", reads=("x",), writes=("x",), cost=1)
