"""Paper-claim validation: the head-counting applications (§5–§6).

Every assertion cites the paper's number. Where the reconstruction cannot be
exact (the paper omits the full packet layout) tolerances are documented in
EXPERIMENTS.md §Paper-repro.
"""

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from repro.core import (
    BurstRuntime,
    MemoryNVM,
    execute_atomic,
    optimal_partition,
    q_min,
    single_task_partition,
    sweep,
    whole_app_partition,
)
from repro.core.apps.headcount import THERMAL, VISUAL, build_graph, paper_cost_model

CM = paper_cost_model()


@pytest.fixture(scope="module")
def thermal():
    return build_graph(THERMAL)


@pytest.fixture(scope="module")
def visual():
    return build_graph(VISUAL)


class TestEnergyCharacterization:
    def test_task_count_matches_single_task_bursts(self, thermal):
        assert thermal.n_tasks == 5458  # paper Fig. 6: 5458 bursts

    def test_application_energy(self, thermal):
        # §6.4: atomic execution requires harvesting 2.294 J
        assert thermal.total_task_cost() == pytest.approx(2.294, abs=5e-4)

    def test_cnn_energy_sums(self):
        # Table 2 E_sum column
        assert 4125 * 0.396e-3 == pytest.approx(1633.5e-3, rel=1e-3)
        assert 936 * 0.396e-3 == pytest.approx(370.7e-3, rel=1e-3)
        assert 391 * 0.403e-3 == pytest.approx(157.6e-3, rel=1e-3)

    def test_processing_total(self):
        # Table 2: total head-counting 2161.8 mJ
        proc = (
            THERMAL.e_normalize + THERMAL.e_initialize
            + sum(e * n for e, n in zip(THERMAL.e_cnn, THERMAL.n_cnn))
            + THERMAL.e_sort + THERMAL.e_nms
        )
        assert proc == pytest.approx(2161.8e-3, abs=0.05e-3)

    def test_visual_differs_only_in_sense(self, thermal, visual):
        # §5: "the only difference ... is the energy required for the image
        # acquisition itself"
        assert VISUAL.e_sense == pytest.approx(4.4e-3)
        assert (thermal.total_task_cost() - visual.total_task_cost()
                == pytest.approx(131.9e-3 - 4.4e-3, rel=1e-9))


class TestPartitioningResults:
    def test_qmin_is_132mJ(self, thermal):
        # §6.3: "We thus use Q_max=132 mJ as the smallest feasible capacity"
        assert q_min(thermal, CM) == pytest.approx(132e-3, abs=0.5e-3)

    def test_julienning_18_bursts(self, thermal):
        p = optimal_partition(thermal, CM, 132e-3)
        assert p.n_bursts == 18  # Fig. 6

    def test_overhead_near_paper(self, thermal):
        # Fig. 6 / abstract: 2.79 mJ ≈ 0.12 % overhead. Our reconstruction
        # gives ~1.8 mJ ≈ 0.08 % — same order, see EXPERIMENTS.md.
        p = optimal_partition(thermal, CM, 132e-3)
        pct = 100 * p.e_overhead / p.e_total
        assert pct < 0.2
        assert p.e_overhead < 3e-3

    def test_single_task_5458_bursts_437MB(self, thermal):
        st = single_task_partition(thermal, CM)
        assert st.n_bursts == 5458
        assert st.transfer_bytes > 437e6  # "over 437 MB"
        assert st.transfer_bytes < 1.2 * 449.8e6
        # Fig. 6: overhead larger than the application energy itself
        assert st.e_overhead > st.e_app

    def test_storage_reduction_94pct(self, thermal):
        # §7: "reduce the energy storage by 94% compared to no partitioning"
        whole = whole_app_partition(thermal, CM)
        reduction = 1 - q_min(thermal, CM) / whole.max_burst
        assert reduction > 0.94

    def test_single_burst_when_qmax_exceeds_app(self, thermal):
        # §6.3: "Once Q_max > E_app + E_bootup, the optimal N_bursts is 1"
        p = optimal_partition(thermal, CM, thermal.total_task_cost() * 1.01)
        assert p.n_bursts == 1


class TestDesignSpace:
    def test_thermal_feasibility_range(self, thermal):
        # §6.4: thermal feasibility range is 1–18 bursts
        qs = np.geomspace(132e-3, 2.5, 24)
        parts = [p for p in sweep(thermal, CM, qs) if p is not None]
        nb = [p.n_bursts for p in parts]
        assert max(nb) == 18 and min(nb) == 1

    def test_nbursts_monotone_nonincreasing(self, visual):
        qs = np.geomspace(4.5e-3, 2.4, 16)
        parts = sweep(visual, CM, qs)
        nb = [p.n_bursts for p in parts if p is not None]
        assert all(a >= b for a, b in zip(nb, nb[1:]))

    def test_visual_wider_range_than_thermal(self, thermal, visual):
        # §6.4: visual partitions much finer (456 bursts in the paper;
        # ~500 in our reconstruction) because sensing is only 4.4 mJ
        qv, qt = q_min(visual, CM), q_min(thermal, CM)
        assert qv < qt / 25
        pv = optimal_partition(visual, CM, qv)
        assert pv.n_bursts > 400

    def test_overhead_below_3pct_at_4p3pct_storage(self, visual, thermal):
        # Fig. 8 caption: overhead stays below 3% for storage bounds as low
        # as 4.3% of E_app.
        for g in (thermal, visual):
            e_app = g.total_task_cost()
            q = max(0.043 * e_app, q_min(g, CM))
            p = optimal_partition(g, CM, q)
            assert p.e_overhead / p.e_total < 0.03


class TestExecutableCNN:
    def test_reduced_graph_runs_and_matches_atomic(self):
        spec = THERMAL.reduced(scale=128)
        g = build_graph(spec, with_fns=True, seed=3)
        ref = execute_atomic(g, {})
        assert int(ref["headcount"]) > 0
        p = optimal_partition(g, CM, 132e-3)
        rt = BurstRuntime(g, p, MemoryNVM(), cost=CM)
        out = rt.run({})
        assert out["headcount"] == ref["headcount"]

    def test_thermal_visual_same_pipeline_shape(self):
        gt = build_graph(THERMAL.reduced(128), with_fns=True, seed=3)
        gv = build_graph(VISUAL.reduced(128), with_fns=True, seed=3)
        # same CNN → identical headcount on the same frame (§5)
        assert execute_atomic(gt, {})["headcount"] == execute_atomic(gv, {})["headcount"]
