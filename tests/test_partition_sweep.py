"""Differential suite for the CSR/Pallas sweep-kernel subsystem.

Layers under test, bottom-up:

* ``TaskGraph.to_csr_arrays`` — round-trips against the dense ``to_arrays``
  export slot-for-slot; padding/stacking never changes a solution.
* ``kernels.partition_sweep.ref`` — the numpy CSR sweep is bit-identical to
  the numpy DP oracle (bounds included).
* ``kernels.partition_sweep.kernel`` (interpret mode) — bit-identical column
  tables (mns *and* argmin bests) against the ref, on random graphs, the
  adversarial equal-cost tie family, and lowered model-zoo graphs.
* ``partition_jax`` backend plumbing — backend="pallas" returns the same
  JaxSweep as backend="scan"/numpy; backend="auto" routes by export size;
  serving loops neither re-trace nor re-upload.
* slow: the full (unreduced) 5458-task head-count graphs solve end-to-end
  through the CSR backend — the dense export would be ~1 GB and is never
  materialized — reproducing the paper's 18-burst @ 132 mJ plan.
"""

import random

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from helpers_random import (
    adversarial_tie_graph,
    random_cost_model,
    random_q_grid,
    random_task_graph,
    tie_cost_model,
    tie_q_grid,
)

from repro.core import (
    PAPER_FRAM_MODEL,
    GraphBuilder,
    Infeasible,
    dense_export_nbytes,
    lower_config,
    optimal_partition_multi,
    q_min,
    stack_csr_arrays,
    tpu_host_offload_model,
    whole_app_partition,
)
from repro.core import partition_jax
from repro.core.apps.headcount import THERMAL, VISUAL, build_graph
from repro.core.partition_jax import (
    optimal_partition_jax,
    sweep_from_columns,
    sweep_jax,
    sweep_jax_batched,
)
from repro.configs import REGISTRY
from repro.api import PartitionSpec, solve
from repro.core.partition import _optimal_k
from repro.kernels.partition_sweep import kernel as sweep_kernel
from repro.kernels.partition_sweep.ops import sweep_columns
from repro.kernels.partition_sweep.ref import (
    sweep_columns_exactk_ref,
    sweep_columns_minimax_ref,
    sweep_columns_ref,
)

CM = PAPER_FRAM_MODEL


def _case(seed):
    rng = random.Random(seed)
    g = random_task_graph(rng, max_tasks=18)
    cm = random_cost_model(rng)
    qs = random_q_grid(rng, q_min(g, cm), whole_app_partition(g, cm).e_total)
    return g, cm, qs


def _tie_case(seed):
    rng = random.Random(9000 + seed)
    g = adversarial_tie_graph(rng)
    cm = tie_cost_model(rng)
    qs = tie_q_grid(rng, q_min(g, cm), whole_app_partition(g, cm).e_total)
    return g, cm, qs


def _assert_bitequal(a, b, ctx=""):
    assert ((a == b) | (np.isinf(a) & np.isinf(b))).all(), ctx


# -- CSR export ---------------------------------------------------------------


@pytest.mark.parametrize("seed", range(20))
def test_csr_roundtrip_vs_dense(seed):
    """The CSR export carries exactly the dense export's slots, in order."""
    g, _, _ = _case(seed)
    dense = g.to_arrays()
    csr = g.to_csr_arrays()
    assert csr.n_tasks == dense.n_tasks == g.n_tasks
    np.testing.assert_array_equal(csr.e_task, dense.e_task)
    assert csr.read_ptr[0] == 0 and csr.read_ptr[-1] == csr.nnz_reads
    for j in range(1, g.n_tasks + 1):
        lo, hi = int(csr.read_ptr[j - 1]), int(csr.read_ptr[j])
        deg = hi - lo
        assert deg == int(dense.read_valid[j - 1].sum())
        for name_d, name_c in (
            ("read_bytes", "read_bytes"),
            ("read_c0w", "read_c0w"),
            ("read_lt", "read_lt"),
            ("read_writer", "read_writer"),
            ("read_linf", "read_linf"),
        ):
            np.testing.assert_array_equal(
                getattr(csr, name_c)[lo:hi],
                getattr(dense, name_d)[j - 1, :deg],
                err_msg=f"task {j} {name_c}",
            )
        wlo, whi = int(csr.write_ptr[j - 1]), int(csr.write_ptr[j])
        wdeg = whi - wlo
        assert wdeg == int(dense.write_valid[j - 1].sum())
        np.testing.assert_array_equal(
            csr.write_bytes[wlo:whi], dense.write_bytes[j - 1, :wdeg]
        )
        np.testing.assert_array_equal(
            csr.write_linf[wlo:whi], dense.write_linf[j - 1, :wdeg]
        )


def test_csr_cache_and_padding():
    g, cm, qs = _case(3)
    assert g.to_csr_arrays() is g.to_csr_arrays()  # unpadded export cached
    csr = g.to_csr_arrays()
    pad = g.to_csr_arrays(
        n_pad=csr.n_pad + 5, r_pad=csr.nnz_reads + 7, w_pad=csr.nnz_writes + 3
    )
    assert pad.n_pad == csr.n_pad + 5 and pad.read_ptr.shape[0] == pad.n_pad + 1
    # padded rows own no slots
    assert (pad.read_ptr[csr.n_pad:] == csr.nnz_reads).all()
    with pytest.raises(ValueError):
        csr.padded(1, 1, 1)
    # a padded export solves identically
    a = sweep_from_columns(g.n_tasks, qs, *sweep_columns_ref(csr, cm, qs))
    b = sweep_from_columns(g.n_tasks, qs, *sweep_columns_ref(pad, cm, qs))
    _assert_bitequal(a.e_total, b.e_total)
    for qi in range(len(qs)):
        assert a.bounds(qi) == b.bounds(qi)


def test_stack_csr_arrays_batches_heterogeneous_graphs():
    graphs = [_case(s)[0] for s in (11, 12, 13, 14)]
    stacked = stack_csr_arrays([g.to_csr_arrays() for g in graphs])
    assert stacked.e_task.shape[0] == len(graphs)
    assert (np.asarray(stacked.n_tasks) == [g.n_tasks for g in graphs]).all()
    qs = [None, 0.5]
    for g, res in zip(graphs, sweep_jax_batched(graphs, CM, qs, backend="pallas")):
        ref = optimal_partition_multi(g, CM, qs)
        for r, p in zip(ref, res.to_partitions(g, CM)):
            if r is None:
                assert p is None
            else:
                assert p is not None and p.e_total == r.e_total
                assert p.bounds == r.bounds


# -- ref vs numpy DP ----------------------------------------------------------


@pytest.mark.parametrize("seed", range(20))
def test_ref_matches_numpy_dp(seed):
    """The numpy CSR sweep is bit-identical to optimal_partition_multi —
    e_total AND reconstructed bounds, including Infeasible cases."""
    g, cm, qs = _case(seed)
    ref = optimal_partition_multi(g, cm, qs)
    res = sweep_from_columns(
        g.n_tasks, qs, *sweep_columns_ref(g.to_csr_arrays(), cm, qs)
    )
    for q, r, p in zip(qs, ref, res.to_partitions(g, cm)):
        if r is None:
            assert p is None, (seed, q)
        else:
            assert p is not None and p.e_total == r.e_total, (seed, q)
            assert p.bounds == r.bounds, (seed, q)
            p.validate(g)


# -- kernel (interpret) vs ref ------------------------------------------------


@pytest.mark.parametrize("seed", range(20))
def test_kernel_matches_ref_bitexact(seed):
    """Pallas kernel (interpret, slot_chunk=1) replays numpy's accumulation
    order: mns AND argmin bests are bit-identical to the CSR oracle."""
    g, cm, qs = _case(100 + seed)
    csr = g.to_csr_arrays()
    mr, br = sweep_columns_ref(csr, cm, qs)
    mk, bk = sweep_columns(csr, cm, qs, interpret=True)
    _assert_bitequal(mr, mk, seed)
    assert (br == bk).all(), seed


@pytest.mark.parametrize("tile", [8, 64])
def test_kernel_tile_size_invariance(tile):
    """Cross-tile min/argmin combining is associative with the first-minimum
    rule: any i-tiling gives the same tables."""
    g, cm, qs = _tie_case(0)
    csr = g.to_csr_arrays()
    mr, br = sweep_columns_ref(csr, cm, qs)
    mk, bk = sweep_columns(csr, cm, qs, tile=tile, interpret=True)
    _assert_bitequal(mr, mk, tile)
    assert (br == bk).all(), tile


def test_kernel_chunked_slots_close_to_ref():
    """slot_chunk>1 vectorizes the slot loop (TPU throughput mode): values
    drift by ulps only; exact dyadic graphs stay bit-equal."""
    g, cm, qs = _case(7)
    csr = g.to_csr_arrays()
    mr, _ = sweep_columns_ref(csr, cm, qs)
    mk, _ = sweep_columns(csr, cm, qs, slot_chunk=4, interpret=True)
    fin = np.isfinite(mr)
    assert (np.isfinite(mk) == fin).all()
    np.testing.assert_allclose(mk[fin], mr[fin], rtol=1e-9, atol=0)
    gt, cmt, qst = _tie_case(3)
    csrt = gt.to_csr_arrays()
    mrt, brt = sweep_columns_ref(csrt, cmt, qst)
    mkt, bkt = sweep_columns(csrt, cmt, qst, slot_chunk=4, interpret=True)
    _assert_bitequal(mrt, mkt)
    assert (brt == bkt).all()


# -- minimax / exact-K kernel modes (§4.4 objective matrix) -------------------


def _exactk_bounds(bsts, n, n_bursts):
    """The shared host parent walk over an exact-K (vals, bsts) table."""
    bounds = []
    j, b = n, n_bursts
    while j > 0:
        i = int(bsts[j - 1, b])
        bounds.append((i, j))
        j, b = i - 1, b - 1
    bounds.reverse()
    return bounds


@pytest.mark.parametrize("seed", range(20))
def test_minimax_ref_matches_numpy_qmin(seed):
    """The minimax CSR oracle's mm[n] is bit-identical to the numpy q_min
    (max/min combines are exact in float64)."""
    g, cm, _ = _case(300 + seed)
    mns, bests = sweep_columns_minimax_ref(g.to_csr_arrays(), cm)
    assert mns[g.n_tasks - 1, 0] == q_min(g, cm), seed
    assert (bests >= 1).all()


@pytest.mark.parametrize("seed", range(20))
def test_minimax_kernel_matches_ref_bitexact(seed):
    """Pallas minimax mode (interpret, slot_chunk=1) is bit-identical to the
    CSR oracle — mns AND argmin bests, every column."""
    g, cm, _ = _case(300 + seed)
    csr = g.to_csr_arrays()
    mr, br = sweep_columns_minimax_ref(csr, cm)
    mk, bk = sweep_columns(csr, cm, (), objective="minimax", interpret=True)
    _assert_bitequal(mr, mk, seed)
    assert (br == bk).all(), seed


@pytest.mark.parametrize("seed", range(12))
def test_exactk_ref_matches_numpy_dp(seed):
    """The exact-K CSR oracle reconstructs the numpy _optimal_k partition —
    bounds AND e_total — for both combines, feasible and infeasible Qs."""
    g, cm, qs = _case(320 + seed)
    n = g.n_tasks
    csr = g.to_csr_arrays()
    for K in sorted({1, max(1, n // 2), n}):
        for kobj in ("sum", "max"):
            for q in (None, qs[2]):
                vals, bsts = sweep_columns_exactk_ref(csr, cm, q, K, kobj)
                try:
                    part = _optimal_k(g, cm, K, q, kobj)
                except Infeasible:
                    assert not np.isfinite(vals[n - 1, K]), (seed, K, kobj, q)
                    continue
                assert np.isfinite(vals[n - 1, K]), (seed, K, kobj, q)
                assert _exactk_bounds(bsts, n, K) == part.bounds, \
                    (seed, K, kobj, q)


@pytest.mark.parametrize("kobj", ["sum", "max"])
@pytest.mark.parametrize("seed", range(12))
def test_exactk_kernel_matches_ref_bitexact(seed, kobj):
    """Pallas exact_k mode (interpret, slot_chunk=1): the burst-count lane
    axis reproduces the CSR oracle's (vals, bsts) bit-for-bit, including
    the degenerate b=0 lane (inf, parent 1)."""
    g, cm, qs = _case(320 + seed)
    n = g.n_tasks
    csr = g.to_csr_arrays()
    for K in sorted({1, max(1, n // 2), n}):
        for q in (None, qs[2]):
            vr, br = sweep_columns_exactk_ref(csr, cm, q, K, kobj)
            vk, bk = sweep_columns(
                csr, cm, (q,), objective="exact_k", n_bursts=K,
                k_objective=kobj, interpret=True,
            )
            _assert_bitequal(vr, vk, (seed, K, kobj, q))
            assert (br == bk).all(), (seed, K, kobj, q)
            _assert_bitequal(vr[:, 0], np.full(vr.shape[0], np.inf))
            assert (br[:, 0] == 1).all()


@pytest.mark.parametrize("tile", [8, 64])
def test_objective_modes_tile_invariance(tile):
    """Cross-tile combining in the minimax and exact-K modes keeps the
    first-minimum rule under any i-tiling (the exact-K lane shift must not
    interact with tile boundaries)."""
    g, cm, qs = _tie_case(0)
    csr = g.to_csr_arrays()
    mr, br = sweep_columns_minimax_ref(csr, cm)
    mk, bk = sweep_columns(
        csr, cm, (), objective="minimax", tile=tile, interpret=True
    )
    _assert_bitequal(mr, mk, tile)
    assert (br == bk).all(), tile
    K = max(1, g.n_tasks // 2)
    vr, brr = sweep_columns_exactk_ref(csr, cm, qs[2], K, "sum")
    vk, bkk = sweep_columns(
        csr, cm, (qs[2],), objective="exact_k", n_bursts=K, tile=tile,
        interpret=True,
    )
    _assert_bitequal(vr, vk, tile)
    assert (brr == bkk).all(), tile


@pytest.mark.parametrize("backend", ["scan", "pallas"])
def test_engine_objectives_match_numpy(backend):
    """Engine.solve routes minimax/exact_k to the named jit backend and both
    reproduce the numpy oracles (pallas bit-identically on every graph)."""
    for seed in (5, 17, 23):
        g, cm, qs = _case(340 + seed)
        s = solve(PartitionSpec(graph=g, cost=cm, objective="minimax",
                                backend=backend))
        assert s.q_min() == q_min(g, cm), (seed, backend)
        K = max(1, g.n_tasks // 2)
        for kobj in ("sum", "max"):
            ref = _optimal_k(g, cm, K, None, kobj)
            p = solve(PartitionSpec(graph=g, cost=cm, objective="exact_k",
                                    n_bursts=K, k_objective=kobj,
                                    backend=backend)).partition()
            assert p.bounds == ref.bounds and p.e_total == ref.e_total, \
                (seed, backend, kobj)


def test_csr_export_minimax_routes_to_pallas():
    """A GraphCSRArrays export now solves minimax under backend='auto' (it
    used to be an ExportMismatch — no minimax-capable backend took CSR)."""
    g, cm, _ = _case(6)
    s = solve(PartitionSpec(graph=g.to_csr_arrays(), cost=cm,
                            objective="minimax"))
    assert s.backend == "pallas"
    assert s.q_min() == q_min(g, cm)
    assert partition_jax._select_backend(
        g.to_csr_arrays(), "auto", objective="minimax") == "pallas"


def test_zoo_config_objectives_pallas_matches_numpy():
    """A lowered model-zoo graph (coalesced fractional weights) through the
    minimax and exact-K kernel modes, bit-identical to numpy."""
    cm = tpu_host_offload_model()
    g = lower_config(REGISTRY["qwen1.5-0.5b"], batch=2, seq=256)
    s = solve(PartitionSpec(graph=g, cost=cm, objective="minimax",
                            backend="pallas"))
    assert s.q_min() == q_min(g, cm)
    ref = _optimal_k(g, cm, 4, None, "sum")
    p = solve(PartitionSpec(graph=g, cost=cm, objective="exact_k",
                            n_bursts=4, backend="pallas")).partition()
    assert p.bounds == ref.bounds and p.e_total == ref.e_total


# -- three-way exact-tie audit (ROADMAP) --------------------------------------


@pytest.mark.parametrize("seed", range(20))
def test_tie_audit_numpy_scan_pallas(seed):
    """On the adversarial equal-cost family every summation order is exact,
    so ties are exact ties everywhere — numpy DP, scan backend, and
    CSR/Pallas backend must agree on e_total bits AND reconstructed bounds
    (argmin tie-break: smallest burst start wins)."""
    g, cm, qs = _tie_case(seed)
    ref = optimal_partition_multi(g, cm, qs)
    scan = sweep_jax(g, cm, qs, backend="scan")
    pall = sweep_jax(g, cm, qs, backend="pallas")
    _assert_bitequal(scan.dp, pall.dp, seed)
    for qi, (q, r) in enumerate(zip(qs, ref)):
        if r is None:
            assert not scan.feasible[qi] and not pall.feasible[qi], (seed, q)
            continue
        assert scan.e_total[qi] == r.e_total == pall.e_total[qi], (seed, q)
        assert scan.bounds(qi) == r.bounds == pall.bounds(qi), (seed, q)


@pytest.mark.parametrize("slot_chunk", [1, 4])
@pytest.mark.parametrize("seed", range(12))
def test_tie_audit_chunked_all_objectives(seed, slot_chunk):
    """The exact-tie audit at both slot-loop modes, all three kernel
    objectives: dyadic costs make even the chunked 2-D reductions exact, so
    slot_chunk>1 must keep mns AND argmin bests bit-identical to the
    oracles — not just ~ulp-close (this pins the chunked max/argmin
    reduction's tie-breaks, which the slot_chunk=1 audit never exercised)."""
    g, cm, qs = _tie_case(seed)
    csr = g.to_csr_arrays()
    mr, br = sweep_columns_ref(csr, cm, qs)
    mk, bk = sweep_columns(csr, cm, qs, slot_chunk=slot_chunk, interpret=True)
    _assert_bitequal(mr, mk, ("sum", seed, slot_chunk))
    assert (br == bk).all(), ("sum", seed, slot_chunk)
    mr2, br2 = sweep_columns_minimax_ref(csr, cm)
    mk2, bk2 = sweep_columns(
        csr, cm, (), objective="minimax", slot_chunk=slot_chunk,
        interpret=True,
    )
    _assert_bitequal(mr2, mk2, ("minimax", seed, slot_chunk))
    assert (br2 == bk2).all(), ("minimax", seed, slot_chunk)
    K = max(1, g.n_tasks // 2)
    for kobj in ("sum", "max"):
        for q in (None, qs[2]):
            vr, brr = sweep_columns_exactk_ref(csr, cm, q, K, kobj)
            vk, bkk = sweep_columns(
                csr, cm, (q,), objective="exact_k", n_bursts=K,
                k_objective=kobj, slot_chunk=slot_chunk, interpret=True,
            )
            _assert_bitequal(vr, vk, ("exact_k", seed, slot_chunk, kobj, q))
            assert (brr == bkk).all(), ("exact_k", seed, slot_chunk, kobj, q)


# -- engine integration -------------------------------------------------------


@pytest.mark.parametrize("seed", range(10))
def test_engine_pallas_vs_scan(seed):
    g, cm, qs = _case(200 + seed)
    a = sweep_jax(g, cm, qs, backend="scan")
    b = sweep_jax(g, cm, qs, backend="pallas")
    _assert_bitequal(a.dp, b.dp, seed)
    assert (a.feasible == b.feasible).all()
    for qi in range(len(qs)):
        assert a.bounds(qi) == b.bounds(qi), (seed, qi)


def test_backend_selection():
    g, _, _ = _case(1)
    assert partition_jax._select_backend(g, "scan") == "scan"
    assert partition_jax._select_backend(g, "pallas") == "pallas"
    assert partition_jax._select_backend(g, "auto") == "scan"  # tiny graph
    assert partition_jax._select_backend(g.to_arrays(), "auto") == "scan"
    assert partition_jax._select_backend(g.to_csr_arrays(), "auto") == "pallas"
    with pytest.raises(ValueError):
        partition_jax._select_backend(g, "mosaic")
    # explicit exports refuse the wrong backend instead of silently converting
    with pytest.raises(TypeError):
        sweep_jax(g.to_csr_arrays(), CM, [None], backend="scan")
    with pytest.raises(TypeError):
        sweep_jax(g.to_arrays(), CM, [None], backend="pallas")
    # the full head-count shape routes to pallas purely by export size
    full = THERMAL
    n = full.n_tasks
    r = sum(full.n_cnn)  # the sort task's read degree
    assert dense_export_nbytes(n, r, 1) > partition_jax._AUTO_DENSE_BYTES


def test_auto_threshold_routes_small_graph(monkeypatch):
    g, cm, qs = _case(2)
    monkeypatch.setattr(partition_jax, "_AUTO_DENSE_BYTES", 0)
    assert partition_jax._select_backend(g, "auto") == "pallas"
    res = sweep_jax(g, cm, qs)  # default backend="auto" → pallas
    ref = sweep_jax(g, cm, qs, backend="scan")
    _assert_bitequal(res.dp, ref.dp)


def test_batched_auto_mixed_exports(monkeypatch):
    """A legal mixed batch — dense export, CSR export, TaskGraphs resolving
    to different backends — solves per-group under backend='auto' with
    order preserved."""
    g1, g2, g3 = (_case(40 + s)[0] for s in range(3))
    monkeypatch.setattr(partition_jax, "_AUTO_DENSE_BYTES", 0)  # g3 → pallas
    qs = [None, 0.5]
    batch = [g1.to_arrays(), g2.to_csr_arrays(), g3]
    results = sweep_jax_batched(batch, CM, qs)
    for g, res in zip((g1, g2, g3), results):
        ref = optimal_partition_multi(g, CM, qs)
        for r, p in zip(ref, res.to_partitions(g, CM)):
            if r is None:
                assert p is None
            else:
                assert p is not None and p.e_total == r.e_total
                assert p.bounds == r.bounds


def test_batched_pallas_reuses_padded_rows():
    """Repeated batched solves must hand identical padded-row objects to the
    kernel wrapper (whose device cache is id-keyed): no per-request
    re-padding, re-pricing, or re-upload."""
    graphs = [_case(50 + s)[0] for s in range(3)]
    csrs = [g.to_csr_arrays() for g in graphs]
    n = max(a.n_pad for a in csrs)
    r = max(max(a.nnz_reads for a in csrs), 1)
    w = max(max(a.nnz_writes for a in csrs), 1)
    rows = [partition_jax._padded_csr(a, n, r, w) for a in csrs]
    again = [partition_jax._padded_csr(a, n, r, w) for a in csrs]
    assert all(x is y for x, y in zip(rows, again))
    # already-matching shapes short-circuit to the export itself
    assert partition_jax._padded_csr(rows[0], n, r, w) is rows[0]
    qs = [None, 1.0]
    first = sweep_jax_batched(graphs, CM, qs, backend="pallas")
    traces = sweep_kernel.TRACE_COUNT["sweep_columns"]
    second = sweep_jax_batched(graphs, CM, qs, backend="pallas")
    assert sweep_kernel.TRACE_COUNT["sweep_columns"] == traces
    for a, b in zip(first, second):
        _assert_bitequal(a.dp, b.dp)


def test_empty_and_single_task_pallas():
    assert sweep_jax(GraphBuilder().build(), CM, [None, 0.0],
                     backend="pallas").feasible.all()
    b = GraphBuilder()
    b.packet("x", 128, keep=True)
    b.task("t", writes=("x",), cost=1.0)
    g = b.build()
    p = optimal_partition_jax(g, CM, None, backend="pallas")
    assert p.n_bursts == 1
    with pytest.raises(Infeasible):
        optimal_partition_jax(g, CM, 1e-9, backend="pallas")


def test_zoo_config_pallas_matches_numpy():
    cm = tpu_host_offload_model()
    g = lower_config(REGISTRY["qwen1.5-0.5b"], batch=2, seq=256)
    qs = [None, q_min(g, cm), q_min(g, cm) * 4]
    ref = optimal_partition_multi(g, cm, qs)
    res = sweep_jax(g, cm, qs, backend="pallas")
    for q, r, p in zip(qs, ref, res.to_partitions(g, cm)):
        assert p is not None and r is not None
        assert p.e_total == r.e_total and p.bounds == r.bounds, q


def test_headcount_reduced_pallas_matches_numpy():
    """Coalesced sub-packet weights (fractional c0_weight) through the CSR
    path; slot-at-a-time order keeps even these bit-exact vs numpy."""
    g = build_graph(THERMAL.reduced(256))
    qmn = q_min(g, CM)
    qs = list(np.geomspace(qmn, g.total_task_cost() * 1.05, 16)) + [None, 0.0]
    ref = optimal_partition_multi(g, CM, qs)
    res = sweep_jax(g, CM, qs, backend="pallas")
    for q, r, p in zip(qs, ref, res.to_partitions(g, CM)):
        if r is None:
            assert p is None
            continue
        assert p is not None
        assert p.e_total == r.e_total and p.bounds == r.bounds, q
        p.validate(g)


def test_serving_loop_no_retrace_no_reupload():
    """ROADMAP 'hoist dtype handling': repeated solves of one application
    must not re-trace either backend nor re-upload the graph per request."""
    g, cm, _ = _case(4)
    qs1, qs2 = [None, 1.0], [None, 2.0]
    sweep_jax(g, cm, qs1, backend="scan")
    sweep_jax(g, cm, qs1, backend="pallas")
    t_scan = partition_jax.TRACE_COUNT["dp_sweep"]
    t_pall = sweep_kernel.TRACE_COUNT["sweep_columns"]
    ga_id = id(partition_jax._ga_dict(g.to_arrays()))
    for qs in (qs1, qs2, qs1):
        a = sweep_jax(g, cm, qs, backend="scan")
        b = sweep_jax(g, cm, qs, backend="pallas")
        _assert_bitequal(a.dp, b.dp)
    assert partition_jax.TRACE_COUNT["dp_sweep"] == t_scan
    assert sweep_kernel.TRACE_COUNT["sweep_columns"] == t_pall
    assert id(partition_jax._ga_dict(g.to_arrays())) == ga_id  # device-cached


# -- the paper's application, unreduced (slow) --------------------------------


@pytest.mark.slow
def test_full_headcount_solves_through_csr_backend():
    """The acceptance check: both full 5458-task graphs solve end-to-end via
    the CSR backend (the dense (N, R) read matrix — ~238 MB of float64 —
    is never materialized), the thermal plan reproduces the paper's
    18 bursts @ 132 mJ, bounds on the reduced cross-check are bit-equal to
    the numpy DP oracle, and the CSR export is ≥ 50× smaller than dense.
    """
    for spec in (THERMAL, VISUAL):
        g = build_graph(spec)
        assert partition_jax._select_backend(g, "auto") == "pallas"
        csr = g.to_csr_arrays()
        r = max(len(t.reads) for t in g.tasks)
        w = max(len(t.writes) for t in g.tasks)
        dense_bytes = dense_export_nbytes(g.n_tasks, r, w)
        assert dense_bytes >= 50 * csr.nbytes, (dense_bytes, csr.nbytes)

        qs = [132e-3, None]
        res = sweep_jax(g, CM, qs)  # auto → pallas
        assert res.feasible.all()
        e_app = g.total_task_cost()
        assert res.e_total[1] >= e_app  # total can't beat pure execution
        bounds = res.bounds(0)
        assert bounds is not None and bounds[0][0] == 1
        assert bounds[-1][1] == g.n_tasks
        if spec is THERMAL:
            assert len(bounds) == 18  # paper Fig. 6
            overhead = (res.e_total[0] - e_app) / res.e_total[0]
            assert overhead < 0.0012  # paper: 0.12 %

    # reduced cross-check: same pipeline, bounds bit-equal to the numpy DP
    g = build_graph(THERMAL.reduced(64))
    qs = [132e-3, q_min(g, CM), None]
    ref = optimal_partition_multi(g, CM, qs)
    res = sweep_jax(g, CM, qs, backend="pallas")
    for q, r_, p in zip(qs, ref, res.to_partitions(g, CM)):
        assert r_ is not None and p is not None
        assert p.e_total == r_.e_total and p.bounds == r_.bounds, q


@pytest.mark.slow
def test_full_headcount_minimax_exactk_pallas_vs_numpy():
    """Objective-matrix acceptance on the unreduced 5458-task graph: the
    kernel's minimax and exact-K modes are bit-identical to the numpy
    q_min / _optimal_k oracles at full scale (the numpy side column-sweeps
    the TaskGraph; the kernel side never materializes the dense export)."""
    g = build_graph(THERMAL)
    assert g.n_tasks == 5458

    s = solve(PartitionSpec(graph=g, cost=CM, objective="minimax",
                            backend="pallas"))
    assert s.q_min() == q_min(g, CM)

    # the paper's plan shape: exactly 18 bursts under the 132 mJ capacitor
    ref = _optimal_k(g, CM, 18, 132e-3)
    p = solve(PartitionSpec(graph=g, cost=CM, objective="exact_k",
                            n_bursts=18, q_max=132e-3,
                            backend="pallas")).partition()
    assert p.bounds == ref.bounds and p.e_total == ref.e_total
    assert p.n_bursts == 18
