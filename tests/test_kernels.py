"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode).

Each kernel sweeps sequence lengths that exercise multiple grid steps,
block-divisibility fallbacks, GQA ratios, and both bf16/f32, asserting
allclose against its ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apps.headcount import cnn_weights
from repro.kernels.conv_window.ops import score_windows
from repro.kernels.conv_window.ref import conv_window_scores_ref
from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_reference)
from repro.kernels.mlstm_chunk.ops import mlstm_cell
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

pytestmark = pytest.mark.slow  # interpret-mode sweeps; fast job skips these


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,KV,hd,blk", [
        (128, 4, 4, 64, 128),    # MHA, single block
        (256, 8, 4, 64, 128),    # GQA 2:1, two k blocks
        (512, 8, 2, 64, 128),    # GQA 4:1, four k blocks
        (256, 4, 4, 128, 64),    # head_dim 128, small blocks
    ])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, S, H, KV, hd, blk, dtype, causal):
        B = 2
        q = _rand(0, (B, S, H, hd), dtype)
        k = _rand(1, (B, S, KV, hd), dtype)
        v = _rand(2, (B, S, KV, hd), dtype)
        o = flash_attention(q, k, v, causal=causal, block_k=blk, interpret=True)
        o_ref = flash_attention_reference(q, k, v, causal=causal)
        tol = 0.05 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            o.astype(np.float32), o_ref.astype(np.float32), atol=tol, rtol=tol)

    def test_cross_attention_kv_len(self):
        """Non-power-of-two KV length (the 1601-vision-token case)."""
        B, Sq, Sk, H, hd = 1, 64, 1601 % 512 + 99, 4, 64  # Sk = 212
        q = _rand(0, (B, Sq, H, hd), jnp.float32)
        k = _rand(1, (B, Sk, H, hd), jnp.float32)
        v = _rand(2, (B, Sk, H, hd), jnp.float32)
        o = flash_attention(q, k, v, causal=False, block_k=Sk, interpret=True)
        o_ref = flash_attention_reference(q, k, v, causal=False)
        np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)

    def test_first_token_attends_only_itself(self):
        B, S, H, hd = 1, 128, 2, 64
        q = _rand(0, (B, S, H, hd), jnp.float32)
        k = _rand(1, (B, S, H, hd), jnp.float32)
        v = _rand(2, (B, S, H, hd), jnp.float32)
        o = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(o[:, 0], v[:, 0], atol=2e-5, rtol=2e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(7, 64), (2, 33, 256), (1, 1, 4096),
                                       (5, 3, 2, 128)])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_matches_oracle(self, shape, dtype):
        x = _rand(0, shape, dtype, scale=3.0)
        w = _rand(1, shape[-1:], jnp.float32)
        y = rmsnorm(x, w, interpret=True)
        y_ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(y.astype(np.float32),
                                   y_ref.astype(np.float32), atol=1e-2, rtol=1e-2)

    def test_unit_variance(self):
        x = _rand(0, (16, 512), jnp.float32, scale=10.0)
        y = rmsnorm(x, jnp.ones(512), interpret=True)
        ms = np.mean(np.square(np.asarray(y)), axis=-1)
        np.testing.assert_allclose(ms, 1.0, atol=1e-3)


class TestMlstmChunk:
    @pytest.mark.parametrize("S,hd,chunk", [(128, 64, 64), (256, 64, 128),
                                            (128, 128, 32), (64, 32, 64)])
    def test_matches_sequential_oracle(self, S, hd, chunk):
        B, H = 2, 2
        q = _rand(0, (B, S, H, hd), jnp.float32, 0.5)
        k = _rand(1, (B, S, H, hd), jnp.float32, 0.5)
        v = _rand(2, (B, S, H, hd), jnp.float32, 0.5)
        ip = _rand(3, (B, S, H), jnp.float32)
        fp = _rand(4, (B, S, H), jnp.float32) + 2.0
        y = mlstm_cell(q, k, v, ip, fp, chunk=chunk, interpret=True)

        def fold(a):
            return a.transpose(0, 2, 1, *range(3, a.ndim)).reshape(
                B * H, S, *a.shape[3:])

        y_ref = mlstm_ref(fold(q), fold(k), fold(v), fold(ip), fold(fp))
        y_ref = y_ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-3)

    def test_forget_gate_saturation_stable(self):
        """Strongly negative forget gates must not produce NaN (log-space m)."""
        B, S, H, hd = 1, 128, 1, 32
        q = _rand(0, (B, S, H, hd), jnp.float32)
        k = _rand(1, (B, S, H, hd), jnp.float32)
        v = _rand(2, (B, S, H, hd), jnp.float32)
        ip = jnp.full((B, S, H), 5.0)
        fp = jnp.full((B, S, H), -20.0)
        y = mlstm_cell(q, k, v, ip, fp, chunk=64, interpret=True)
        assert np.isfinite(np.asarray(y)).all()


class TestConvWindow:
    @pytest.mark.parametrize("n,seed", [(1, 0), (37, 1), (128, 2), (300, 3)])
    def test_matches_oracle(self, n, seed):
        w = cnn_weights(seed)
        wins = np.random.RandomState(seed).rand(n, 12, 12).astype(np.float32)
        s = score_windows(wins, w, interpret=True)
        s_ref = conv_window_scores_ref(jnp.asarray(wins), w["conv1"], w["b1"],
                                       w["conv2"], w["b2"], w["fc"], w["fc_b"])
        np.testing.assert_allclose(s, s_ref, atol=1e-4, rtol=1e-4)

    def test_matches_headcount_app_cnn(self):
        """The Pallas kernel scores == the head-count application's CNN task
        bodies (same weights, same windows) — the paper's kernel, TPU-native."""
        from repro.core.apps.headcount import _jax_kernels

        normalize, score_window = _jax_kernels()
        w = cnn_weights(7)
        img = np.random.RandomState(7).randint(0, 65535, (60, 80)).astype(np.uint16)
        norm = np.asarray(normalize(img))
        f = norm.astype(np.float32) / 65535.0
        coords = [(0, 0), (3, 9), (40, 60), (12, 30)]
        wins = np.stack([f[y:y + 12, x:x + 12] for (y, x) in coords])
        s_kernel = score_windows(wins, w, interpret=True)
        s_app = [float(score_window(norm, {k: jnp.asarray(v) for k, v in w.items()},
                                    1, y, x)) for (y, x) in coords]
        np.testing.assert_allclose(s_kernel, s_app, atol=1e-4, rtol=1e-4)
