"""Swarm placement tier (ISSUE 10 acceptance):

* **Input oracles** — the host-precomputed arrays
  (:func:`repro.core.placement.placement_inputs`) match brute-force
  recomputation from the graph: span NVM footprints, boundary live sets,
  per-node burst energies (compute_scale included), hop pricing.
* **Exhaustive differential** — on ≤8-task / ≤3-node seeded random and
  adversarial-tie graphs, the two-level DP equals full enumeration
  *bitwise*, including the (energy, node count, span starts, burst starts)
  tie-break key.
* **Backend bit-identity** — the ``lax.scan`` grid solver reproduces the
  numpy oracle on every smoke config and on tie-heavy random specs:
  every DP array (values *and* parents), not just the optima.
* **Engine integration** — one batched ``Engine.solve`` call sweeps a
  ≥8-link bandwidth grid (counter-pinned to a single backend solve), and
  every feasible plan's per-node energy ledgers conserve node-by-node.
* **Tables** — ``PlacementTable`` JSON round-trips bitwise and detects
  tampering / version skew.
"""

import dataclasses
import json
import math
import random

import numpy as np
import pytest

from helpers_random import (
    adversarial_tie_graph,
    random_cost_model,
    random_task_graph,
    tie_cost_model,
)

from repro.api import (
    Engine,
    EngineError,
    ExportMismatch,
    PartitionSpec,
    SpecError,
    solve,
)
from repro.configs import SMOKE_CONFIGS
from repro.core import lower_config
from repro.core.burst import burst_cost
from repro.core.graph import GraphBuilder
from repro.core.layer_profile import default_cost_model
from repro.core.placement import (
    PLACEMENT_COUNT,
    LinkModel,
    NodeSpec,
    PlacementError,
    PlacementSpec,
    PlacementTable,
    _scaled_graph,
    exhaustive_placement,
    placement_inputs,
    solve_placement_numpy,
)
from repro.obs.ledger import LedgerImbalance

ARCHS = sorted(SMOKE_CONFIGS)


def _chain_graph(costs, nbytes=None, keep_last=True):
    """A linear chain: task t reads t-1's packet, writes its own."""
    b = GraphBuilder()
    nbytes = nbytes or [64] * len(costs)
    prev = None
    for t, c in enumerate(costs):
        pkt = f"p{t}"
        b.packet(pkt, nbytes[t], keep=(keep_last and t == len(costs) - 1))
        b.task(f"t{t}", reads=(prev,) if prev else (), writes=(pkt,), cost=c)
        prev = pkt
    return b.build()


def _random_spec(rng, max_nodes=3):
    """A small random PlacementSpec mixing every axis the solver sweeps."""
    n_nodes = rng.randint(1, max_nodes)
    nodes = tuple(
        NodeSpec(
            q_max=rng.choice([None, rng.uniform(0.5, 6.0)]),
            memory_bytes=rng.choice([None, rng.uniform(50, 4000)]),
            compute_scale=rng.choice([1.0, 1.0, 0.5, 2.0]),
        )
        for _ in range(n_nodes)
    )
    links = tuple(
        LinkModel(
            bandwidth_mbps=rng.choice([900.0, 2000.0, 3300.0]),
            energy_per_byte=rng.choice([None, 0.0, 1e-3]),
            init_energy=rng.choice([0.0, 0.1]),
            rx_fraction=rng.choice([1.0, 0.5]),
        )
        for _ in range(rng.randint(1, 2))
    )
    return PlacementSpec(
        nodes=nodes,
        links=links,
        q_scales=tuple(rng.choice([(1.0,), (0.75, 1.5)])),
        memory_scales=tuple(rng.choice([(1.0,), (0.5, 2.0)])),
    )


# ---------------------------------------------------------------------------
# Spec validation
# ---------------------------------------------------------------------------


def test_link_model_validation_and_defaults():
    lk = LinkModel(bandwidth_mbps=1000.0)
    assert lk.per_byte == 8.0 / 1e9
    assert lk.name == "link-1000mbps"
    assert lk.tx_energy(100) == lk.per_byte * 100
    assert lk.hop_energy(100) == 2.0 * lk.tx_energy(100)  # rx_fraction=1
    assert lk.latency_s(1000) == 1000 * 8.0 / 1e9
    assert LinkModel(900.0, energy_per_byte=2e-9).per_byte == 2e-9
    half = LinkModel(900.0, rx_fraction=0.5)
    assert half.hop_energy(64) == 1.5 * half.tx_energy(64)
    for bad in (0.0, -1.0, math.inf, math.nan):
        with pytest.raises(PlacementError):
            LinkModel(bandwidth_mbps=bad)
    with pytest.raises(PlacementError):
        LinkModel(900.0, energy_per_byte=-1.0)
    with pytest.raises(PlacementError):
        LinkModel(900.0, rx_fraction=math.inf)


def test_node_spec_validation():
    NodeSpec()  # all-default is valid (unbounded)
    with pytest.raises(PlacementError):
        NodeSpec(q_max=0.0)
    with pytest.raises(PlacementError):
        NodeSpec(memory_bytes=-1.0)
    with pytest.raises(PlacementError):
        NodeSpec(compute_scale=0.0)
    with pytest.raises(PlacementError):
        NodeSpec(cost="not-a-model")


def test_placement_spec_validation():
    lk = LinkModel(900.0)
    spec = PlacementSpec(nodes=3, link=lk)
    assert spec.n_nodes == 3 and len(spec.nodes) == 3
    assert spec.links == (lk,) and spec.link is None  # normalized
    assert spec.grid_shape == (1, 1, 1)
    sweep = PlacementSpec(
        nodes=2, links=(lk, LinkModel(1800.0)), q_scales=(0.5, 1.0, 2.0)
    )
    assert sweep.grid_shape == (2, 1, 3)
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=0, link=lk)
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=(), link=lk)
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=("x",), link=lk)
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=2)  # neither link nor links
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=2, link=lk, links=(lk,))  # both
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=2, links=())
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=2, link=lk, q_scales=())
    with pytest.raises(PlacementError):
        PlacementSpec(nodes=2, link=lk, memory_scales=(0.0,))


def test_partition_spec_rejects_bad_placement_combos():
    g = _chain_graph([1.0, 2.0])
    cm = random_cost_model(random.Random(0))
    pl = PlacementSpec(nodes=2, link=LinkModel(900.0))
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, cost=cm, placement="nope")
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, cost=cm, placement=pl, objective="minimax")
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, cost=cm, placement=pl, q_max=1.0)
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, cost=cm, placement=pl, q_grid=(1.0, None))
    from repro.api import QGridSharding

    with pytest.raises(SpecError):
        PartitionSpec(
            graph=g, cost=cm, placement=pl, sharding=QGridSharding(n_shards=2)
        )
    # pallas registers without placement support → typed capability error
    with pytest.raises(SpecError):
        Engine().solve(
            PartitionSpec(graph=g, cost=cm, placement=pl, backend="pallas")
        )
    # placement needs the TaskGraph, not a dense/CSR export
    with pytest.raises(ExportMismatch):
        Engine().solve(
            PartitionSpec(graph=g.to_arrays(), cost=cm, placement=pl)
        )


def test_empty_graph_rejected():
    g = GraphBuilder().build()
    cm = random_cost_model(random.Random(1))
    with pytest.raises(PlacementError):
        placement_inputs(g, cm, PlacementSpec(nodes=2, link=LinkModel(900.0)))


# ---------------------------------------------------------------------------
# Input oracles
# ---------------------------------------------------------------------------


def test_placement_inputs_match_bruteforce_oracles():
    rng = random.Random(7)
    for _ in range(25):
        g = random_task_graph(rng, max_tasks=7)
        cm = random_cost_model(rng)
        spec = _random_spec(rng)
        inp = placement_inputs(g, cm, spec)
        n, N = g.n_tasks, spec.n_nodes
        L, M, Z = spec.grid_shape

        # live sets per boundary == TaskGraph.live_packets
        for b in range(n + 1):
            live = g.live_packets(b)
            assert inp.live_bytes[b] == float(
                sum(g.packets[p].nbytes for p in live)
            )
            assert inp.live_c0w[b] == float(
                sum(g.packets[p].c0_weight for p in live)
            )

        # span NVM footprint: packets whose live interval hits [i, j]
        for i in range(1, n + 1):
            for j in range(i, n + 1):
                expect = sum(
                    float(p.nbytes)
                    for name, p in g.packets.items()
                    if g.writer(name) <= j and g.l_inf[name] >= i
                )
                assert inp.mem[i, j] == expect

        # per-node burst energies: bitwise the ColumnSweep columns (the
        # actual source), ulp-close to the direct burst_cost recurrence
        # (whose accumulation order differs from the incremental sweep)
        from repro.core.burst import ColumnSweep

        for k, nd in enumerate(spec.nodes):
            sg = _scaled_graph(g, float(nd.compute_scale))
            cmk = nd.cost if nd.cost is not None else cm
            for bb, col in zip(range(1, n + 1), ColumnSweep(sg, cmk)):
                assert np.array_equal(
                    inp.energy[k, 1 : bb + 1, bb], col[1 : bb + 1]
                )
            for a in range(1, n + 1):
                for bb in range(a, n + 1):
                    assert inp.energy[k, a, bb] == pytest.approx(
                        burst_cost(sg, cmk, a, bb), rel=1e-12, abs=0.0
                    )
                for bb in range(0, a):
                    assert np.isinf(inp.energy[k, a, bb])

        # hop pricing == the LinkModel formulas
        for li, lk in enumerate(spec.links):
            tx = (
                lk.init_energy * inp.live_c0w + lk.per_byte * inp.live_bytes
            )
            assert np.array_equal(inp.hop_tx[li], tx)
            assert np.array_equal(inp.hop_rx[li], lk.rx_fraction * tx)
            assert np.array_equal(inp.hop_total[li], inp.hop_tx[li] + inp.hop_rx[li])

        assert inp.q_thresh.shape == (N, Z)
        assert inp.mem_thresh.shape == (N, M)


# ---------------------------------------------------------------------------
# Exhaustive differential (the oracle tier)
# ---------------------------------------------------------------------------


def _assert_cell_matches_oracle(sweep, inp, li, m, z, ctx):
    got = exhaustive_placement(inp, li, m, z)
    if not sweep.feasible(li, m, z):
        assert got is None, ctx
        return False
    plan = sweep.plan(li, m, z)
    plan.validate()
    assert got is not None, ctx
    e_ref, spans_ref, bursts_ref = got
    assert plan.e_total == e_ref, ctx          # bitwise, not approx
    assert plan.spans == spans_ref, ctx        # span tie-break pinned
    assert plan.node_bursts == bursts_ref, ctx  # burst tie-break pinned
    plan.check_conservation()
    return True


def test_dp_matches_exhaustive_on_random_graphs():
    rng = random.Random(0)
    feasible = 0
    for case in range(45):
        g = random_task_graph(rng, max_tasks=7)
        cm = random_cost_model(rng)
        spec = _random_spec(rng)
        inp = placement_inputs(g, cm, spec)
        sweep = solve_placement_numpy(g, cm, spec, inputs=inp)
        L, M, Z = spec.grid_shape
        for li in range(L):
            for m in range(M):
                for z in range(Z):
                    feasible += _assert_cell_matches_oracle(
                        sweep, inp, li, m, z, (case, li, m, z)
                    )
    assert feasible >= 40  # the family must actually exercise feasibility


def test_dp_matches_exhaustive_on_adversarial_ties():
    """Dyadic-cost tie families: every quantity is exactly representable,
    so equal-energy placements abound and the tie-break key is load-bearing."""
    rng = random.Random(3)
    feasible = 0
    for case in range(20):
        g = adversarial_tie_graph(rng, max_tasks=8, min_tasks=4)
        cm = tie_cost_model(rng)
        n_nodes = rng.randint(2, 3)
        spec = PlacementSpec(
            nodes=tuple(
                NodeSpec(q_max=rng.choice([None, 4.0, 8.0]))
                for _ in range(n_nodes)
            ),
            # dyadic per-byte prices keep hop sums exact → real ties survive
            links=(
                LinkModel(1000.0, energy_per_byte=rng.choice([0.0, 2.0 ** -8])),
            ),
            q_scales=(1.0,),
        )
        inp = placement_inputs(g, cm, spec)
        sweep = solve_placement_numpy(g, cm, spec, inputs=inp)
        feasible += _assert_cell_matches_oracle(sweep, inp, 0, 0, 0, case)
    assert feasible >= 15


def test_tie_break_prefers_fewest_nodes_then_earliest_cuts():
    # zero hop cost + zero startup → splitting is energy-neutral; the solver
    # must keep everything on one node (fewest nodes among optima)
    from repro.core.cost import CostModel, LinearTransfer

    g = _chain_graph([1.0, 1.0, 1.0], nbytes=[8, 8, 8])
    cm = CostModel(
        e_startup=0.0,
        read=LinearTransfer(0.0, 0.0),
        write=LinearTransfer(0.0, 0.0),
    )
    spec = PlacementSpec(
        nodes=3, link=LinkModel(900.0, energy_per_byte=0.0)
    )
    sweep = solve_placement_numpy(g, cm, spec)
    plan = sweep.plan()
    assert plan.n_nodes_used == 1
    assert plan.spans == ((1, 3),)
    # a 20-byte NVM cap rules out any span holding 3 packets: ⟨1,3⟩ needs
    # all 24 B, and ⟨2,3⟩ still carries p0 in (16 + 8). The one feasible
    # split is ⟨1,2⟩ | ⟨3,3⟩ — footprints count relayed packets, not just
    # locally written ones
    tight = PlacementSpec(
        nodes=tuple(NodeSpec(memory_bytes=20.0) for _ in range(3)),
        link=LinkModel(900.0, energy_per_byte=0.0),
    )
    plan2 = solve_placement_numpy(g, cm, tight).plan()
    assert plan2.n_nodes_used == 2
    assert plan2.spans == ((1, 2), (3, 3))
    assert plan2.hop_boundaries == (2,)


# ---------------------------------------------------------------------------
# scan backend bit-identity
# ---------------------------------------------------------------------------


def _assert_sweeps_identical(a, b, ctx=""):
    assert np.array_equal(a.e_total, b.e_total), ctx
    assert np.array_equal(a.k_used, b.k_used), ctx
    assert np.array_equal(a.outer_dp, b.outer_dp), ctx
    assert np.array_equal(a.outer_parent, b.outer_parent), ctx
    assert np.array_equal(a.inner_S, b.inner_S), ctx
    assert np.array_equal(a.inner_A, b.inner_A), ctx


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_bit_identical_to_numpy_on_smoke_configs(arch):
    from repro.core.placement_jax import solve_placement_scan

    cfg = SMOKE_CONFIGS[arch]
    cm = default_cost_model("time")
    g = lower_config(cfg, batch=2, seq=16, kind="time")
    qmin = solve(graph=g, cost=cm, objective="minimax").q_min()
    spec = PlacementSpec(
        nodes=tuple(NodeSpec(q_max=qmin * 1.25) for _ in range(3)),
        links=tuple(LinkModel(b) for b in (900.0, 1800.0, 3300.0)),
        q_scales=(0.9, 1.0, 2.0),
        memory_scales=(1.0, 0.25),
    )
    ref = solve_placement_numpy(g, cm, spec)
    got = solve_placement_scan(g, cm, spec)
    _assert_sweeps_identical(ref, got, arch)


def test_scan_bit_identical_on_tie_heavy_random_specs():
    from repro.core.placement_jax import solve_placement_scan

    rng = random.Random(11)
    for case in range(6):
        g = adversarial_tie_graph(rng, max_tasks=6, min_tasks=3)
        cm = tie_cost_model(rng)
        spec = _random_spec(rng)
        ref = solve_placement_numpy(g, cm, spec)
        got = solve_placement_scan(g, cm, spec)
        _assert_sweeps_identical(ref, got, case)


# ---------------------------------------------------------------------------
# Engine integration: one batched call, ledger conservation
# ---------------------------------------------------------------------------


def test_engine_solves_bandwidth_sweep_in_one_batched_call():
    g = lower_config(SMOKE_CONFIGS[ARCHS[0]], batch=2, seq=16, kind="time")
    cm = default_cost_model("time")
    qmin = solve(graph=g, cost=cm, objective="minimax").q_min()
    spec = PlacementSpec(
        nodes=tuple(NodeSpec(q_max=qmin * 1.25) for _ in range(3)),
        links=tuple(
            LinkModel(float(b)) for b in range(900, 3400, 300)
        ),  # 9 >= 8 link speeds
    )
    before = int(PLACEMENT_COUNT["scan"])
    sol = Engine().solve(PartitionSpec(graph=g, cost=cm, placement=spec))
    assert sol.backend == "scan"  # auto routes to the batched grid solver
    assert int(PLACEMENT_COUNT["scan"]) == before + 1  # ONE solve, whole grid
    sweep = sol.placement_sweep()
    assert sweep.grid_shape == (9, 1, 1)
    # per-node ledgers conserve on every feasible cell
    n_checked = 0
    for plan in sweep.plans():
        if plan is None:
            continue
        plan.validate()
        plan.check_conservation()
        for k, led in enumerate(plan.ledgers()):
            led.check_conservation(plan.node_spent(k))
        n_checked += 1
    assert n_checked >= 1
    # the accessor sugar matches the sweep
    assert sol.placement_plan(link_index=0).e_total == sweep.plan(0).e_total


def test_engine_numpy_backend_matches_scan():
    g = _chain_graph([0.4, 1.1, 0.2, 0.9], nbytes=[256, 64, 512, 32])
    cm = random_cost_model(random.Random(5))
    spec = PlacementSpec(
        nodes=tuple(NodeSpec(q_max=3.0) for _ in range(2)),
        links=(LinkModel(900.0), LinkModel(3300.0)),
    )
    a = Engine().solve(
        PartitionSpec(graph=g, cost=cm, placement=spec, backend="numpy")
    )
    b = Engine().solve(
        PartitionSpec(graph=g, cost=cm, placement=spec, backend="scan")
    )
    _assert_sweeps_identical(a.placement_sweep(), b.placement_sweep())


def test_non_placement_solution_carries_no_placements():
    g = _chain_graph([1.0, 2.0])
    cm = random_cost_model(random.Random(2))
    sol = solve(graph=g, cost=cm)
    with pytest.raises(EngineError):
        sol.placement_sweep()


# ---------------------------------------------------------------------------
# Plans: transfer accounting and ledgers
# ---------------------------------------------------------------------------


def _forced_split_plan():
    g = _chain_graph([1.0, 1.0, 1.0, 1.0], nbytes=[400, 400, 400, 40])
    cm = random_cost_model(random.Random(9))
    spec = PlacementSpec(
        nodes=tuple(NodeSpec(memory_bytes=900.0) for _ in range(3)),
        link=LinkModel(1000.0, init_energy=0.05, rx_fraction=0.5),
    )
    sweep = solve_placement_numpy(g, cm, spec)
    assert sweep.feasible()
    return sweep.plan()


def test_plan_transfer_accounting():
    plan = _forced_split_plan()
    assert plan.n_nodes_used >= 2  # memory cap forces a split
    assert plan.transfer_energy == sum(plan.hop_tx) + sum(plan.hop_rx)
    assert plan.transfer_overhead == plan.transfer_energy / plan.e_total
    # node totals (span energy + hop shares) reproduce the DP total
    total = sum(plan.node_spent(k) for k in range(plan.n_nodes_used))
    assert total == pytest.approx(plan.e_total, rel=1e-12)
    # hop pricing matches the link model on the boundary live sets
    for h, b in enumerate(plan.hop_boundaries):
        inp_bytes = plan.hop_bytes[h]
        assert plan.hop_rx[h] == plan.link.rx_fraction * plan.hop_tx[h]
        assert plan.hop_latency_s[h] == plan.link.latency_s(inp_bytes)


def test_plan_ledger_conservation_and_imbalance():
    plan = _forced_split_plan()
    plan.check_conservation()
    leds = plan.ledgers()
    assert len(leds) == plan.n_nodes_used
    # receiver nodes carry an RX commit row; senders a TX commit row
    assert any(e.category == "commit" for e in leds[0].entries)
    # a perturbed total must trip the gate
    bad = dataclasses.replace(plan, e_total=plan.e_total * 1.01)
    with pytest.raises(LedgerImbalance):
        bad.check_conservation()


def test_infeasible_cell_raises_typed_error():
    g = _chain_graph([5.0, 5.0])
    cm = random_cost_model(random.Random(4))
    spec = PlacementSpec(
        nodes=tuple(NodeSpec(q_max=1e-6) for _ in range(2)),
        link=LinkModel(900.0),
    )
    sweep = solve_placement_numpy(g, cm, spec)
    assert not sweep.feasible()
    with pytest.raises(PlacementError):
        sweep.plan()
    assert all(p is None for p in sweep.plans())


# ---------------------------------------------------------------------------
# PlacementTable
# ---------------------------------------------------------------------------


def _small_table():
    g = _chain_graph([0.5, 0.8, 0.3], nbytes=[128, 64, 16])
    cm = random_cost_model(random.Random(6))
    spec = PlacementSpec(
        nodes=2,
        links=(LinkModel(900.0), LinkModel(1800.0)),
        q_scales=(1.0, 2.0),
    )
    return PlacementTable(
        solve_placement_numpy(g, cm, spec), meta={"arch": "unit-test"}
    )


def test_placement_table_roundtrip(tmp_path):
    table = _small_table()
    path = str(tmp_path / "table.json")
    table.to_json(path)
    back = PlacementTable.from_json(path)
    assert back.fingerprint() == table.fingerprint()
    assert back.grid_shape == table.grid_shape
    assert back.bandwidths == table.bandwidths
    assert np.array_equal(
        np.asarray(back.e_total), np.asarray(table.e_total), equal_nan=True
    )
    assert back.meta["arch"] == "unit-test"
    assert back.cell(0, 0, 0) == table.cell(0, 0, 0)


def test_placement_table_tamper_and_version_skew(tmp_path):
    table = _small_table()
    path = str(tmp_path / "table.json")
    table.to_json(path)
    payload = json.load(open(path))
    payload["e_total"][0][0][0] = 123.0
    tampered = str(tmp_path / "tampered.json")
    json.dump(payload, open(tampered, "w"))
    with pytest.raises(PlacementError):
        PlacementTable.from_json(tampered)
    payload2 = json.load(open(path))
    payload2["version"] = 99
    skewed = str(tmp_path / "skewed.json")
    json.dump(payload2, open(skewed, "w"))
    with pytest.raises(PlacementError):
        PlacementTable.from_json(skewed)
