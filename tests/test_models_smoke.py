"""Per-architecture smoke tests (deliverable f): reduced configs of the same
family run one forward/train step on CPU asserting output shapes + no NaNs,
and decode extends prefill consistently."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, REGISTRY, SMOKE_CONFIGS
from repro.models import api

pytestmark = pytest.mark.slow  # ~minutes of XLA compiles; fast job skips these


def _batch(cfg, B, S, key=1):
    tok = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab)
    b = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        b["vision"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_vision_tokens, cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.family == "encdec":
        b["audio"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_audio_frames, cfg.d_model)
        ).astype(jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestSmoke:
    def test_full_config_registered(self, arch):
        cfg = REGISTRY[arch]
        assert cfg.param_count() > 0
        assert SMOKE_CONFIGS[arch].family == cfg.family

    def test_train_step_finite(self, arch):
        cfg = SMOKE_CONFIGS[arch]
        params, _ = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=32)
        l, ce = api.loss(cfg, params, _batch(cfg, 2, 32))
        assert np.isfinite(float(l)) and np.isfinite(float(ce))
        # one gradient step moves the loss
        grads = jax.grad(lambda p: api.loss(cfg, p, _batch(cfg, 2, 32))[0])(params)
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_prefill_decode_consistent(self, arch):
        cfg = SMOKE_CONFIGS[arch]
        MAX, S_pre = 40, 24
        params, _ = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=MAX)
        b = _batch(cfg, 2, S_pre)
        pre = dict(b)
        pre.pop("labels")
        logits, cache = api.prefill(cfg, params, pre, max_seq=MAX)
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        lg2, cache = api.decode_step(cfg, params, cache, tok, jnp.int32(S_pre))
        assert lg2.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(lg2, np.float32)).all()


def test_all_ten_archs_assigned():
    assert len(ALL_ARCHS) == 10
    fams = {REGISTRY[a].family for a in ALL_ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "encdec", "vlm"}
