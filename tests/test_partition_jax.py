"""Differential suite: the jitted engine vs the three numpy oracles.

On ≥100 randomized graphs (1–20 tasks, random Q grids straddling the
feasibility boundary) the jitted ``sweep_jax`` must agree with

* :func:`optimal_partition_multi` — e_total AND reconstructed bounds
  (bit-exact: the engine replays the numpy accumulation order, so even
  argmin tie-breaks match on unit-``c0_weight`` graphs);
* :func:`dijkstra_partition` — e_total on every feasible Q;
* :func:`brute_force_partition` — e_total on graphs small enough to
  enumerate (n ≤ 9);

including the Infeasible/None cases and the empty graph. A second block
checks every lowerable model-zoo config, the cross-graph vmapped batch
path, and the head-count app (coalesced sub-packet weights, where XLA's
FMA contraction allows ulp-level drift → 1e-6 rel as per spec, asserted
far tighter).
"""

import random

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from helpers_random import (
    adversarial_tie_graph,
    random_cost_model,
    random_q_grid,
    random_task_graph,
    tie_cost_model,
    tie_q_grid,
)

from repro.configs import REGISTRY
from repro.core import (
    PAPER_FRAM_MODEL,
    GraphBuilder,
    Infeasible,
    brute_force_partition,
    dijkstra_partition,
    lower_zoo,
    optimal_partition_multi,
    q_min,
    stack_graph_arrays,
    tpu_host_offload_model,
    whole_app_partition,
)
from repro.core.apps.headcount import THERMAL, build_graph
from repro.core.partition_jax import (
    optimal_partition_jax,
    sweep_jax,
    sweep_jax_batched,
)

CM = PAPER_FRAM_MODEL

# One padded shape for every random graph → a single XLA compilation serves
# the whole 100-graph suite (padding correctness is itself under test).
PAD = dict(n_pad=20, r_pad=3, w_pad=2)

REL = 1e-6  # spec'd tolerance; the engine is asserted exact/1e-9 below


def _assert_matches_oracles(g, cm, qs):
    ref = optimal_partition_multi(g, cm, qs)
    res = sweep_jax(g.to_arrays(**PAD), cm, qs)
    parts = res.to_partitions(g, cm)
    for q, r, p in zip(qs, ref, parts):
        if r is None:
            assert p is None, f"jax feasible where numpy Infeasible (Q={q})"
            with pytest.raises(Infeasible):
                dijkstra_partition(g, cm, q)
            continue
        assert p is not None, f"jax Infeasible where numpy feasible (Q={q})"
        # vs the fused numpy DP: bit-exact, including reconstructed bounds
        assert p.e_total == r.e_total
        assert p.bounds == r.bounds
        # vs the paper's explicit state-graph Dijkstra
        dj = dijkstra_partition(g, cm, q)
        assert p.e_total == pytest.approx(dj.e_total, rel=REL, abs=1e-12)
        # vs exhaustive search (test oracle) where enumerable
        if g.n_tasks <= 9:
            bf = brute_force_partition(g, cm, q)
            assert p.e_total == pytest.approx(bf.e_total, rel=REL, abs=1e-12)
        p.validate(g)


@pytest.mark.parametrize("seed", range(100))
def test_differential_random_graphs(seed):
    rng = random.Random(seed)
    g = random_task_graph(rng, max_tasks=20)
    cm = random_cost_model(rng)
    qs = random_q_grid(rng, q_min(g, cm), whole_app_partition(g, cm).e_total)
    _assert_matches_oracles(g, cm, qs)


@pytest.mark.parametrize("seed", range(25))
def test_differential_tie_graphs(seed):
    """Exact-tie audit (ROADMAP): on the adversarial equal-cost family every
    burst cost is a dyadic rational, so DP argmin ties are exact everywhere —
    the engine must reconstruct the *same bounds* as the numpy DP (smallest
    burst start wins), not merely the same totals. The three-way check
    including the CSR/Pallas backend lives in tests/test_partition_sweep.py.
    """
    rng = random.Random(7000 + seed)
    g = adversarial_tie_graph(rng)
    cm = tie_cost_model(rng)
    qs = tie_q_grid(rng, q_min(g, cm), whole_app_partition(g, cm).e_total)
    _assert_matches_oracles(g, cm, qs)


def test_empty_graph_feasible_everywhere():
    g = GraphBuilder().build()
    res = sweep_jax(g, CM, [None, 0.0, 1.0])
    assert res.feasible.all() and (res.e_total == 0.0).all()
    parts = res.to_partitions(g, CM)
    assert all(p is not None and p.n_bursts == 0 for p in parts)


def test_single_q_convenience_raises_infeasible():
    b = GraphBuilder()
    b.packet("x", 100, keep=True)
    b.task("t", writes=("x",), cost=1.0)
    g = b.build()
    p = optimal_partition_jax(g, CM, None)
    assert p.n_bursts == 1
    with pytest.raises(Infeasible):
        optimal_partition_jax(g, CM, 1e-6)


def test_dp_and_parent_tables_match_recurrence():
    """dp[q, j] must be monotone in q and parent must reconstruct dp."""
    rng = random.Random(12345)
    g = random_task_graph(rng, max_tasks=12, min_tasks=8)
    cm = random_cost_model(rng)
    qmn = q_min(g, cm)
    qs = [qmn, qmn * 2.0, None]
    res = sweep_jax(g, cm, qs)
    n = g.n_tasks
    assert res.dp.shape == (3, res.dp.shape[1]) and res.dp[:, 0].min() == 0.0
    # larger budget → every dp entry no worse
    assert (res.dp[1, : n + 1] <= res.dp[0, : n + 1] + 1e-12).all()
    # bounds from parents cover 1..n contiguously
    for qi in range(3):
        bounds = res.bounds(qi)
        assert bounds is not None
        assert bounds[0][0] == 1 and bounds[-1][1] == n
        for (a, b2), (c, _) in zip(bounds, bounds[1:]):
            assert c == b2 + 1


# -- model zoo ----------------------------------------------------------------


def test_zoo_configs_match_numpy_multi():
    """Every lowerable config, solved in one vmapped batch, matches the
    numpy DP exactly (zoo packets have unit c0_weight)."""
    cm = tpu_host_offload_model()
    zoo = lower_zoo(batch=2, seq=256)
    assert set(zoo) == set(REGISTRY)
    names = sorted(zoo)
    qmns = {name: q_min(zoo[name], cm) for name in names}
    q_hi = max(qmns.values()) * 4
    qs = [None, 0.0, min(qmns.values()), q_hi]
    results = sweep_jax_batched([zoo[n] for n in names], cm, qs)
    for name, res in zip(names, results):
        g = zoo[name]
        ref = optimal_partition_multi(g, cm, qs)
        parts = res.to_partitions(g, cm)
        for q, r, p in zip(qs, ref, parts):
            if r is None:
                assert p is None, (name, q)
            else:
                assert p is not None, (name, q)
                assert p.e_total == r.e_total, (name, q)
                assert p.bounds == r.bounds, (name, q)


def test_zoo_memory_kind_q_min_sweep():
    """The §4.4 storage-minimization reading: Q_max bounds per-segment
    activation bytes; sweeping tight→loose must be feasible above Q_min."""
    from repro.core import memory_cost_model

    cm = memory_cost_model()
    zoo = lower_zoo(batch=1, seq=128, kind="memory")
    for name, g in sorted(zoo.items()):
        qmn = q_min(g, cm)
        res = sweep_jax(g, cm, [qmn * 0.5, qmn, qmn * 4])
        assert not res.feasible[0] or qmn == 0.0
        assert res.feasible[1] and res.feasible[2]
        assert res.e_total[2] <= res.e_total[1] + 1e-9


def test_stacked_arrays_roundtrip():
    """stack_graph_arrays pads heterogeneous graphs without changing any
    per-graph solution."""
    rng = random.Random(7)
    graphs = [random_task_graph(rng, max_tasks=6 + 2 * k) for k in range(4)]
    stacked = stack_graph_arrays([g.to_arrays() for g in graphs])
    assert stacked.e_task.shape[0] == len(graphs)
    qs = [None, 0.5]
    for g, res in zip(graphs, sweep_jax_batched(graphs, CM, qs)):
        ref = optimal_partition_multi(g, CM, qs)
        for r, p in zip(ref, res.to_partitions(g, CM)):
            if r is None:
                assert p is None
            else:
                assert p is not None and p.e_total == r.e_total


# -- the paper's application --------------------------------------------------


def test_headcount_reduced_matches_numpy():
    """Coalesced score arrays carry fractional c0_weight, where XLA FMA
    contraction may drift by ~1 ulp — assert well inside the 1e-6 spec."""
    g = build_graph(THERMAL.reduced(256))
    qmn = q_min(g, CM)
    qs = list(np.geomspace(qmn, g.total_task_cost() * 1.05, 64)) + [None, 0.0]
    ref = optimal_partition_multi(g, CM, qs)
    res = sweep_jax(g, CM, qs)
    parts = res.to_partitions(g, CM)
    for q, r, p in zip(qs, ref, parts):
        if r is None:
            assert p is None
            continue
        assert p is not None
        assert p.e_total == pytest.approx(r.e_total, rel=1e-9)
        assert p.n_bursts == r.n_bursts
        p.validate(g)
