"""Julienne-planner tests: pipeline / offload / remat over the model zoo,
plus optimal_partition_k invariants.

The partition-k properties are plain ``check_*`` functions driven by a
stdlib-``random`` seed parametrization (always runs) and additionally by
hypothesis when it is installed (``pytest.importorskip`` semantics — the
fuzz class simply does not exist without it).
"""

import random

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from repro.configs import REGISTRY
from repro.core import (GraphBuilder, Infeasible, PAPER_FRAM_MODEL,
                        brute_force_partition, optimal_partition_k, q_min)
from repro.core.layer_profile import build_activation_graph, profile_model
from repro.core.offload import min_activation_budget, plan_offload
from repro.core.pipeline import plan_pipeline
from repro.core.remat_policy import plan_remat, segments_for_scan

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def chain_graph(costs, nbytes=1000):
    b = GraphBuilder()
    prev = None
    for i, c in enumerate(costs):
        p = b.packet(f"p{i}", nbytes, keep=(i == len(costs) - 1))
        b.task(f"t{i}", reads=(prev,) if prev else (), writes=(p,), cost=c)
        prev = p
    return b.build()


def check_k_bursts_exact_count(costs, k):
    if k > len(costs):
        k = len(costs)
    g = chain_graph(costs)
    p = optimal_partition_k(g, PAPER_FRAM_MODEL, k)
    assert p.n_bursts == k
    p.validate(g)


def check_minimax_beats_uniform_split(costs):
    g = chain_graph(costs)
    k = 3 if len(costs) >= 3 else len(costs)
    p = optimal_partition_k(g, PAPER_FRAM_MODEL, k, objective="max")
    # uniform split is a candidate → optimum bottleneck ≤ its bottleneck
    n = len(costs)
    bounds, start = [], 1
    for s in range(k):
        end = (s + 1) * n // k
        bounds.append((start, end))
        start = end + 1
    from repro.core.burst import burst_cost
    uniform_max = max(burst_cost(g, PAPER_FRAM_MODEL, i, j) for i, j in bounds)
    assert p.max_burst <= uniform_max + 1e-9


class TestPartitionK:
    @pytest.mark.parametrize("seed", range(30))
    def test_k_bursts_exact_count(self, seed):
        rng = random.Random(seed)
        costs = [rng.uniform(0.1, 5.0) for _ in range(rng.randint(2, 10))]
        check_k_bursts_exact_count(costs, rng.randint(1, 5))

    @pytest.mark.parametrize("seed", range(20))
    def test_minimax_beats_uniform_split(self, seed):
        rng = random.Random(100 + seed)
        costs = [rng.uniform(0.1, 5.0) for _ in range(rng.randint(3, 9))]
        check_minimax_beats_uniform_split(costs)

    def test_k_equals_brute_force(self):
        g = chain_graph([1.0, 3.0, 0.5, 2.0, 1.5])
        p = optimal_partition_k(g, PAPER_FRAM_MODEL, 2)
        # brute force over all 2-burst splits
        from repro.core.burst import burst_cost
        best = min(
            burst_cost(g, PAPER_FRAM_MODEL, 1, c) + burst_cost(g, PAPER_FRAM_MODEL, c + 1, 5)
            for c in range(1, 5))
        assert p.e_total == pytest.approx(best, rel=1e-12)


if HAVE_HYPOTHESIS:

    class TestPartitionKFuzz:
        @settings(max_examples=40, deadline=None)
        @given(st.lists(st.floats(0.1, 5.0), min_size=2, max_size=10),
               st.integers(1, 5))
        def test_k_bursts_exact_count(self, costs, k):
            check_k_bursts_exact_count(costs, k)

        @settings(max_examples=30, deadline=None)
        @given(st.lists(st.floats(0.1, 5.0), min_size=3, max_size=9))
        def test_minimax_beats_uniform_split(self, costs):
            check_minimax_beats_uniform_split(costs)


ARCHS = ["deepseek-coder-33b", "zamba2-7b", "whisper-large-v3",
         "phi3.5-moe-42b-a6.6b", "xlstm-1.3b", "llama-3.2-vision-11b"]


@pytest.mark.parametrize("arch", ARCHS)
class TestPlanners:
    def test_pipeline_balance(self, arch):
        cfg = REGISTRY[arch]
        pp = plan_pipeline(cfg, batch=16, seq=4096, n_stages=8)
        assert pp.n_stages == 8
        assert pp.balance < 1.25  # within 25% of perfect balance
        assert pp.bottleneck_seconds > 0

    def test_offload_respects_budget(self, arch):
        cfg = REGISTRY[arch]
        qmn = min_activation_budget(cfg, 16, 4096)
        plan = plan_offload(cfg, 16, 4096, qmn * 2)
        assert all(s <= qmn * 2 * (1 + 1e-9) for s in plan.segment_peak_bytes)
        with pytest.raises(Infeasible):
            plan_offload(cfg, 16, 4096, qmn * 0.5)

    def test_remat_monotone_in_budget(self, arch):
        cfg = REGISTRY[arch]
        qmn = min_activation_budget(cfg, 4, 4096)
        fracs = []
        for m in (8.0, 16.0, 64.0):
            try:
                fracs.append(plan_remat(cfg, 4, 4096, qmn * m).recompute_fraction)
            except Infeasible:
                fracs.append(None)
        feas = [f for f in fracs if f is not None]
        assert len(feas) >= 2, "budgets too tight for this arch"
        # more memory → no more recompute
        assert all(a >= b - 1e-12 for a, b in zip(feas, feas[1:]))
        plan = plan_remat(cfg, 4, 4096, qmn * 64)
        n, seg = segments_for_scan(cfg.n_layers, plan)
        assert n * seg == cfg.n_layers


class TestDependencyAwareness:
    def test_whisper_keeps_enc_out_resident(self):
        """The encoder output has l_∞ = last decoder layer: a single burst
        over all decoder layers loads it exactly once (the paper's image
        packet pattern)."""
        cfg = REGISTRY["whisper-large-v3"]
        profiles, ll = profile_model(cfg, 16, 4096)
        g = build_activation_graph(profiles, ll, kind="time")
        from repro.core import burst_detail, tpu_pipeline_model
        n_enc = cfg.n_encoder_layers
        d = burst_detail(g, tpu_pipeline_model(), n_enc + 1, g.n_tasks)
        assert d.loads.count("enc_out") == 1

    def test_zamba_boundaries_after_mamba(self):
        """Pipeline cuts should not strand the shared-attn's embed0 input
        needlessly — every stage after the first reads it exactly once."""
        cfg = REGISTRY["zamba2-7b"]
        pp = plan_pipeline(cfg, 16, 4096, 4)
        profiles, ll = profile_model(cfg, 16, 4096)
        g = build_activation_graph(profiles, ll, kind="time")
        from repro.core import burst_detail, tpu_pipeline_model
        for (i, j) in pp.bounds:
            d = burst_detail(g, tpu_pipeline_model(), i, j)
            assert d.loads.count("embed0") <= 1
