"""Optimality and invariant tests for the partitioner (paper §4.3–4.4).

Property invariants:

* the fused DP, the paper's state-graph Dijkstra, and exhaustive search agree;
* Q_min from the minimax sweep equals the brute-force bottleneck;
* a partition exists iff Q_max ≥ Q_min;
* E_total and N_bursts are monotone non-increasing in Q_max;
* every returned partition is structurally valid and within budget.

Each property is a plain ``check_*`` function. A stdlib-``random``
seed-parametrized driver always runs them (so the suite works in minimal
environments); when hypothesis is installed the same checks additionally run
under its fuzzer. ``pytest.importorskip`` guards the hypothesis-only class.
"""

import random

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from helpers_random import random_cost_model, random_task_graph

from repro.core import (
    PAPER_FRAM_MODEL,
    GraphBuilder,
    Infeasible,
    brute_force_partition,
    dijkstra_partition,
    optimal_partition,
    optimal_partition_multi,
    q_min,
    q_min_bruteforce,
    single_task_partition,
    sweep,
    whole_app_partition,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CM = PAPER_FRAM_MODEL


# -- the properties (shared between both drivers) ------------------------------


def check_dp_equals_bruteforce_and_dijkstra(g, cm, qscale):
    qmn = q_min(g, cm)
    whole = whole_app_partition(g, cm).e_total
    q = qmn + qscale * (whole - qmn) / 3.0
    bf = brute_force_partition(g, cm, q)
    dp = optimal_partition(g, cm, q)
    dj = dijkstra_partition(g, cm, q)
    assert dp.e_total == pytest.approx(bf.e_total, rel=1e-9, abs=1e-12)
    assert dj.e_total == pytest.approx(bf.e_total, rel=1e-9, abs=1e-12)
    dp.validate(g)
    dj.validate(g)


def check_qmin_matches_bruteforce(g, cm):
    assert q_min(g, cm) == pytest.approx(q_min_bruteforce(g, cm), rel=1e-9, abs=1e-12)


def check_feasibility_boundary(g, cm):
    qmn = q_min(g, cm)
    # feasible exactly at Q_min
    p = optimal_partition(g, cm, qmn)
    assert p.max_burst <= qmn * (1 + 1e-9) + 1e-12
    # infeasible strictly below (when Q_min is positive)
    if qmn > 1e-9:
        with pytest.raises(Infeasible):
            optimal_partition(g, cm, qmn * 0.99 - 1e-12)


def check_monotonicity_in_qmax(g, cm):
    qmn = q_min(g, cm)
    whole = whole_app_partition(g, cm).e_total
    qs = np.linspace(qmn, max(whole, qmn) * 1.01, 8)
    parts = optimal_partition_multi(g, cm, list(qs))
    assert all(p is not None for p in parts)
    e = [p.e_total for p in parts]
    nb = [p.n_bursts for p in parts]
    assert all(a >= b - 1e-9 for a, b in zip(e, e[1:])), "E_total must not increase"
    # N_bursts is not guaranteed strictly monotone pointwise for equal-cost
    # ties, but the optimum cost is; check bursts never exceed the Q_min count.
    assert max(nb) <= parts[0].n_bursts


def check_unbounded_at_most_whole_app(g, cm):
    # With no Q_max the optimum is at most the whole-app cost (one burst is
    # always a candidate).
    p = optimal_partition(g, cm, None)
    assert p.e_total <= whole_app_partition(g, cm).e_total + 1e-12


def check_optimal_beats_baselines(g, cm):
    qmn = q_min(g, cm)
    p = optimal_partition(g, cm, None)
    st_ = single_task_partition(g, cm, naive_state_retention=True)
    assert p.e_total <= st_.e_total + 1e-9
    p2 = optimal_partition(g, cm, qmn)
    st2 = single_task_partition(g, cm, naive_state_retention=False)
    # dependency-optimized single-task is also a valid partition → optimum ≤ it
    if st2.max_burst <= qmn * (1 + 1e-9):
        assert p2.e_total <= st2.e_total + 1e-9


# -- driver 1: stdlib-random fallback (always runs) ----------------------------


@pytest.mark.parametrize("seed", range(40))
def test_dp_equals_bruteforce_and_dijkstra(seed):
    rng = random.Random(seed)
    check_dp_equals_bruteforce_and_dijkstra(
        random_task_graph(rng), random_cost_model(rng), rng.uniform(0.0, 3.0)
    )


@pytest.mark.parametrize("seed", range(40))
def test_qmin_matches_bruteforce(seed):
    rng = random.Random(1000 + seed)
    check_qmin_matches_bruteforce(random_task_graph(rng), random_cost_model(rng))


@pytest.mark.parametrize("seed", range(25))
def test_feasibility_boundary(seed):
    rng = random.Random(2000 + seed)
    check_feasibility_boundary(random_task_graph(rng), random_cost_model(rng))


@pytest.mark.parametrize("seed", range(25))
def test_monotonicity_in_qmax(seed):
    rng = random.Random(3000 + seed)
    check_monotonicity_in_qmax(random_task_graph(rng), random_cost_model(rng))


@pytest.mark.parametrize("seed", range(15))
def test_unbounded_at_most_whole_app(seed):
    rng = random.Random(4000 + seed)
    check_unbounded_at_most_whole_app(random_task_graph(rng), random_cost_model(rng))


@pytest.mark.parametrize("seed", range(15))
def test_optimal_beats_baselines(seed):
    rng = random.Random(5000 + seed)
    check_optimal_beats_baselines(random_task_graph(rng), random_cost_model(rng))


# -- driver 2: hypothesis fuzzing (when installed) -----------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def task_graphs(draw, max_tasks=9):
        n = draw(st.integers(1, max_tasks))
        n_ext = draw(st.integers(0, 2))
        b = GraphBuilder()
        avail = []
        for i in range(n_ext):
            b.packet(f"e{i}", draw(st.integers(1, 4000)), external=True)
            avail.append(f"e{i}")
        for t in range(n):
            n_reads = draw(st.integers(0, min(3, len(avail))))
            reads = draw(
                st.lists(st.sampled_from(avail), min_size=n_reads,
                         max_size=n_reads, unique=True)
            ) if avail else []
            n_writes = draw(st.integers(0, 2))
            writes = []
            for w in range(n_writes):
                name = f"p{t}_{w}"
                b.packet(name, draw(st.integers(1, 4000)),
                         keep=draw(st.booleans()))
                writes.append(name)
            b.task(f"t{t}", reads=tuple(reads), writes=tuple(writes),
                   cost=draw(st.floats(0.01, 10.0, allow_nan=False)))
            avail.extend(writes)
        return b.build()

    @st.composite
    def cost_models(draw):
        from repro.core import CostModel, LinearTransfer

        return CostModel(
            e_startup=draw(st.floats(0, 1.0)),
            read=LinearTransfer(draw(st.floats(0, 0.1)), draw(st.floats(0, 1e-3))),
            write=LinearTransfer(draw(st.floats(0, 0.1)), draw(st.floats(0, 1e-3))),
        )

    class TestHypothesisFuzz:
        @settings(max_examples=60, deadline=None)
        @given(task_graphs(), cost_models(), st.floats(0.0, 3.0))
        def test_dp_equals_bruteforce_and_dijkstra(self, g, cm, qscale):
            check_dp_equals_bruteforce_and_dijkstra(g, cm, qscale)

        @settings(max_examples=60, deadline=None)
        @given(task_graphs(), cost_models())
        def test_qmin_matches_bruteforce(self, g, cm):
            check_qmin_matches_bruteforce(g, cm)

        @settings(max_examples=40, deadline=None)
        @given(task_graphs(), cost_models())
        def test_feasibility_boundary(self, g, cm):
            check_feasibility_boundary(g, cm)

        @settings(max_examples=40, deadline=None)
        @given(task_graphs(), cost_models())
        def test_monotonicity_in_qmax(self, g, cm):
            check_monotonicity_in_qmax(g, cm)

        @settings(max_examples=30, deadline=None)
        @given(task_graphs(), cost_models())
        def test_unbounded_at_most_whole_app(self, g, cm):
            check_unbounded_at_most_whole_app(g, cm)

        @settings(max_examples=30, deadline=None)
        @given(task_graphs(), cost_models())
        def test_optimal_beats_baselines(self, g, cm):
            check_optimal_beats_baselines(g, cm)

else:

    def test_hypothesis_fuzz_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")


# -- deterministic regressions -------------------------------------------------


def test_sweep_none_for_infeasible():
    b = GraphBuilder()
    b.packet("x", 100, keep=True)
    b.task("t", writes=("x",), cost=1.0)
    g = b.build()
    res = sweep(g, CM, [0.1, 2.0])
    assert res[0] is None and res[1] is not None


def test_empty_graph():
    g = GraphBuilder().build()
    p = optimal_partition(g, CM, None)
    assert p.n_bursts == 0 and p.e_total == 0.0


def test_partition_summary_smoke():
    g = GraphBuilder()
    g.packet("x", 10, keep=True)
    g.task("t", writes=("x",), cost=1.0)
    p = optimal_partition(g.build(), CM, None)
    assert "bursts=1" in p.summary()
