"""NS Optimizer ingestion tier: fixture round-trip + typed error paths.

The checked-in fixture (tests/fixtures/ns_mini) is a 5-layer diamond
(conv1 → conv2a/conv2b → concat → fc). Loading it must be deterministic:
same topological order, same packet sizes, same read ordering on every
load — the placement DP's inputs depend on the task sequence.
"""

import os

import pytest

from repro.core.calibration import MeasuredCostTable
from repro.data.ns_optimizer import (
    MB,
    NSOptimizerError,
    load_ns_model,
)

FIXTURE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "fixtures", "ns_mini"
)
PROF = os.path.join(FIXTURE, "prof.csv")
DEP = os.path.join(FIXTURE, "dep.csv")


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


# ---------------------------------------------------------------------------
# Round-trip on the checked-in fixture
# ---------------------------------------------------------------------------


def test_fixture_roundtrip():
    model = load_ns_model(PROF, DEP)
    g = model.graph

    # deterministic Kahn order: prof.csv row order breaks ties
    names = [t.name for t in g.tasks]
    assert names == ["conv1", "conv2a", "conv2b", "concat", "fc"]
    assert [l.name for l in model.layers] == names
    assert model.n_layers == 5 and len(model.edges) == 5

    # packet sizes are decimal megabytes; the sink keeps its output
    assert g.packets["out:conv1"].nbytes == int(0.6 * MB)
    assert g.packets["out:fc"].nbytes == int(0.004 * MB)
    assert g.packets["out:fc"].keep
    assert not g.packets["out:concat"].keep
    assert g.packets["out:conv1"].meta["layer"] == "conv1"
    assert g.packets["out:conv1"].meta["memory_bytes"] == int(1.5 * MB)

    # reads follow prof.csv order; costs are the layer times
    concat = next(t for t in g.tasks if t.name == "concat")
    assert concat.reads == ("out:conv2a", "out:conv2b")
    assert concat.cost == 0.005
    fc = next(t for t in g.tasks if t.name == "fc")
    assert fc.reads == ("out:concat",)
    assert model.total_time_s == pytest.approx(0.058)

    # loading twice is bit-stable
    again = load_ns_model(PROF, DEP)
    assert [t.name for t in again.graph.tasks] == names
    assert [t.cost for t in again.graph.tasks] == [t.cost for t in g.tasks]
    assert "5 layers" in model.summary()


def test_calibration_rows_feed_measured_table():
    model = load_ns_model(PROF, DEP)
    rows = model.calibration_rows()
    assert len(rows) == 5
    assert all(r["category"] == "compute" for r in rows)
    assert {r["kernel"] for r in rows} == {l.name for l in model.layers}
    from repro.core.layer_profile import default_cost_model

    table = MeasuredCostTable(default_cost_model("time"), kind="time")
    table.ingest_rows(rows)
    assert table.n_samples == 5
    assert table.stats["compute"].mean == pytest.approx(
        model.total_time_s / 5
    )


def test_fixture_graph_is_placeable():
    from repro.core.placement import (
        LinkModel,
        PlacementSpec,
        solve_placement_numpy,
    )
    from repro.core.layer_profile import default_cost_model

    model = load_ns_model(PROF, DEP)
    sweep = solve_placement_numpy(
        model.graph,
        default_cost_model("time"),
        PlacementSpec(nodes=2, link=LinkModel(900.0)),
    )
    assert sweep.feasible()
    plan = sweep.plan()
    plan.validate()
    plan.check_conservation()


# ---------------------------------------------------------------------------
# Typed error paths
# ---------------------------------------------------------------------------


GOOD_PROF = "a,0.1,0.5,1.0,0\nb,0.2,0.25,0.5,0\n"


def test_prof_headerless_and_macs_optional(tmp_path):
    prof = _write(tmp_path, "prof.csv", "a,0.1,0.5,1.0\nb,0.2,0.25,0.5\n")
    dep = _write(tmp_path, "dep.csv", "a,b\n")
    model = load_ns_model(prof, dep)
    assert [l.name for l in model.layers] == ["a", "b"]
    assert model.layers[0].macs == 0.0


@pytest.mark.parametrize(
    "text,match",
    [
        ("a,0.1,0.5\n", "at least 4 columns"),
        ("a,0.1,0.5,oops,0\n", "non-numeric"),
        ("a,-0.1,0.5,1.0,0\n", "negative"),
        ("a,0.1,0.5,1.0,0\na,0.2,0.2,0.2,0\n", "duplicate layer"),
        (",0.1,0.5,1.0,0\n", "empty layer name"),
        ("", "no layers"),
        ("Layer,time,out,mem,MACs\n", "no layers"),
    ],
)
def test_malformed_prof_raises(tmp_path, text, match):
    prof = _write(tmp_path, "prof.csv", text)
    dep = _write(tmp_path, "dep.csv", "")
    with pytest.raises(NSOptimizerError, match=match):
        load_ns_model(prof, dep)


@pytest.mark.parametrize(
    "text,match",
    [
        ("a,ghost\n", "unknown layer"),
        ("a,a\n", "self-edge"),
        ("a\n", "Source,Destination"),
        ("a,\n", "Source,Destination"),
    ],
)
def test_malformed_dep_raises(tmp_path, text, match):
    prof = _write(tmp_path, "prof.csv", GOOD_PROF)
    dep = _write(tmp_path, "dep.csv", text)
    with pytest.raises(NSOptimizerError, match=match):
        load_ns_model(prof, dep)


def test_cycle_raises_with_cyclic_layers(tmp_path):
    prof = _write(
        tmp_path, "prof.csv",
        "a,0.1,0.5,1.0,0\nb,0.2,0.25,0.5,0\nc,0.3,0.1,0.2,0\n",
    )
    dep = _write(tmp_path, "dep.csv", "a,b\nb,c\nc,a\n")
    with pytest.raises(NSOptimizerError, match="cycle") as exc:
        load_ns_model(prof, dep)
    # the offending layers are named
    assert "'a'" in str(exc.value) and "'c'" in str(exc.value)


def test_duplicate_edges_dedupe(tmp_path):
    prof = _write(tmp_path, "prof.csv", GOOD_PROF)
    dep = _write(tmp_path, "dep.csv", "a,b\na,b\n")
    model = load_ns_model(prof, dep)
    assert model.edges == (("a", "b"),)
    b = next(t for t in model.graph.tasks if t.name == "b")
    assert b.reads == ("out:a",)
