"""Differential suite for the plan-table subsystem (serving-path integration).

* every smoke config × shape bucket: table lookups return segment bounds
  bit-identical to direct ``optimal_partition_jax`` / ``sweep_jax`` solves;
* save → load → lookup round-trips exactly (bounds, e_total, cycle energies);
* stale-version and unknown-bucket lookups raise cleanly;
* the fingerprint-keyed build cache short-circuits the solve;
* tabulated cut points drive the offload/remat planners to the same plans a
  direct solve produces (no re-solve on the consuming side);
* request-cycle grouping (the online half of energy-bounded serving) respects
  the shared budget tolerance.

The smoke bucket set and Q-grid derivation live in conftest.py
(``PLAN_BUCKETS`` / ``plan_grid`` / ``smoke_plan_table``), shared with
tests/test_serve_plan.py and the sharded-DSE tier in tests/test_dse_shard.py.
"""

import numpy as np
import pytest
# These suites pin the *legacy* entry points (deprecation shims) bit-for-bit
# against the facade-era implementations; the CI deprecation gate excludes
# them via -m "not legacy" (see conftest).
pytestmark = pytest.mark.legacy


from conftest import PLAN_BUCKETS

from repro.configs import SMOKE_CONFIGS
from repro.core import (
    Infeasible,
    PlanTable,
    PlanTableError,
    StaleTableError,
    UnknownBucketError,
    build_plan_table,
    config_fingerprint,
    lower_config,
    optimal_partition_jax,
    sweep_jax,
)
from repro.core import plan_table as pt_mod
from repro.core import partition_jax
from repro.core.offload import plan_offload
from repro.core.remat_policy import plan_remat
from repro.launch.planner import ServePlanner, as_planner, request_cycles

BUCKETS = PLAN_BUCKETS


@pytest.mark.parametrize("arch", sorted(SMOKE_CONFIGS))
def test_lookup_bitidentical_to_direct_solve(arch, smoke_plan_table):
    cfg, cm, qs, table = smoke_plan_table(arch)
    for (b, s) in BUCKETS:
        g = lower_config(cfg, b, s, kind="time")
        direct = sweep_jax(g, cm, qs)
        for qi, q in enumerate(qs):
            if not direct.feasible[qi]:
                with pytest.raises(Infeasible):
                    table.lookup(b, s, q)
                continue
            plan = table.lookup(b, s, q)
            assert list(plan.bounds) == direct.bounds(qi), (arch, b, s, q)
            assert plan.e_total == direct.e_total[qi], (arch, b, s, q)
            assert plan.n_tasks == g.n_tasks
        # the single-Q convenience API agrees too (bounds bit-identical)
        part = optimal_partition_jax(g, cm, qs[-2])
        assert list(table.lookup(b, s, qs[-2]).bounds) == part.bounds


def test_bucketing_rounds_seq_up(smoke_plan_table):
    _, _, qs, table = smoke_plan_table("qwen3-4b")
    # seq 20 rounds up to the (2, 32) bucket, not (2, 16)
    plan = table.lookup(2, 20, None)
    assert (plan.batch, plan.seq_bucket) == (2, 32)
    plan = table.lookup(2, 16, None)
    assert (plan.batch, plan.seq_bucket) == (2, 16)
    # budget selection: largest tabulated Q under the budget
    finite = sorted(q for q in qs if q is not None)
    k = table.q_index(finite[-1] * 1.5)
    assert table.q_grid[k] == finite[-1]
    with pytest.raises(Infeasible):
        table.q_index(finite[0] * 1e-6)


def test_roundtrip_save_load_exact(tmp_path, smoke_plan_table):
    _, _, qs, table = smoke_plan_table("whisper-large-v3")
    path = str(tmp_path / "plan.npz")
    table.save(path)
    loaded = PlanTable.load(path)
    assert loaded.header == table.header
    assert loaded.content_digest() == table.content_digest()
    np.testing.assert_array_equal(loaded.q_grid, table.q_grid)
    np.testing.assert_array_equal(loaded.e_total, table.e_total)
    np.testing.assert_array_equal(loaded.cycle_energy, table.cycle_energy)
    for (b, s) in BUCKETS:
        for q in qs:
            try:
                a = table.lookup(b, s, q)
            except Infeasible:
                with pytest.raises(Infeasible):
                    loaded.lookup(b, s, q)
                continue
            z = loaded.lookup(b, s, q)
            assert a == z  # frozen dataclass: full bit-exact equality


def test_stale_version_and_unknown_bucket(tmp_path, monkeypatch,
                                          smoke_plan_table):
    _, _, _, table = smoke_plan_table("xlstm-1.3b")
    path = str(tmp_path / "plan.npz")
    table.save(path)

    with pytest.raises(UnknownBucketError):
        table.lookup(3, 16, None)          # batch never tabulated
    with pytest.raises(UnknownBucketError):
        table.lookup(2, 33, None)          # seq beyond every bucket
    assert issubclass(UnknownBucketError, KeyError)

    monkeypatch.setattr(pt_mod, "PLAN_TABLE_VERSION", pt_mod.PLAN_TABLE_VERSION + 1)
    with pytest.raises(StaleTableError):
        PlanTable.load(path)


def test_build_cache_short_circuits_solve(tmp_path, plan_grid):
    cfg = SMOKE_CONFIGS["tinyllama-1.1b"]
    cm, qs = plan_grid(cfg)
    cache = str(tmp_path)
    built0 = dict(pt_mod.BUILD_STATS)
    t1 = build_plan_table(cfg, BUCKETS, qs, cost=cm, cache_dir=cache)
    assert pt_mod.BUILD_STATS["built"] == built0["built"] + 1
    solves = dict(partition_jax.SOLVE_COUNT)
    t2 = build_plan_table(cfg, BUCKETS, qs, cost=cm, cache_dir=cache)
    assert partition_jax.SOLVE_COUNT == solves, "cache hit must not solve"
    assert pt_mod.BUILD_STATS["cache_hits"] == built0["cache_hits"] + 1
    assert t2.fingerprint == t1.fingerprint
    np.testing.assert_array_equal(t2.e_total, t1.e_total)
    # a different Q grid is a different fingerprint → fresh build
    fp = config_fingerprint(cfg, BUCKETS, qs, "time", cm)
    fp2 = config_fingerprint(cfg, BUCKETS, qs[:-1], "time", cm)
    assert fp != fp2
    # ... but the fingerprint is canonical: call order does not matter
    assert fp == config_fingerprint(cfg, BUCKETS[::-1], qs[::-1], "time", cm)


def test_builder_rejects_malformed_inputs():
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    with pytest.raises(PlanTableError):
        build_plan_table(cfg, [], [None])
    with pytest.raises(PlanTableError):
        build_plan_table(cfg, [(2, 16)], [])
    with pytest.raises(PlanTableError):
        build_plan_table(cfg, [(2, 16), (2, 16)], [None])
    with pytest.raises(PlanTableError):
        build_plan_table(cfg, [(2, 16)], [1e-3, 1e-3, None])


def test_canonical_ordering_is_call_order_invariant(plan_grid):
    """Permuted buckets/Q values build content-identical tables."""
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cm, qs = plan_grid(cfg)
    a = build_plan_table(cfg, BUCKETS, qs, cost=cm)
    b = build_plan_table(cfg, BUCKETS[::-1], qs[::-1], cost=cm)
    assert a.content_digest() == b.content_digest()
    assert a.buckets() == sorted(BUCKETS)
    assert list(a.q_grid) == sorted(a.q_grid)


def test_tabulated_cuts_drive_offload_and_remat(smoke_plan_table):
    """A kind='memory' table's stored bounds, priced through the planner,
    reproduce the directly-solved OffloadPlan/RematPlan at on-grid budgets."""
    cfg, _, qs, table = smoke_plan_table("zamba2-7b", kind="memory")
    planner = ServePlanner(table)
    b, s = BUCKETS[1]
    budget = sorted(q for q in qs if q is not None)[-1]  # on-grid, feasible

    derived = planner.offload_plan(cfg, b, s, budget)
    direct = plan_offload(cfg, b, s, budget)
    assert derived.bounds == direct.bounds
    assert derived.offload_bytes == direct.offload_bytes
    assert derived.pcie_seconds == direct.pcie_seconds
    assert derived.segment_peak_bytes == direct.segment_peak_bytes

    rem = planner.remat_plan(cfg, b, s, budget)
    assert rem.bounds == list(planner.plan_for(b, s, budget).bounds)
    assert rem.saved_bytes >= 0 and rem.compute_seconds > 0
    cuts = planner.pipeline_cuts(b, s, budget)
    assert cuts == tuple(j for (_, j) in rem.bounds[:-1])

    # a time-kind table refuses memory-model derivation
    _, _, _, t_time = smoke_plan_table("zamba2-7b", kind="time")
    with pytest.raises(PlanTableError):
        ServePlanner(t_time).offload_plan(cfg, b, s, budget)


def test_as_planner_coercions(tmp_path, smoke_plan_table):
    cfg, _, _, table = smoke_plan_table("qwen1.5-0.5b")
    path = str(tmp_path / "t.npz")
    table.save(path)
    assert as_planner(path).table.arch == cfg.name
    p = ServePlanner(table)
    assert as_planner(p) is p
    assert as_planner(table).table is table
    with pytest.raises(TypeError):
        as_planner(123)


class TestRequestCycles:
    def test_unbounded_is_one_cycle(self):
        assert request_cycles(7, 1.0, None) == [(1, 7)]
        assert request_cycles(0, 1.0, None) == []

    def test_exact_fill_uses_shared_tolerance(self):
        # budget exactly 3 steps + startup: float noise must not split it
        assert request_cycles(9, 0.1, 0.3 + 0.01, e_startup=0.01) == [
            (1, 3), (4, 6), (7, 9)
        ]

    def test_oversized_step_gets_own_cycle(self):
        assert request_cycles(3, 5.0, 1.0) == [(1, 1), (2, 2), (3, 3)]

    def test_startup_charged_per_cycle(self):
        # 2 steps/cycle with startup, 3 without
        assert request_cycles(6, 1.0, 3.0, e_startup=0.5) == [
            (1, 2), (3, 4), (5, 6)
        ]
        assert request_cycles(6, 1.0, 3.0, e_startup=0.0) == [
            (1, 3), (4, 6)
        ]
